"""Quickstart: compose App 1 (paper Table 1) and execute it via the app
compiler.

    PYTHONPATH=src python examples/quickstart.py

Composes the domain-specific dataflow — FC (isActive) -> VA (detector) ->
CR (re-id) -> TL (WBFS spotlight) — and runs the composed ``TrackingApp``
itself on the 1000-camera discrete-event platform:
``repro.core.compile.compile_app`` lowers the app + world + deployment onto
the Task DAG and ``TrackingScenario`` drives it.  The tuning-triangle claim
to check: with dynamic batching, zero events miss the gamma deadline.
"""

import sys

sys.path.insert(0, "src")

from repro.core.compile import DeploymentSpec, linear_xi
from repro.core.dataflow import ModuleSpec, TrackingApp, fc_is_active, make_cr, make_va
from repro.core.tracking import TLWBFS
from repro.sim import ScenarioConfig, TrackingScenario, WorldKey, get_world


def hog_detector(frames, query):
    """Stand-in for OpenCV HoG: every frame yields person candidates."""
    return [[(0, 0, 64, 128)] for _ in frames]


def openreid_matcher(crops, query):
    """Stand-in for the OpenReid DNN verdicts (crops arrive as
    ``(frame, boxes)`` pairs from the VA stage)."""
    return [bool(getattr(c[0], "has_entity", False)) for c in crops]


def main() -> None:
    # --- the workload: 1000 cameras, 300 s, the paper's entity walk ------ #
    cfg = ScenarioConfig(num_cameras=1000, duration_s=300.0)
    world = get_world(WorldKey.from_config(cfg))

    # --- compose App 1 (pure DSL; Table 1 row 1) ------------------------- #
    app = TrackingApp(
        name="app1-missing-person",
        fc=fc_is_active,
        va=make_va(hog_detector),
        cr=make_cr(openreid_matcher),
        tl=TLWBFS(world.road, world.cameras.camera_vertices, entity_speed=4.0),
        specs={
            "FC": ModuleSpec(xi=linear_xi(0.0002, 0.0008), resource_tier="edge"),
            "VA": ModuleSpec(instances=10, resource_tier="fog",
                             batching="dynamic", m_max=25,
                             xi=linear_xi(0.020, 0.010)),
            "CR": ModuleSpec(instances=10, resource_tier="cloud",
                             batching="dynamic", m_max=25,
                             xi=linear_xi(0.067, 0.053)),
        },
        gamma=15.0,
    )
    print(f"Composed {app.name}: gamma={app.gamma}s, "
          f"VA x{app.spec('VA').instances}, CR x{app.spec('CR').instances}")

    # --- compile + run it on the discrete-event platform ----------------- #
    # TrackingScenario lowers the app through compile_app and drives the
    # compiled pipeline; the DeploymentSpec holds the platform-side knobs.
    scenario = TrackingScenario(cfg, app=app, deployment=DeploymentSpec(num_nodes=10))
    res = scenario.run()
    s = res.summary()
    print("\nScenario summary:")
    for k, v in s.items():
        print(f"  {k:22s} {v}")
    assert s["delayed"] == 0, "dynamic batching should meet every deadline"
    print("\nOK: all events within gamma; spotlight peaked at "
          f"{s['peak_active']} of 1000 cameras.")

    # --- same app under dynamism: a Fig.-9-style bandwidth collapse ------ #
    # A DynamismSpec attaches to the workload config; the platform composes
    # the perturbation onto the network model, samples per-task telemetry
    # on a 5 s cadence, and scores tracking quality against the ground
    # truth.  Drops are enabled so the completion-budget protocol is live.
    from repro.sim import BandwidthCollapse, DynamismSpec

    perturbed = ScenarioConfig(
        num_cameras=300, duration_s=150.0, batching="dynamic",
        drops_enabled=True, avoid_drop_positives=True,
        dynamism=DynamismSpec((BandwidthCollapse(50.0, 90.0, 2e-5),)),
    )
    res2 = TrackingScenario(perturbed).run()
    trace = res2.trace
    rec = trace.budget_recovery("CR")
    q = res2.quality
    print("\nDynamism: 1 Gbps link collapses over t=[50,90)s ...")
    print(f"  CR budget: pre={rec['pre']:.1f}s  post={rec['post']:.1f}s "
          f"(recovery {rec['recovery']:.2f}x via {res2.summary()['probes']} probes)")
    print(f"  dropped {res2.dropped_fraction:.0%} of frames, yet track "
          f"recall={q['track_recall']:.2f} precision={q['track_precision']:.2f}")
    assert rec["recovery"] >= 0.9, "dynamic batching should recover its budget"
    print("OK: budget recovered after the collapse.")

    # --- multi-query tenancy: two users, one shared pipeline ------------- #
    # The platform serves a *set* of concurrent tracking queries through
    # ONE pipeline: each sourced frame is tagged with the live queries
    # interested in its camera, the active set is the union of the queries'
    # spotlights, and per-query summaries are split back out at the sink.
    # Query 1 is cancelled mid-run; its cameras drop out of the union and
    # anything still in flight is orphan-accounted, never attributed.
    from repro.query import MultiQueryScenario, QuerySpec

    mq_cfg = ScenarioConfig(num_cameras=300, duration_s=150.0)
    res3 = MultiQueryScenario(
        mq_cfg,
        [
            QuerySpec(),                          # user A: track from t=0
            QuerySpec(submit_at=20.0, cancel_at=90.0),  # user B: cancels
        ],
    ).run()
    print("\nMulti-query: two queries, one pipeline ...")
    for qid, st in sorted(res3.registry.states.items()):
        s_q = res3.per_query_summary(qid)
        print(f"  query {qid}: state={st.state:9s} events={s_q['source_events']}"
              f" positives={s_q['positives_completed']}"
              f" median_lat={s_q['median_latency_s']}s")
    g = res3.summary()
    print(f"  shared pipeline sourced {g['source_events']} events for "
          f"{g['per_query_sourced_sum']} per-query deliveries "
          f"(union peak {g['union_peak_active']} cameras)")
    assert res3.states[0] == "found" and res3.states[1] == "cancelled"
    assert res3.registry.reconcile()[1]["unaccounted"] == 0
    print("OK: multi-query tenancy — cancelled mid-run, books balanced.")

    # --- fault tolerance: crash a host, restore from the journal --------- #
    # A HostCrash kills node0 for 20 s mid-run: its queued events are lost
    # (charged as the dp_fault drop class), blocked sends retry with seeded
    # backoff, and the books still reconcile exactly.  The serving driver
    # journals the event stream + periodic snapshots; after the driver
    # itself is killed at t=100, a fresh build replays to the last snapshot
    # (bit-verified) and continues — producing per-query summaries
    # bit-identical to a run that was never interrupted.
    from repro.serving.journal import Journal
    from repro.sim import HostCrash

    fault_cfg = lambda: ScenarioConfig(
        num_cameras=100, duration_s=120.0,
        dynamism=DynamismSpec((HostCrash(("node0",), t_start=60.0, outage_s=20.0),)),
    )
    ref = MultiQueryScenario(fault_cfg(), 2, journal=Journal(snapshot_period_s=30.0))
    ref_res = ref.run()

    crashed = MultiQueryScenario(fault_cfg(), 2, journal=Journal(snapshot_period_s=30.0))
    crashed.run_until(100.0)  # the driver dies here; only its journal survives
    wal = crashed.journal

    recovered = MultiQueryScenario(fault_cfg(), 2, journal=Journal(snapshot_period_s=30.0))
    recovered.restore(wal)  # replay to t=90, bit-verify the frontier
    rec_res = recovered.run()

    print("\nFault tolerance: node0 crashes over t=[60,80)s, driver killed at t=100 ...")
    s_ref = ref_res.per_query_summary(0)
    print(f"  lost {ref_res.per_query[0].drops_by_task.get('dp_fault', 0)} events to "
          f"the crash; {s_ref['source_events']} sourced == "
          f"{s_ref['on_time'] + s_ref['delayed']} completed + {s_ref['dropped']} dropped")
    assert all(
        rec_res.per_query_summary(q) == ref_res.per_query_summary(q)
        for q in ref_res.per_query
    )
    assert recovered.journal.digest() == ref.journal.digest()
    print("OK: crash-and-restore — recovered run bit-identical to uninterrupted.")


if __name__ == "__main__":
    main()
