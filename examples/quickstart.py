"""Quickstart: compose App 1 (paper Table 1) and run a tracking scenario.

    PYTHONPATH=src python examples/quickstart.py

Composes the domain-specific dataflow — FC (isActive) -> VA (detector) ->
CR (re-id) -> TL (WBFS spotlight) — and runs the 1000-camera simulation with
Anveshak's dynamic batching.  The tuning-triangle claim to check: with the
batching knob on 'dynamic', zero events miss the gamma deadline.
"""

import sys

sys.path.insert(0, "src")

from repro.core.dataflow import ModuleSpec, TrackingApp, fc_is_active, make_cr, make_va
from repro.core.roadnet import make_road_network
from repro.core.tracking import TLWBFS
from repro.sim import ScenarioConfig, TrackingScenario


def hog_detector(frames, query):
    """Stand-in for OpenCV HoG: every frame yields person candidates."""
    return [[(0, 0, 64, 128)] for _ in frames]


def openreid_matcher(crops, query):
    """Stand-in for the OpenReid DNN verdicts."""
    return [bool(getattr(c, "has_entity", False)) for c in crops]


def main() -> None:
    # --- compose App 1 (pure DSL view; Table 1 row 1) ------------------- #
    road = make_road_network(seed=0)
    cameras = {i: i for i in range(1000)}
    app = TrackingApp(
        name="app1-missing-person",
        fc=fc_is_active,
        va=make_va(hog_detector),
        cr=make_cr(openreid_matcher),
        tl=TLWBFS(road, cameras, entity_speed=4.0),
        specs={
            "VA": ModuleSpec(instances=10, resource_tier="fog", batching="dynamic", m_max=25),
            "CR": ModuleSpec(instances=10, resource_tier="cloud", batching="dynamic", m_max=25),
        },
        gamma=15.0,
    )
    print(f"Composed {app.name}: gamma={app.gamma}s, "
          f"VA x{app.spec('VA').instances}, CR x{app.spec('CR').instances}")

    # --- run it on the discrete-event platform --------------------------- #
    cfg = ScenarioConfig(
        num_cameras=1000, duration_s=300.0, tl="wbfs", tl_peak_speed=4.0,
        batching="dynamic", m_max=25, gamma=app.gamma,
    )
    res = TrackingScenario(cfg).run()
    s = res.summary()
    print("\nScenario summary:")
    for k, v in s.items():
        print(f"  {k:22s} {v}")
    assert s["delayed"] == 0, "dynamic batching should meet every deadline"
    print("\nOK: all events within gamma; spotlight peaked at "
          f"{s['peak_active']} of 1000 cameras.")


if __name__ == "__main__":
    main()
