"""Train a ~100M-parameter LM for a few hundred steps on synthetic data.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch llama3.2-1b]

Uses the training substrate end to end: config -> init -> AdamW(+schedule)
-> jit'd train step -> checkpoint.  The ~100M variant is the assigned arch's
family scaled to d_model=768 / 12 layers (not the 2-layer smoke config).
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.config import get_config
from repro.models import init_params
from repro.training import AdamWConfig, TrainConfig, lm_batches, save_checkpoint, train_loop


def hundred_m_config(arch: str):
    cfg = get_config(arch)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-100m",
        n_layers=12,
        d_model=768,
        n_heads=12 if cfg.n_heads else 0,
        n_kv_heads=4 if cfg.n_kv_heads else 0,
        head_dim=64,
        d_ff=2048 if cfg.d_ff else 0,
        vocab_size=32000,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--out", default="/tmp/repro_ckpt/lm")
    args = ap.parse_args()

    cfg = hundred_m_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab_size}) "
          f"schedule={cfg.lr_schedule}")

    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=args.lr),
        warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps,
    )
    params, opt, hist = train_loop(
        params, cfg, tcfg,
        lm_batches(cfg, batch=args.batch, seq=args.seq, seed=0),
        steps=args.steps, log_every=max(args.steps // 15, 1),
    )
    print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    save_checkpoint(args.out, params, metadata={"arch": cfg.name, "steps": args.steps})
    print(f"checkpoint written to {args.out}.npz")


if __name__ == "__main__":
    main()
