"""Serve a small LM with Anveshak-scheduled batched requests.

    PYTHONPATH=src python examples/serve_llm.py [--arch qwen2-1.5b] [--requests 24]

The decode engine (prefill + KV-cache decode, greedy) runs as a
:class:`ServedStage`-style loop: prompt requests arrive, the dynamic
deadline batcher forms padded buckets, the completion budget drops requests
that cannot meet gamma, and every surviving prompt is decoded to completion.
This is the paper's VA/CR pattern with a language model as the analytic.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.models import init_params, reduced_config
from repro.serving import Generator, bucket_for
from repro.core.batching import DynamicBatcher, PendingEvent
from repro.core.events import Event, EventHeader, new_event_id


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--gamma", type=float, default=30.0)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    print(f"Serving {cfg.name} ({cfg.arch_type}); gamma={args.gamma}s")
    params = init_params(jax.random.PRNGKey(0), cfg)
    gen = Generator(cfg, params)

    # Warm the jit caches on the buckets we expect.
    for b in (1, 4, 8):
        gen.generate(jnp.zeros((b, args.prompt_len), jnp.int32), max_new_tokens=2)

    # xi(b): measure a full generate on each bucket.
    def measure(b: int) -> float:
        prompts = jnp.zeros((b, args.prompt_len), jnp.int32)
        t0 = time.perf_counter()
        jax.block_until_ready(gen.generate(prompts, max_new_tokens=args.new_tokens))
        return time.perf_counter() - t0

    xi_pts = {b: measure(b) for b in (1, 4, 8)}
    xi = lambda m: float(np.interp(m, list(xi_pts), list(xi_pts.values())))
    print("xi(b):", {b: f"{t*1e3:.0f}ms" for b, t in xi_pts.items()})

    batcher = DynamicBatcher(xi, m_max=8)
    rng = np.random.default_rng(0)
    served = total_latency = 0
    t_start = time.perf_counter()

    def run_batch(batch):
        nonlocal served, total_latency
        m = len(batch)
        bucket = bucket_for(m, (1, 2, 4, 8))
        prompts = np.zeros((bucket, args.prompt_len), np.int32)
        for i, pe in enumerate(batch):
            prompts[i] = pe.event.value
        out = gen.generate(jnp.asarray(prompts), max_new_tokens=args.new_tokens)
        now = time.perf_counter()
        for i, pe in enumerate(batch):
            served += 1
            total_latency += now - pe.event.header.source_arrival
        return out

    for i in range(args.requests):
        # Poisson-ish arrivals at ~4 req/s.
        time.sleep(float(rng.exponential(0.25)))
        now = time.perf_counter()
        prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        ev = Event(header=EventHeader(event_id=new_event_id(), source_arrival=now),
                   key=i, value=prompt)
        batch = batcher.offer(
            PendingEvent(event=ev, arrival=now, deadline=now + args.gamma), now
        )
        if batch:
            run_batch(batch)
        flushed = batcher.flush_if_due(time.perf_counter())
        if flushed:
            run_batch(flushed)
    leftover = batcher.take()
    if leftover:
        run_batch(leftover)

    wall = time.perf_counter() - t_start
    print(
        f"\nServed {served}/{args.requests} prompts in {wall:.1f}s "
        f"(mean latency {total_latency/max(served,1):.2f}s, "
        f"{served*args.new_tokens/wall:.1f} tok/s)"
    )


if __name__ == "__main__":
    main()
