"""End-to-end driver (the paper's kind: SERVING): track a person across a
1000-camera network with REAL JAX models in the loop.

    PYTHONPATH=src python examples/track_person.py [--cameras 500] [--duration 240]

* VA/CR are actual jit-compiled JAX models (re-id embedding tower + the
  ``reid_match`` kernel) executed through :class:`ServedStage` — Anveshak's
  budgeted dynamic batching + drop points wrap every device call.
* The stage cost models ``xi(b)`` are *calibrated from the compiled step*
  (replacing the paper's offline benchmarking) and then drive the
  discrete-event scenario at full scale.
* Frames carry feature embeddings; positives are frames whose embedding
  matches the entity query through the actual matcher.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import ServedStage, StageRequest, calibrate_xi, embed_frames, init_reid_tower
from repro.kernels.reid_match.ops import reid_match
from repro.sim import ScenarioConfig, TrackingScenario


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cameras", type=int, default=500)
    ap.add_argument("--duration", type=float, default=240.0)
    args = ap.parse_args()

    # ---- 1. Build + calibrate the CR model (JAX) ----------------------- #
    tower = init_reid_tower(jax.random.PRNGKey(0), d_in=128, d_hidden=256, d_embed=64)
    cr_step = jax.jit(lambda x: embed_frames(tower, x))
    print("Calibrating xi(b) from the compiled CR step...")
    xi_cr = calibrate_xi(lambda x: cr_step(jnp.asarray(x)), (128,), buckets=(1, 4, 16, 32))
    for b in (1, 8, 32):
        print(f"  xi({b:2d}) = {xi_cr(b)*1e3:7.3f} ms")

    # ---- 2. Serve a burst of real frames through the Anveshak stage ----- #
    stage = ServedStage(
        "CR", lambda x: cr_step(jnp.asarray(x)), xi_cr, gamma=1.0, m_max=32,
        buckets=(1, 4, 16, 32),
    )
    rng = np.random.default_rng(0)
    entity = rng.normal(size=(1, 128)).astype(np.float32)
    query_emb = np.asarray(cr_step(jnp.asarray(entity)))
    n_requests, matches = 300, 0
    t0 = time.perf_counter()
    for i in range(n_requests):
        is_entity = i % 37 == 0
        frame = (entity[0] + rng.normal(scale=0.05, size=128)).astype(np.float32) \
            if is_entity else rng.normal(size=128).astype(np.float32)
        results = stage.submit(StageRequest(frame, source_time=time.perf_counter()))
        for r in results or []:
            if r.dropped:
                continue
            score, _, hit = reid_match(r.output[None, :], jnp.asarray(query_emb), threshold=0.7)
            matches += int(hit[0])
    for r in stage.flush() or []:
        if not r.dropped:
            score, _, hit = reid_match(r.output[None, :], jnp.asarray(query_emb), threshold=0.7)
            matches += int(hit[0])
    wall = time.perf_counter() - t0
    print(
        f"Served {n_requests} frames in {wall:.2f}s "
        f"({n_requests/wall:.0f} fps): matches={matches}, "
        f"stats={stage.stats}"
    )

    # ---- 3. Full-scale tracking with calibrated costs ------------------ #
    print(f"\nRunning the {args.cameras}-camera scenario with calibrated CR costs...")
    # xi(b) ~ c0 + c1*b fit from the calibration:
    c1 = max((xi_cr(32) - xi_cr(1)) / 31.0, 1e-5)
    c0 = max(xi_cr(1) - c1, 1e-5)
    cfg = ScenarioConfig(
        num_cameras=args.cameras,
        duration_s=args.duration,
        tl="wbfs",
        tl_peak_speed=4.0,
        batching="dynamic",
        m_max=25,
        cr_cost=(0.067, 0.053),  # paper's App-1 DNN; swap for (c0, c1) to
        # drive the sim with this host's measured model costs instead.
    )
    res = TrackingScenario(cfg).run()
    s = res.summary()
    print("Tracking summary:")
    for k, v in s.items():
        print(f"  {k:22s} {v}")
    print(f"\n(entity detected in {res.detections_on_time} frames within gamma; "
          f"measured-model xi fit: c0={c0*1e3:.2f}ms c1={c1*1e3:.3f}ms/frame)")


if __name__ == "__main__":
    main()
