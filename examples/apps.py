"""The four tracking applications of paper Table 1, composed in the DSL and
**executed end-to-end** through the app compiler.

    PYTHONPATH=src python examples/apps.py

Demonstrates the programming model (paper §2.3): each app is a handful of
lines — only the module logics change, the dataflow is fixed — and a
composed :class:`TrackingApp` is the platform's executable unit.  The main
program runs all four apps through ``SweepRunner`` (fork pool where
available): each grid case pairs an app *factory* with a workload, the
worker builds the app against the shared world and
``repro.core.compile.compile_app`` lowers it onto the discrete-event
pipeline (App 2 exercising the QF query-fusion feedback edge, App 4 the
real JAX re-id towers through the bucket-batched kernel dispatch plane).

App factories (not instances) go into the grid so JAX-touching apps
construct *inside* the fork workers — the parent never initializes a JAX
backend before forking.
"""

import sys

sys.path.insert(0, "src")

from dataclasses import replace

from repro.core.compile import DeploymentSpec, linear_xi
from repro.core.dataflow import (
    ModuleSpec,
    TrackingApp,
    fc_frame_rate,
    fc_is_active,
    make_cr,
    make_va,
)
from repro.core.tracking import TLBFS, TLProbabilistic, TLWBFS
from repro.sim import AppCase, ScenarioConfig, SweepRunner

# One workload for the whole grid: a 300-camera / 60 s slice of the paper's
# setup (the benchmarks run the full 1000-camera grids).  App 4 adds real
# 128-d frame embeddings so its towers have tensors to chew on.
WORKLOAD = ScenarioConfig(num_cameras=300, duration_s=60.0, seed=0)
EMBED_WORKLOAD = replace(WORKLOAD, embed_dim=128)

# Paper cost models: VA ~30 ms/frame streaming, CR ~120 ms/event (App 1),
# App 2's better CR DNN ~63% slower, App 3's YOLO heavier than HoG.
_FC_COST = (0.0002, 0.0008)
_VA_COST = (0.020, 0.010)
_CR_COST = (0.067, 0.053)


def _specs(batching="dynamic", va_scale=1.0, cr_scale=1.0):
    return {
        "FC": ModuleSpec(xi=linear_xi(*_FC_COST), resource_tier="edge"),
        "VA": ModuleSpec(
            instances=10, resource_tier="fog", batching=batching, m_max=25,
            xi=linear_xi(_VA_COST[0] * va_scale, _VA_COST[1] * va_scale),
        ),
        "CR": ModuleSpec(
            instances=10, resource_tier="cloud", batching=batching, m_max=25,
            xi=linear_xi(_CR_COST[0] * cr_scale, _CR_COST[1] * cr_scale),
        ),
    }


def _frame_of(value):
    """VA emits ``(frame, boxes)`` pairs; CR crops unwrap to the frame."""
    return value[0] if isinstance(value, tuple) else value


# --------------------------------------------------------------------- #
# The four apps (Table 1).  Each builder takes the world geometry the    #
# app will run over; the analytics are stand-ins except App 4's real     #
# JAX towers.                                                            #
# --------------------------------------------------------------------- #
def build_app1(road, cameras, batching="dynamic"):
    """App 1: missing person — HoG + OpenReid stand-ins + WBFS spotlight."""
    hog = lambda frames, q: [[(0, 0, 64, 128)] for _ in frames]           # [20]
    person_reid = lambda crops, q: [
        bool(getattr(_frame_of(c), "has_entity", False)) for c in crops   # [2]
    ]
    return TrackingApp(
        name="app1",
        fc=fc_is_active,
        va=make_va(hog),
        cr=make_cr(person_reid),
        tl=TLWBFS(road, cameras, entity_speed=4.0),
        specs=_specs(batching),
    )


def build_app2(road, cameras, batching="dynamic"):
    """App 2: better CR DNN + query fusion + plain BFS.  QF fuses every
    confirmed sighting into the entity query (stand-in for the RNN query
    refresher [42]); the platform pushes each fused query to the VA/CR
    states over the control network."""
    hog = lambda frames, q: [[(0, 0, 64, 128)] for _ in frames]
    person_reid_v2 = lambda crops, q: [
        bool(getattr(_frame_of(c), "has_entity", False)) for c in crops   # [8]
    ]

    def qf_fuse(detections, state):
        fused = state.get("fused_hits", 0) + len(detections)
        state["fused_hits"] = fused
        return ("query", fused)  # a new (refined) query object per fusion

    return TrackingApp(
        name="app2",
        fc=fc_is_active,
        va=make_va(hog),
        cr=make_cr(person_reid_v2),
        tl=TLBFS(road, cameras, entity_speed=4.0, fixed_edge_length_m=84.5),
        qf=qf_fuse,
        specs=_specs(batching, cr_scale=1.63),
    )


def build_app3(road, cameras, batching="dynamic"):
    """App 3: stolen vehicle — frame-rate FC, YOLO + car re-id stand-ins,
    speed-aware WBFS (~50 km/h car)."""
    yolo_cars = lambda frames, q: [[(0, 0, 96, 64)] for _ in frames]      # [47]
    car_reid = lambda crops, q: [
        bool(getattr(_frame_of(c), "has_entity", False)) for c in crops   # [53]
    ]
    return TrackingApp(
        name="app3",
        fc=fc_frame_rate,
        va=make_va(yolo_cars),
        cr=make_cr(car_reid),
        tl=TLWBFS(road, cameras, entity_speed=14.0),
        specs=_specs(batching, va_scale=1.5),
    )


def build_app4(road, cameras, batching="dynamic", entity_embedding=None):
    """App 4: small/large re-id tower pair + probabilistic TL — the real
    JAX towers, with gallery scoring routed through the bucket-batched
    kernel dispatch plane (``repro.kernels.dispatch``).

    ``entity_embedding`` is the tracked entity's raw 128-d feature (the
    simulator's camera network exposes it when the workload carries
    ``embed_dim=128``); the entity query holds its small/large tower
    embeddings.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import dispatch
    from repro.serving import embed_frames, init_reid_tower

    small_tower = init_reid_tower(jax.random.PRNGKey(0), d_in=128, d_hidden=128, d_embed=32)
    large_tower = init_reid_tower(jax.random.PRNGKey(1), d_in=128, d_hidden=512, d_embed=64, depth=4)

    if entity_embedding is None:
        entity_embedding = np.zeros(128, np.float32)
    query = {
        "small": np.asarray(embed_frames(small_tower, jnp.asarray(entity_embedding)[None, :])),
        "large": np.asarray(embed_frames(large_tower, jnp.asarray(entity_embedding)[None, :])),
    }

    def _features(values):
        feats = []
        for v in values:
            frame = _frame_of(v)
            if isinstance(frame, np.ndarray):  # raw feature vector
                feats.append(np.asarray(frame, np.float32))
                continue
            emb = getattr(frame, "embedding", None)
            feats.append(np.zeros(128, np.float32) if emb is None else emb)
        return np.stack(feats)

    def _query(q, tower):
        # The compiled app carries the small/large tower query pair; callers
        # poking the logic directly may pass a bare embedded query.
        return q[tower] if isinstance(q, dict) else np.asarray(q)

    def reid_small(frames, q):  # VA: cheap tower filters candidates
        embs = np.asarray(embed_frames(small_tower, jnp.asarray(_features(frames))))
        _, _, hits = dispatch.reid_match(embs, _query(q, "small"), threshold=0.3)
        return [[(0, 0, 64, 128)] if bool(h) else [] for h in np.asarray(hits)]

    def reid_large(crops, q):  # CR: accurate tower confirms
        embs = np.asarray(embed_frames(large_tower, jnp.asarray(_features(crops))))
        _, _, hits = dispatch.reid_match(embs, _query(q, "large"), threshold=0.7)
        return [bool(h) for h in np.asarray(hits)]

    return TrackingApp(
        name="app4",
        fc=fc_is_active,
        va=make_va(reid_small),
        cr=make_cr(reid_large),
        tl=TLProbabilistic(road, cameras, entity_speed=4.0, coverage=0.9),
        entity_query=query,
        specs=_specs(batching),
    )


_BUILDERS = {"app1": build_app1, "app2": build_app2, "app3": build_app3, "app4": build_app4}


def app_factory(name, batching="dynamic"):
    """A sweep-grid factory ``(world, cameras) -> TrackingApp``: the app is
    built against the case's world geometry inside the worker process."""
    build = _BUILDERS[name]

    def factory(world, cameras):
        kw = {}
        if name == "app4":
            kw["entity_embedding"] = getattr(cameras, "entity_embedding", None)
        return build(world.road, cameras.camera_vertices, batching=batching, **kw)

    return factory


def table1_grid(batching="dynamic"):
    """All four Table-1 apps as one ``SweepRunner`` grid."""
    grid = []
    for name in ("app1", "app2", "app3"):
        grid.append(
            (name, AppCase(app=app_factory(name, batching), workload=WORKLOAD,
                           deployment=DeploymentSpec()))
        )
    grid.append(
        ("app4", AppCase(app=app_factory("app4", batching), workload=EMBED_WORKLOAD,
                         deployment=DeploymentSpec(), needs_jax=True))
    )
    return grid


def build_apps(road=None, cameras=None):
    """All four apps composed against one (small, display-only) world —
    the DSL-conciseness exhibit (paper §2.3)."""
    if road is None:
        from repro.core.roadnet import make_road_network

        road = make_road_network(seed=0)
    if cameras is None:
        cameras = {i: i for i in range(min(1000, road.num_vertices))}
    return [
        build_app1(road, cameras),
        build_app2(road, cameras),
        build_app3(road, cameras),
        build_app4(road, cameras),
    ]


def main() -> None:
    # ---- execute: the composed apps ARE the runnable artifact ---------- #
    # (Run first: app factories construct JAX-touching apps inside the
    # fork workers, so the parent forks before any JAX backend exists.)
    mode = "fork" if SweepRunner.fork_available() else "serial"
    print(f"Running the four Table-1 apps end-to-end (SweepRunner, {mode})...\n")
    res = SweepRunner(mode=mode).run(table1_grid("dynamic"))
    for rec in res.records:
        s = rec.summary
        print(
            f"  {rec.name}: events={s['source_events']} on_time={s['on_time']} "
            f"delayed={s['delayed']} peak_active={s['peak_active']} "
            f"positives={s['positives_completed']}/{s['positives_generated']} "
            f"({rec.run_s:.2f}s run)"
        )
    print(f"\nSweep: mode={res.mode} workers={res.workers} wall={res.wall_s:.2f}s")

    # ---- compose: the DSL-conciseness exhibit -------------------------- #
    apps = build_apps()
    print(f"\nComposed {len(apps)} tracking applications (paper Table 1):\n")
    for app in apps:
        tl_name = type(app.tl).__name__
        print(
            f"  {app.name}: FC={app.fc.__name__} TL={tl_name} "
            f"QF={'yes' if app.qf else '—'} gamma={app.gamma}s "
            f"(VA x{app.spec('VA').instances} on {app.spec('VA').resource_tier}, "
            f"CR x{app.spec('CR').instances} on {app.spec('CR').resource_tier})"
        )
    # Exercise App 4's real JAX towers once more, standalone.
    import numpy as np

    frames = np.random.default_rng(0).normal(size=(6, 128)).astype(np.float32)

    class _F:  # minimal frame stand-in with a feature vector
        def __init__(self, emb):
            self.embedding = emb

    boxes = apps[3].va(0, [_F(f) for f in frames], {"entity_query": apps[3].entity_query})
    print(f"\nApp 4 small-tower VA scored {len(boxes)} frames "
          f"({sum(1 for _, b in boxes if b)} candidates) — JAX end to end.")


if __name__ == "__main__":
    main()
