"""The four tracking applications of paper Table 1, composed in the DSL.

    PYTHONPATH=src python examples/apps.py

Demonstrates the programming model's conciseness (paper §2.3): each app is a
handful of lines — only the module logics change, the dataflow is fixed.
App 4's small/large re-id pair uses the actual JAX re-id towers.
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.dataflow import ModuleSpec, TrackingApp, fc_frame_rate, fc_is_active, make_cr, make_va
from repro.core.roadnet import make_road_network
from repro.core.tracking import TLBFS, TLProbabilistic, TLWBFS
from repro.serving import embed_frames, init_reid_tower
from repro.kernels.reid_match.ops import reid_match


def build_apps():
    road = make_road_network(seed=0)
    cameras = {i: i for i in range(1000)}

    # ---- analytics logics (stand-ins / real JAX towers) ----------------- #
    hog = lambda frames, q: [[(0, 0, 64, 128)] for _ in frames]           # [20]
    yolo_cars = lambda frames, q: [[(0, 0, 96, 64)] for _ in frames]      # [47]
    person_reid = lambda crops, q: [bool(getattr(c, "has_entity", 0)) for c in crops]  # [2]
    person_reid_v2 = lambda crops, q: [bool(getattr(c, "has_entity", 0)) for c in crops]  # [8]
    car_reid = lambda crops, q: [bool(getattr(c, "has_entity", 0)) for c in crops]     # [53]

    small_tower = init_reid_tower(jax.random.PRNGKey(0), d_in=128, d_hidden=128, d_embed=32)
    large_tower = init_reid_tower(jax.random.PRNGKey(1), d_in=128, d_hidden=512, d_embed=64, depth=4)

    def reid_small(frames, query):  # App 4 VA: cheap tower filters candidates
        embs = embed_frames(small_tower, jnp.asarray([f for f in frames]))
        _, _, hits = reid_match(embs, jnp.asarray(query), threshold=0.3)
        return [[(0, 0, 64, 128)] if bool(h) else [] for h in hits]

    def reid_large(crops, query):  # App 4 CR: accurate tower confirms
        embs = embed_frames(large_tower, jnp.asarray([c for c in crops]))
        _, _, hits = reid_match(embs, jnp.asarray(query), threshold=0.7)
        return [bool(h) for h in hits]

    def qf_rnn(detections, state):  # App 2 QF: fuse hits into the query [42]
        return state.get("entity_query")

    apps = [
        TrackingApp(  # App 1: missing person, HoG + OpenReid + WBFS
            name="app1",
            fc=fc_is_active,
            va=make_va(hog),
            cr=make_cr(person_reid),
            tl=TLWBFS(road, cameras, entity_speed=4.0),
        ),
        TrackingApp(  # App 2: better CR DNN + query fusion + plain BFS
            name="app2",
            fc=fc_is_active,
            va=make_va(hog),
            cr=make_cr(person_reid_v2),
            tl=TLBFS(road, cameras, entity_speed=4.0, fixed_edge_length_m=84.5),
            qf=qf_rnn,
        ),
        TrackingApp(  # App 3: stolen vehicle — frame-rate FC, YOLO, car re-id,
            name="app3",  # speed-aware WBFS
            fc=fc_frame_rate,
            va=make_va(yolo_cars),
            cr=make_cr(car_reid),
            tl=TLWBFS(road, cameras, entity_speed=14.0),  # ~50 km/h car
        ),
        TrackingApp(  # App 4: small/large re-id pair + probabilistic TL
            name="app4",
            fc=fc_is_active,
            va=make_va(reid_small),
            cr=make_cr(reid_large),
            tl=TLProbabilistic(road, cameras, entity_speed=4.0, coverage=0.9),
        ),
    ]
    for app in apps:
        app.specs = {
            "VA": ModuleSpec(instances=10, resource_tier="fog", batching="dynamic"),
            "CR": ModuleSpec(instances=10, resource_tier="cloud", batching="dynamic"),
        }
    return apps


def main() -> None:
    apps = build_apps()
    print(f"Composed {len(apps)} tracking applications (paper Table 1):\n")
    for app in apps:
        tl_name = type(app.tl).__name__
        print(
            f"  {app.name}: FC={app.fc.__name__} TL={tl_name} "
            f"QF={'yes' if app.qf else '—'} gamma={app.gamma}s "
            f"(VA x{app.spec('VA').instances} on {app.spec('VA').resource_tier}, "
            f"CR x{app.spec('CR').instances} on {app.spec('CR').resource_tier})"
        )
    # Exercise App 4's real JAX towers once.
    import numpy as np

    frames = np.random.default_rng(0).normal(size=(6, 128)).astype(np.float32)
    query = np.random.default_rng(1).normal(size=(1, 32)).astype(np.float32)
    boxes = apps[3].va(0, list(frames), {"entity_query": query})
    print(f"\nApp 4 small-tower VA scored {len(boxes)} frames "
          f"({sum(1 for _, b in boxes if b)} candidates) — JAX end to end.")


if __name__ == "__main__":
    main()
