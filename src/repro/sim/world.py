"""Shared-world bundles for the sweep engine (one build per world key).

The paper's evaluation is a *grid* of scenarios (batching x dropping x
tracking-logic x camera-count sweeps) and most grid points share the exact
same world: road network, entity walk, camera placement, and the static
per-(src, dst) transit classification.  A :class:`WorldBundle` owns that
immutable state, built once per :class:`WorldKey` and shared by every
:class:`~repro.sim.scenario.TrackingScenario` that uses it:

* **in-process cache** — ``get_world`` memoizes bundles in a small LRU, so
  the second 10k-camera scenario constructs in a fraction of the first's
  build time;
* **on-disk cache** — set ``REPRO_WORLD_CACHE`` to a directory (or ``1``
  for ``~/.cache/repro/worlds``) and bundles are pickled across processes;
  ``benchmarks.run`` enables this by default.  Entries are keyed by a
  version-salted hash of the :class:`WorldKey`; bump
  :data:`WORLD_CACHE_VERSION` whenever world construction changes.

Bundles are *bit-identical* to what ``TrackingScenario.__init__`` used to
build inline: :meth:`WorldKey.from_config` replicates the old constructor's
parameter derivation exactly, so per-config ``summary()`` dicts are
unchanged by the refactor.

Sharing contract: everything in a bundle is treated as immutable by
consumers.  The one exception is ``embed_dim > 0`` camera networks, whose
embedding RNG is stateful — scenarios that need embeddings build their own
:class:`CameraNetwork` (still sharing the bundle's road + walk).
"""

from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.roadnet import RoadNetwork, make_road_network

from .cameras import CameraNetwork, EntityWalk

__all__ = [
    "WorldKey",
    "WorldBundle",
    "get_world",
    "build_world",
    "world_cache_stats",
    "clear_world_cache",
    "WORLD_CACHE_VERSION",
]

#: Bump whenever RoadNetwork / EntityWalk / CameraNetwork construction
#: changes in a way that affects the built world: stale on-disk bundles
#: would otherwise silently break bit-identity with fresh builds.
WORLD_CACHE_VERSION = 1

_WORLDS: "OrderedDict[WorldKey, WorldBundle]" = OrderedDict()
_WORLDS_MAX = 8
_STATS = {
    "builds": 0,
    "memory_hits": 0,
    "disk_hits": 0,
    "disk_writes": 0,
    "disk_write_errors": 0,
}


@dataclass(frozen=True)
class WorldKey:
    """Identity of a shareable world: everything world construction reads."""

    num_cameras: int
    seed: int
    road_vertices: int
    road_edges: int
    mean_length_m: float
    entity_speed_mps: float
    walk_horizon_s: float
    fov_radius_m: float
    fps: float

    @classmethod
    def from_config(cls, cfg) -> "WorldKey":
        """Derive the key from a ``ScenarioConfig`` exactly the way the
        scenario constructor used to derive its world parameters."""
        num_vertices = cfg.road_vertices or max(1000, cfg.num_cameras)
        if num_vertices == 1000:
            road_edges = 2817
        else:
            # Keep the paper's edge density (2817/1000) and mean road length.
            road_edges = int(round(num_vertices * 2.817))
        return cls(
            num_cameras=int(cfg.num_cameras),
            seed=int(cfg.seed),
            road_vertices=int(num_vertices),
            road_edges=road_edges,
            mean_length_m=84.5,
            entity_speed_mps=float(cfg.entity_speed_mps),
            walk_horizon_s=float(cfg.duration_s) + 60.0,
            fov_radius_m=float(cfg.fov_radius_m),
            fps=float(cfg.fps),
        )


@dataclass
class WorldBundle:
    """Immutable world shared by every scenario with the same key."""

    key: WorldKey
    road: RoadNetwork
    walk: EntityWalk
    cameras: CameraNetwork
    build_seconds: float = 0.0
    #: (num_va, num_cr, num_nodes) -> {(src_task, dst_task): (latency, over_net)}.
    #: The static transit classification depends only on the deployment shape
    #: and the (constant) NetworkModel latency tiers, so scenarios sharing a
    #: world also share the memoized table (see DiscreteEventSimulator).
    transit_tables: Dict[Tuple[int, int, int], Dict] = field(
        default_factory=dict, repr=False
    )

    def csr(self):
        """CSR view of the road graph (built once, cached on the network)."""
        return self.road.csr()

    def transit_table(self, num_va: int, num_cr: int, num_nodes: int) -> Dict:
        dep = (int(num_va), int(num_cr), int(num_nodes))
        table = self.transit_tables.get(dep)
        if table is None:
            table = self.transit_tables[dep] = {}
        return table


def build_world(key: WorldKey) -> WorldBundle:
    """Uncached world construction — bit-identical to the pre-sweep
    ``TrackingScenario.__init__`` inline build for the same config."""
    t0 = time.perf_counter()
    road = make_road_network(
        num_vertices=key.road_vertices,
        target_edges=key.road_edges,
        mean_length_m=key.mean_length_m,
        seed=key.seed,
    )
    walk = EntityWalk(
        road,
        start_vertex=0,
        speed_mps=key.entity_speed_mps,
        duration_s=key.walk_horizon_s,
        seed=key.seed + 7,
    )
    cameras = CameraNetwork(
        road,
        walk,
        num_cameras=key.num_cameras,
        fov_radius_m=key.fov_radius_m,
        fps=key.fps,
        seed=key.seed + 13,
    )
    _STATS["builds"] += 1
    return WorldBundle(
        key=key,
        road=road,
        walk=walk,
        cameras=cameras,
        build_seconds=time.perf_counter() - t0,
    )


# --------------------------------------------------------------------- #
# On-disk cache                                                          #
# --------------------------------------------------------------------- #
def _disk_dir() -> Optional[str]:
    """Directory for pickled bundles, from ``REPRO_WORLD_CACHE``:
    unset/empty/``0`` disables, ``1`` selects ``~/.cache/repro/worlds``,
    anything else is used as the directory path."""
    raw = os.environ.get("REPRO_WORLD_CACHE", "")
    if raw in ("", "0"):
        return None
    if raw == "1":
        return os.path.join(os.path.expanduser("~"), ".cache", "repro", "worlds")
    return raw


def _disk_path(key: WorldKey, root: str) -> str:
    digest = hashlib.sha1(
        repr((WORLD_CACHE_VERSION, key)).encode()
    ).hexdigest()[:20]
    return os.path.join(root, f"world_{digest}.pkl")


def _disk_load(key: WorldKey, root: str) -> Optional[WorldBundle]:
    path = _disk_path(key, root)
    try:
        with open(path, "rb") as f:
            payload = pickle.load(f)
    except (OSError, pickle.PickleError, EOFError, AttributeError):
        return None
    if (
        not isinstance(payload, dict)
        or payload.get("version") != WORLD_CACHE_VERSION
        or payload.get("key") != key
    ):
        return None
    bundle: WorldBundle = payload["bundle"]
    bundle.transit_tables = {}
    _STATS["disk_hits"] += 1
    return bundle


def _disk_store(bundle: WorldBundle, root: str) -> None:
    try:
        os.makedirs(root, exist_ok=True)
        payload = {
            "version": WORLD_CACHE_VERSION,
            "key": bundle.key,
            "bundle": WorldBundle(
                key=bundle.key,
                road=bundle.road,
                walk=bundle.walk,
                cameras=bundle.cameras,
                build_seconds=bundle.build_seconds,
            ),
        }
        fd, tmp = tempfile.mkstemp(dir=root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, _disk_path(bundle.key, root))
        except BaseException:
            os.unlink(tmp)
            raise
        _STATS["disk_writes"] += 1
    # Cache is best-effort; never fail the build over it — but count the
    # miss so a persistently broken cache dir is observable in _STATS.
    # repro: noqa[EXC001] — intentional best-effort swallow, counted above.
    except Exception:
        _STATS["disk_write_errors"] += 1


# --------------------------------------------------------------------- #
# Front door                                                             #
# --------------------------------------------------------------------- #
def get_world(key_or_config) -> WorldBundle:
    """Fetch (or build) the shared world for a :class:`WorldKey` or a
    ``ScenarioConfig``; the on-disk layer is governed by
    ``REPRO_WORLD_CACHE`` (see :func:`_disk_dir`)."""
    key = (
        key_or_config
        if isinstance(key_or_config, WorldKey)
        else WorldKey.from_config(key_or_config)
    )
    bundle = _WORLDS.get(key)
    if bundle is not None:
        _WORLDS.move_to_end(key)
        _STATS["memory_hits"] += 1
        return bundle
    root = _disk_dir()
    bundle = _disk_load(key, root) if root else None
    if bundle is None:
        bundle = build_world(key)
        if root:
            _disk_store(bundle, root)
    _WORLDS[key] = bundle
    while len(_WORLDS) > _WORLDS_MAX:
        _WORLDS.popitem(last=False)
    return bundle


def world_cache_stats() -> Dict[str, int]:
    stats = dict(_STATS)
    stats["resident"] = len(_WORLDS)
    return stats


def clear_world_cache(*, disk: bool = False) -> None:
    """Drop in-process bundles (and optionally the on-disk entries)."""
    _WORLDS.clear()
    for k in _STATS:
        _STATS[k] = 0
    if disk:
        root = _disk_dir()
        if root and os.path.isdir(root):
            for name in os.listdir(root):
                if name.startswith("world_") and name.endswith(".pkl"):
                    try:
                        os.unlink(os.path.join(root, name))
                    except OSError:
                        pass
