"""Discrete-event engine + WAN/MAN network model (paper §5.1 system setup).

The engine drives the :mod:`repro.core.pipeline` tasks: a heap of
``(time, seq, fn, args)`` callbacks.  ``schedule`` takes ``(delay, fn,
*args)`` so hot-path callers never need to allocate a closure per event.
The network model charges ``latency + size/bandwidth`` per transit between
nodes; the bandwidth is a function of time so the paper's Fig. 9 experiment
(1 Gbps -> 30 Mbps midway) is expressible.  The per-(src, dst) latency
classification (IPC / LAN / MAN) is cached — topology is static while the
bandwidth multiplier is not.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.pipeline import Scheduler

__all__ = ["NetworkModel", "DiscreteEventSimulator"]


def _default_bandwidth_schedule(t: float) -> float:
    return 1.0


def _hop_latency_is_man(src_host: str, dst_host: str) -> bool:
    """Hop classification for *distinct* hosts (paper §5.1): only the
    compute cluster (``node*`` / ``head``) shares a LAN; edge hosts are
    separate sites, so any hop touching an edge host — including between
    two distinct edge hosts (``edge3`` -> ``edge7``) — crosses the MAN."""
    return src_host.startswith("edge") or dst_host.startswith("edge")


@dataclass
class NetworkModel:
    """Node-to-node transit: ``latency(src,dst) + bytes / bandwidth(t)``.

    ``node_of`` maps a task node-name to a host; transits within the same
    host use IPC and are charged ``ipc_latency`` only (paper §3: Sys V IPC
    between Worker and Executors).
    """

    lan_bandwidth_bps: float = 1e9  # 1 Gbps cluster links (paper §5.1)
    man_latency_s: float = 0.005
    lan_latency_s: float = 0.0005
    ipc_latency_s: float = 0.00005
    # time -> bandwidth multiplier (Fig. 9 drops this to 0.03 at t=300).
    bandwidth_schedule: Callable[[float], float] = _default_bandwidth_schedule

    def transit_delay(self, src_host: str, dst_host: str, size_bytes: float, t: float) -> float:
        if src_host == dst_host:
            return self.ipc_latency_s
        bw = self.lan_bandwidth_bps * max(self.bandwidth_schedule(t), 1e-9)
        latency = (
            self.man_latency_s
            if _hop_latency_is_man(src_host, dst_host)
            else self.lan_latency_s
        )
        return latency + size_bytes * 8.0 / bw


class DiscreteEventSimulator(Scheduler):
    """Minimal deterministic discrete-event scheduler."""

    def __init__(
        self,
        network: Optional[NetworkModel] = None,
        transit_cache: Optional[Dict[Tuple[str, str], Tuple[float, bool]]] = None,
    ) -> None:
        self._heap: List[Tuple[float, int, Callable[..., None], tuple]] = []
        self._seq = 0
        self._time = 0.0
        self.network = network or NetworkModel()
        self.host_of: Dict[str, str] = {}
        # Optional (host, t) -> execution-duration multiplier installed by
        # the dynamism plane (ComputeSlowdown).  Tasks consult it when
        # charging *actual* execution time; the runtime's xi(b) estimates
        # (drop decisions, batch deadlines) stay unscaled — a straggler is
        # unannounced and the budget protocol must adapt through signals.
        self._xi_multiplier: Optional[Callable[[str, float], float]] = None
        # Optional fault plane (repro.sim.dynamism.FaultPlane) installed by
        # the scenario before the pipeline is built: host-down / link-blocked
        # predicates + the seeded retry schedule.  Tasks snapshot it at
        # construction (like the xi multiplier), and its presence disables
        # the static-transit fast paths so every send is fault-checked.
        self._faults: Optional[Any] = None
        # (src, dst) -> (fixed latency, charged over the network?).  Host
        # assignment is static once the pipeline is built, so the
        # classification (IPC vs LAN vs MAN) never changes.  A caller may
        # pass a shared table: entries depend only on task naming and the
        # (constant) latency tiers, so scenarios with the same deployment
        # shape can reuse one memoized table (the time-varying bandwidth
        # term is applied outside the cached entry).
        self._transit_cache = transit_cache if transit_cache is not None else {}

    # -- Scheduler protocol -------------------------------------------- #
    @property
    def time(self) -> float:
        return self._time

    def schedule(self, delay: float, fn: Callable[..., None], *args: Any) -> None:
        t = self._time + delay if delay > 0.0 else self._time
        self._seq = seq = self._seq + 1
        heapq.heappush(self._heap, (t, seq, fn, args))

    def schedule_at(self, t: float, fn: Callable[..., None], *args: Any) -> None:
        if t < self._time:
            t = self._time
        self._seq = seq = self._seq + 1
        heapq.heappush(self._heap, (t, seq, fn, args))

    @property
    def transit_is_static(self) -> bool:
        """True when node-to-node delays cannot vary over time, letting tasks
        memoize their per-destination transit delay.  A fault plane makes
        delivery itself conditional (crashed hosts, partitioned links), so
        it forces the dynamic path too."""
        return (
            self.network.bandwidth_schedule is _default_bandwidth_schedule
            and self._faults is None
        )

    @property
    def faults(self) -> Optional[Any]:
        return self._faults

    @faults.setter
    def faults(self, plane: Optional[Any]) -> None:
        # Same contract as xi_multiplier: tasks snapshot the plane at
        # construction, so installing one after the pipeline is built would
        # leave every existing task fault-blind — refuse loudly.
        if plane is not None and self.tasks and self.tasks is not Scheduler.tasks:
            raise RuntimeError(
                "install faults before building tasks on this simulator — "
                "tasks snapshot the fault plane at construction"
            )
        self._faults = plane

    @property
    def xi_is_static(self) -> bool:
        """True when execution durations cannot vary over time (no compute
        perturbation installed), letting the compiler keep its fused
        streaming / fused-FC fast paths."""
        return self._xi_multiplier is None

    @property
    def xi_multiplier(self) -> Optional[Callable[[str, float], float]]:
        return self._xi_multiplier

    @xi_multiplier.setter
    def xi_multiplier(self, fn: Optional[Callable[[str, float], float]]) -> None:
        # Tasks snapshot the multiplier at construction (hot-path: no
        # per-event indirection), so installing one after the pipeline is
        # built would silently scale nothing while xi_is_static flips —
        # refuse loudly instead.
        if fn is not None and self.tasks and self.tasks is not Scheduler.tasks:
            raise RuntimeError(
                "install xi_multiplier before building tasks on this "
                "simulator — tasks snapshot it at construction"
            )
        self._xi_multiplier = fn

    def transit_delay(self, src: str, dst: str, size_bytes: float) -> float:
        ent = self._transit_cache.get((src, dst))
        if ent is None:
            src_host = self.host_of.get(src, src)
            dst_host = self.host_of.get(dst, dst)
            net = self.network
            if src_host == dst_host:
                ent = (net.ipc_latency_s, False)
            else:
                latency = (
                    net.man_latency_s
                    if _hop_latency_is_man(src_host, dst_host)
                    else net.lan_latency_s
                )
                ent = (latency, True)
            self._transit_cache[(src, dst)] = ent
        latency, over_network = ent
        if not over_network:
            return latency
        net = self.network
        schedule = net.bandwidth_schedule
        if schedule is _default_bandwidth_schedule:
            bw = net.lan_bandwidth_bps
        else:
            bw = net.lan_bandwidth_bps * max(schedule(self._time), 1e-9)
        return latency + size_bytes * 8.0 / bw

    # -- Run loop -------------------------------------------------------- #
    def run(self, until: float = math.inf, max_events: int = 50_000_000) -> int:
        """Process events until the horizon; returns number processed."""
        n = 0
        heap = self._heap
        pop = heapq.heappop
        while heap and n < max_events:
            item = heap[0]
            if item[0] > until:
                break
            pop(heap)
            self._time = item[0]
            item[2](*item[3])
            n += 1
        self._time = max(self._time, min(until, self._time if not heap else until))
        return n
