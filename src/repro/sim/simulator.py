"""Discrete-event engine + WAN/MAN network model (paper §5.1 system setup).

The engine drives the :mod:`repro.core.pipeline` tasks: a heap of
``(time, seq, fn)`` callbacks.  The network model charges
``latency + size/bandwidth`` per transit between nodes; the bandwidth is a
function of time so the paper's Fig. 9 experiment (1 Gbps -> 30 Mbps midway)
is expressible.
"""

from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.pipeline import Scheduler

__all__ = ["NetworkModel", "DiscreteEventSimulator"]


@dataclass
class NetworkModel:
    """Node-to-node transit: ``latency(src,dst) + bytes / bandwidth(t)``.

    ``node_of`` maps a task node-name to a host; transits within the same
    host use IPC and are charged ``ipc_latency`` only (paper §3: Sys V IPC
    between Worker and Executors).
    """

    lan_bandwidth_bps: float = 1e9  # 1 Gbps cluster links (paper §5.1)
    man_latency_s: float = 0.005
    lan_latency_s: float = 0.0005
    ipc_latency_s: float = 0.00005
    # time -> bandwidth multiplier (Fig. 9 drops this to 0.03 at t=300).
    bandwidth_schedule: Callable[[float], float] = lambda t: 1.0

    def transit_delay(self, src_host: str, dst_host: str, size_bytes: float, t: float) -> float:
        if src_host == dst_host:
            return self.ipc_latency_s
        bw = self.lan_bandwidth_bps * max(self.bandwidth_schedule(t), 1e-9)
        latency = (
            self.man_latency_s
            if src_host.startswith("edge") != dst_host.startswith("edge")
            else self.lan_latency_s
        )
        return latency + size_bytes * 8.0 / bw


class DiscreteEventSimulator(Scheduler):
    """Minimal deterministic discrete-event scheduler."""

    def __init__(self, network: Optional[NetworkModel] = None) -> None:
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._time = 0.0
        self.network = network or NetworkModel()
        self.host_of: Dict[str, str] = {}

    # -- Scheduler protocol -------------------------------------------- #
    @property
    def time(self) -> float:
        return self._time

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (self._time + max(delay, 0.0), next(self._seq), fn))

    def schedule_at(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (max(t, self._time), next(self._seq), fn))

    def transit_delay(self, src: str, dst: str, size_bytes: float) -> float:
        src_host = self.host_of.get(src, src)
        dst_host = self.host_of.get(dst, dst)
        return self.network.transit_delay(src_host, dst_host, size_bytes, self._time)

    # -- Run loop -------------------------------------------------------- #
    def run(self, until: float = math.inf, max_events: int = 50_000_000) -> int:
        """Process events until the horizon; returns number processed."""
        n = 0
        while self._heap and n < max_events:
            t, _, fn = self._heap[0]
            if t > until:
                break
            heapq.heappop(self._heap)
            self._time = t
            fn()
            n += 1
        self._time = max(self._time, min(until, self._time if not self._heap else until))
        return n
