"""Discrete-event simulation of the many-camera network (paper §5 setup)."""

from .cameras import CameraNetwork, EntityWalk, Frame
from .dynamism import (
    BandwidthCollapse,
    CameraChurn,
    ComputeSlowdown,
    DynamismSpec,
    DynamismTrace,
    FaultPlane,
    HostCrash,
    InputRateSpike,
    NetworkPartition,
    RetryPolicy,
    fig9_collapse,
)
from .scenario import (
    ScenarioConfig,
    ScenarioResult,
    TrackingScenario,
    linear_xi,
    make_scenario_cr,
    va_passthrough,
)
from .simulator import DiscreteEventSimulator, NetworkModel
from .sweep import AppCase, CaseRecord, QueryCase, SweepResult, SweepRunner
from .world import WorldBundle, WorldKey, clear_world_cache, get_world, world_cache_stats

# Multi-query tenancy plane: repro.query layers on this package's scenario
# driver, so its names are re-exported lazily (PEP 562) — an eager import
# here would be circular (repro.query.scenario imports repro.sim.scenario,
# which initializes this package first).
_QUERY_EXPORTS = (
    "AdmissionController",
    "AdmissionPolicy",
    "MultiQueryResult",
    "MultiQueryScenario",
    "QueryRegistry",
    "QuerySpec",
    "run_queries_serial",
)


def __getattr__(name):
    if name in _QUERY_EXPORTS:
        from repro import query

        return getattr(query, name)
    raise AttributeError(f"module 'repro.sim' has no attribute {name!r}")


__all__ = [
    "AdmissionController", "AdmissionPolicy", "AppCase", "BandwidthCollapse",
    "CameraChurn", "CameraNetwork", "CaseRecord", "ComputeSlowdown",
    "DiscreteEventSimulator", "DynamismSpec", "DynamismTrace", "EntityWalk",
    "FaultPlane", "Frame", "HostCrash", "InputRateSpike", "MultiQueryResult",
    "MultiQueryScenario", "NetworkModel", "NetworkPartition", "QueryCase",
    "QueryRegistry", "QuerySpec", "RetryPolicy",
    "ScenarioConfig", "ScenarioResult", "SweepResult", "SweepRunner",
    "TrackingScenario", "WorldBundle", "WorldKey", "clear_world_cache",
    "fig9_collapse", "get_world", "linear_xi", "make_scenario_cr",
    "run_queries_serial", "va_passthrough", "world_cache_stats",
]
