"""Discrete-event simulation of the many-camera network (paper §5 setup)."""

from .cameras import CameraNetwork, EntityWalk, Frame
from .dynamism import (
    BandwidthCollapse,
    CameraChurn,
    ComputeSlowdown,
    DynamismSpec,
    DynamismTrace,
    InputRateSpike,
    fig9_collapse,
)
from .scenario import (
    ScenarioConfig,
    ScenarioResult,
    TrackingScenario,
    linear_xi,
    make_scenario_cr,
    va_passthrough,
)
from .simulator import DiscreteEventSimulator, NetworkModel
from .sweep import AppCase, CaseRecord, SweepResult, SweepRunner
from .world import WorldBundle, WorldKey, clear_world_cache, get_world, world_cache_stats

__all__ = [
    "AppCase", "BandwidthCollapse", "CameraChurn", "CameraNetwork",
    "CaseRecord", "ComputeSlowdown", "DiscreteEventSimulator", "DynamismSpec",
    "DynamismTrace", "EntityWalk", "Frame", "InputRateSpike", "NetworkModel",
    "ScenarioConfig", "ScenarioResult", "SweepResult", "SweepRunner",
    "TrackingScenario", "WorldBundle", "WorldKey", "clear_world_cache",
    "fig9_collapse", "get_world", "linear_xi", "make_scenario_cr",
    "va_passthrough", "world_cache_stats",
]
