"""Discrete-event simulation of the many-camera network (paper §5 setup)."""

from .cameras import CameraNetwork, EntityWalk, Frame
from .scenario import ScenarioConfig, ScenarioResult, TrackingScenario, linear_xi
from .simulator import DiscreteEventSimulator, NetworkModel

__all__ = [
    "CameraNetwork", "DiscreteEventSimulator", "EntityWalk", "Frame",
    "NetworkModel", "ScenarioConfig", "ScenarioResult", "TrackingScenario",
    "linear_xi",
]
