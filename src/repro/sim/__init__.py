"""Discrete-event simulation of the many-camera network (paper §5 setup)."""

from .cameras import CameraNetwork, EntityWalk, Frame
from .scenario import (
    ScenarioConfig,
    ScenarioResult,
    TrackingScenario,
    linear_xi,
    make_scenario_cr,
    va_passthrough,
)
from .simulator import DiscreteEventSimulator, NetworkModel
from .sweep import AppCase, CaseRecord, SweepResult, SweepRunner
from .world import WorldBundle, WorldKey, clear_world_cache, get_world, world_cache_stats

__all__ = [
    "AppCase", "CameraNetwork", "CaseRecord", "DiscreteEventSimulator",
    "EntityWalk", "Frame", "NetworkModel", "ScenarioConfig",
    "ScenarioResult", "SweepResult", "SweepRunner", "TrackingScenario",
    "WorldBundle", "WorldKey", "clear_world_cache", "get_world", "linear_xi",
    "make_scenario_cr", "va_passthrough", "world_cache_stats",
]
