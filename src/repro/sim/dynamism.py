"""Dynamism experiment plane: composable perturbation schedules + telemetry
(paper §4.3–§4.5, Figs. 7/9 — *responsiveness to dynamism*).

The paper's central claim is not raw throughput but adaptation: tunable
batching and dropping that trade tracking accuracy, real-time latency and
active-camera-set size as conditions vary.  This module makes each source
of variability a first-class, seeded, composable perturbation that attaches
to any ``ScenarioConfig`` (and therefore any ``AppCase``):

* :class:`BandwidthCollapse` — the Fig. 9 experiment (1 Gbps -> 30 Mbps at
  t = 300 s), generalized to a window ``[t_start, t_end)`` and any factor.
* :class:`ComputeSlowdown` — per-host straggler multipliers applied to the
  *actual* execution duration inside the discrete-event engine.  The
  runtime's cost model ``xi(b)`` is deliberately **not** scaled: a straggler
  is unannounced, so drop decisions and batch deadlines keep using the stale
  estimate and the budget protocol has to adapt through accept/reject
  signals — exactly the behavior under test (cf. DeepScale's online
  adaptation to compute variability).
* :class:`InputRateSpike` — frame-rate multiplier at the FC sources over a
  window (flash-crowd input).
* :class:`CameraChurn` — seeded periodic dropout of active cameras (sensing
  churn: a camera the TL wants goes dark for ``outage_s``).

A :class:`DynamismSpec` composes any number of perturbations (multipliers
multiply where they overlap) and additionally switches on the observation
side of the experiment:

* **telemetry** — per-task time series sampled on a fixed cadence into a
  :class:`DynamismTrace` (budget ``beta_i``, queue length, batch sizes,
  the three drop-point counters, probe/accept/reject counts, active-camera
  count).  Sampling walks the compiled tasks once per cadence, entirely off
  the per-event hot path; with no spec attached the scenario schedules
  nothing and the pipeline pays nothing.
* **quality** — ground-truth tracking metrics against the entity walk:
  track recall/precision over (camera, tick) visibility pairs, plus the
  latency percentiles and drop fractions the summary already carries.

Everything is deterministic in (config seed, spec): perturbation windows are
pure functions of time and the churn RNG is seeded, so a dynamism run is as
replayable as any other scenario — the golden-trace regression test freezes
a full :meth:`DynamismTrace.digest` and asserts bit-identical replay.
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.pipeline import STAT_FIELDS

__all__ = [
    "BandwidthCollapse",
    "ComputeSlowdown",
    "InputRateSpike",
    "CameraChurn",
    "HostCrash",
    "NetworkPartition",
    "RetryPolicy",
    "FaultPlane",
    "DynamismSpec",
    "DynamismTrace",
    "fig9_collapse",
]

#: Fields sampled per task on every telemetry tick.  ``beta`` is the task's
#: most conservative completion budget; ``queue`` the events pending in the
#: batcher + run queue; the rest are the cumulative counters of
#: :data:`repro.core.pipeline.STAT_FIELDS` (defined next to PipelineStats
#: so the per-task, aggregate and serving rows share one mapping).
TRACE_FIELDS = ("beta", "queue") + tuple(f for f, _ in STAT_FIELDS)


def _queue_depth(task) -> int:
    return sum(len(b) for b in task._run_queue) + task.batcher.current_size


# --------------------------------------------------------------------- #
# Perturbations                                                          #
# --------------------------------------------------------------------- #
def _in_window(t: float, t_start: float, t_end: float) -> bool:
    return t_start <= t < t_end


@dataclass(frozen=True)
class BandwidthCollapse:
    """Network bandwidth multiplied by ``factor`` over ``[t_start, t_end)``.

    ``factor=0.03`` with an open end reproduces Fig. 9 verbatim; the
    dynamism benchmarks use a transient window so budget *recovery* after
    the collapse is measurable.
    """

    t_start: float = 300.0
    t_end: float = math.inf
    factor: float = 0.03

    def __post_init__(self) -> None:
        if not self.factor > 0.0:
            raise ValueError(f"factor must be > 0, got {self.factor!r}")

    def bandwidth_multiplier(self, t: float) -> float:
        return self.factor if _in_window(t, self.t_start, self.t_end) else 1.0

    def window(self) -> Tuple[float, float]:
        return (self.t_start, self.t_end)


@dataclass(frozen=True)
class ComputeSlowdown:
    """Execution durations on matching hosts multiplied by ``factor`` over
    ``[t_start, t_end)``.  ``hosts=None`` slows every host; otherwise any
    host whose name starts with one of the given prefixes (``("node0",)``
    makes one straggler; ``("node",)`` slows the whole compute tier)."""

    t_start: float = 300.0
    t_end: float = math.inf
    factor: float = 4.0
    hosts: Optional[Tuple[str, ...]] = None

    def __post_init__(self) -> None:
        if not self.factor > 0.0:
            raise ValueError(f"factor must be > 0, got {self.factor!r}")

    def xi_multiplier(self, host: str, t: float) -> float:
        if not _in_window(t, self.t_start, self.t_end):
            return 1.0
        if self.hosts is not None and not host.startswith(self.hosts):
            return 1.0
        return self.factor

    def window(self) -> Tuple[float, float]:
        return (self.t_start, self.t_end)


@dataclass(frozen=True)
class InputRateSpike:
    """Source frame rate multiplied by ``factor`` over ``[t_start, t_end)``
    (the FC sources tick faster, raising the input rate everywhere)."""

    t_start: float = 300.0
    t_end: float = math.inf
    factor: float = 2.0

    def __post_init__(self) -> None:
        # A zero/negative rate would stall or reverse the source clock;
        # model an outage with CameraChurn (or a tiny positive factor).
        if not self.factor > 0.0:
            raise ValueError(f"factor must be > 0, got {self.factor!r}")

    def rate_multiplier(self, t: float) -> float:
        return self.factor if _in_window(t, self.t_start, self.t_end) else 1.0

    def window(self) -> Tuple[float, float]:
        return (self.t_start, self.t_end)


@dataclass(frozen=True)
class CameraChurn:
    """Every ``period_s`` inside ``[t_start, t_end)``, a seeded ``fraction``
    of the TL's currently-requested cameras goes dark for ``outage_s``
    (restored afterwards only if the TL still wants them)."""

    period_s: float = 10.0
    fraction: float = 0.25
    outage_s: float = 5.0
    t_start: float = 0.0
    t_end: float = math.inf
    seed: int = 0

    def __post_init__(self) -> None:
        if not self.period_s > 0.0:
            raise ValueError(f"period_s must be > 0, got {self.period_s!r}")
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(f"fraction must be in [0, 1], got {self.fraction!r}")
        if self.outage_s < 0.0:
            raise ValueError(f"outage_s must be >= 0, got {self.outage_s!r}")

    def window(self) -> Tuple[float, float]:
        return (self.t_start, self.t_end)


@dataclass(frozen=True)
class HostCrash:
    """Hosts matching ``hosts`` die over ``[t_start, t_start + outage_s)``
    and restart afterwards (fail-recover, WatchDog-style edge failures).

    While a host is down it accepts no deliveries: its queued and batching
    events are lost at crash onset (the scenario flushes them through the
    ``dp_fault`` drop class), outputs of an execution finishing during the
    outage are lost, and inter-host sends targeting it time out and retry
    with seeded backoff (see :class:`RetryPolicy`) — surviving if the host
    restarts within the retry horizon, charged as ``dp_fault`` otherwise.
    Prefix-matched like :class:`ComputeSlowdown`: ``("node0",)`` kills one
    compute node, ``("edge",)`` the whole edge tier.
    """

    hosts: Tuple[str, ...] = ("node0",)
    t_start: float = 300.0
    outage_s: float = 30.0

    def __post_init__(self) -> None:
        if not self.hosts:
            raise ValueError("hosts must name at least one host prefix")
        if not self.outage_s > 0.0:
            raise ValueError(f"outage_s must be > 0, got {self.outage_s!r}")

    def host_down(self, host: str, t: float) -> bool:
        return host.startswith(self.hosts) and _in_window(
            t, self.t_start, self.t_start + self.outage_s
        )

    def matches(self, host: str) -> bool:
        return host.startswith(self.hosts)

    def window(self) -> Tuple[float, float]:
        return (self.t_start, self.t_start + self.outage_s)


@dataclass(frozen=True)
class NetworkPartition:
    """LAN/MAN transits *between* the two host groups fail over
    ``[t_start, t_end)`` (both directions); transits within a group — and
    same-host IPC — are unaffected.  The default splits the compute cluster
    from the edge tier, the paper's wide-area failure mode."""

    group_a: Tuple[str, ...] = ("node", "head")
    group_b: Tuple[str, ...] = ("edge",)
    t_start: float = 300.0
    t_end: float = math.inf

    def __post_init__(self) -> None:
        if not self.group_a or not self.group_b:
            raise ValueError("both partition groups must be non-empty")
        if not self.t_end > self.t_start:
            raise ValueError(
                f"t_end must be > t_start, got [{self.t_start!r}, {self.t_end!r})"
            )

    def link_blocked(self, src_host: str, dst_host: str, t: float) -> bool:
        if src_host == dst_host or not _in_window(t, self.t_start, self.t_end):
            return False
        a, b = self.group_a, self.group_b
        return (src_host.startswith(a) and dst_host.startswith(b)) or (
            src_host.startswith(b) and dst_host.startswith(a)
        )

    def window(self) -> Tuple[float, float]:
        return (self.t_start, self.t_end)


@dataclass(frozen=True)
class RetryPolicy:
    """Inter-host send timeout + capped exponential backoff with seeded
    jitter.  Attempt ``k`` (0-based) that finds the link/host down waits
    ``timeout_s + min(cap_s, base_s * 2**k) * (1 + jitter * u)`` with
    ``u ~ U[0, 1)`` from the fault plane's seeded RNG, then retries; after
    ``max_retries`` failed attempts the event is charged as ``dp_fault``."""

    timeout_s: float = 0.05
    base_s: float = 0.1
    cap_s: float = 2.0
    jitter: float = 0.5
    max_retries: int = 5

    def __post_init__(self) -> None:
        if self.timeout_s < 0.0 or self.base_s <= 0.0 or self.cap_s <= 0.0:
            raise ValueError("timeout_s must be >= 0 and backoff terms > 0")
        if not 0.0 <= self.jitter:
            raise ValueError(f"jitter must be >= 0, got {self.jitter!r}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries!r}")


class FaultPlane:
    """Runtime fault state the engine and tasks consult: the composed
    host-down / link-blocked predicates of a spec's :class:`HostCrash` /
    :class:`NetworkPartition` perturbations plus the seeded retry schedule.

    Installed on the simulator (``sim.faults``) *before* the pipeline is
    built — tasks snapshot it at construction, like ``xi_multiplier`` — and
    its presence makes ``transit_is_static`` False, so every transit goes
    through the fault-aware send path (no fused/memoized shortcuts).

    Everything is deterministic in (spec, seed): the windows are pure
    functions of time and the jitter RNG is seeded and consumed in event
    order, so a faulted run replays bit-identically.
    """

    def __init__(
        self,
        crashes: Sequence[HostCrash],
        partitions: Sequence[NetworkPartition],
        retry: Optional[RetryPolicy] = None,
        seed: int = 0,
    ) -> None:
        import numpy as np

        self.crashes: Tuple[HostCrash, ...] = tuple(crashes)
        self.partitions: Tuple[NetworkPartition, ...] = tuple(partitions)
        self.retry = retry or RetryPolicy()
        self._rng = np.random.default_rng(seed + 0x5EED)
        # Fault-plane counters (cold path: only blocked sends touch them).
        self.sends_blocked = 0
        self.retries = 0
        self.fault_drops = 0

    # -- predicates ------------------------------------------------------ #
    def host_down(self, host: str, t: float) -> bool:
        for c in self.crashes:
            if c.host_down(host, t):
                return True
        return False

    def link_blocked(self, src_host: str, dst_host: str, t: float) -> bool:
        for p in self.partitions:
            if p.link_blocked(src_host, dst_host, t):
                return True
        return False

    def send_blocked(self, src_host: str, dst_host: str, t: float) -> bool:
        """Would a send attempted now fail?  (Destination dead, or the
        inter-group link partitioned — the *source* being dead is handled
        separately: a dead sender's outputs are lost, not retried.)"""
        return self.host_down(dst_host, t) or self.link_blocked(
            src_host, dst_host, t
        )

    def partition_active(self, t: float) -> bool:
        for p in self.partitions:
            s, e = p.window()
            if s <= t < e:
                return True
        return False

    # -- retry schedule -------------------------------------------------- #
    def retry_delay(self, attempt: int) -> float:
        r = self.retry
        backoff = min(r.cap_s, r.base_s * (2.0 ** attempt))
        return r.timeout_s + backoff * (1.0 + r.jitter * float(self._rng.uniform()))


# --------------------------------------------------------------------- #
# The composed spec                                                      #
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class DynamismSpec:
    """A bundle of perturbations + the observation cadence.

    Attach via ``ScenarioConfig(dynamism=DynamismSpec((...)))``; the
    scenario composes the perturbations onto the network model, the
    discrete-event engine and the source plane, schedules the telemetry
    tick, and returns the :class:`DynamismTrace` on its ``ScenarioResult``.
    """

    perturbations: Tuple = ()
    #: Telemetry sampling cadence in seconds; 0 disables the trace.
    telemetry_period_s: float = 5.0
    #: Compute ground-truth track recall/precision (costs one vectorized
    #: FOV test over *all* cameras per source tick — off by default only
    #: when you need raw engine throughput).
    quality: bool = True
    #: Retry schedule for inter-host sends while a fault perturbation holds
    #: (only consulted when the spec carries HostCrash/NetworkPartition;
    #: None uses the RetryPolicy defaults).
    retry: Optional[RetryPolicy] = None

    # -- composition ---------------------------------------------------- #
    def _with(self, method: str) -> List:
        return [p for p in self.perturbations if hasattr(p, method)]

    def bandwidth_schedule(
        self, base: Optional[Callable[[float], float]] = None
    ) -> Optional[Callable[[float], float]]:
        """Composed ``t -> bandwidth multiplier`` (product with ``base``);
        None when neither the spec nor ``base`` varies the bandwidth."""
        ps = self._with("bandwidth_multiplier")
        if not ps and base is None:
            return None
        if not ps:
            return base

        def schedule(t: float) -> float:
            m = base(t) if base is not None else 1.0
            for p in ps:
                m *= p.bandwidth_multiplier(t)
            return m

        return schedule

    def xi_multiplier(self) -> Optional[Callable[[str, float], float]]:
        """Composed ``(host, t) -> execution-duration multiplier``, or None
        when no compute perturbation is present (the hot path then keeps its
        static-xi fast paths — fusion, memoized transits)."""
        ps = self._with("xi_multiplier")
        if not ps:
            return None

        def mult(host: str, t: float) -> float:
            m = 1.0
            for p in ps:
                m *= p.xi_multiplier(host, t)
            return m

        return mult

    def rate_multiplier(self) -> Optional[Callable[[float], float]]:
        ps = self._with("rate_multiplier")
        if not ps:
            return None

        def mult(t: float) -> float:
            m = 1.0
            for p in ps:
                m *= p.rate_multiplier(t)
            return m

        return mult

    def churns(self) -> Tuple[CameraChurn, ...]:
        return tuple(p for p in self.perturbations if isinstance(p, CameraChurn))

    def crashes(self) -> Tuple[HostCrash, ...]:
        return tuple(p for p in self.perturbations if isinstance(p, HostCrash))

    def partitions(self) -> Tuple[NetworkPartition, ...]:
        return tuple(
            p for p in self.perturbations if isinstance(p, NetworkPartition)
        )

    def fault_plane(self, seed: int = 0) -> Optional[FaultPlane]:
        """The composed runtime :class:`FaultPlane`, or None when the spec
        carries no fault perturbation (the hot path then keeps every
        fused/memoized fast path)."""
        crashes, partitions = self.crashes(), self.partitions()
        if not crashes and not partitions:
            return None
        return FaultPlane(crashes, partitions, retry=self.retry, seed=seed)

    def windows(self) -> List[Tuple[float, float]]:
        """Perturbation windows, sorted by start (used by the recovery
        metric to split pre / during / post samples)."""
        return sorted(p.window() for p in self.perturbations if hasattr(p, "window"))


def fig9_collapse(
    t_start: float = 300.0, t_end: float = math.inf, factor: float = 0.03
) -> DynamismSpec:
    """The Fig.-9 bandwidth experiment as a spec (telemetry + quality on)."""
    return DynamismSpec((BandwidthCollapse(t_start, t_end, factor),))


# --------------------------------------------------------------------- #
# Telemetry trace                                                        #
# --------------------------------------------------------------------- #
@dataclass
class DynamismTrace:
    """Per-task time series sampled on a fixed cadence, plus the quality
    metrics computed against the ground-truth entity walk.

    ``series`` maps task name -> field -> samples; field names are
    :data:`TRACE_FIELDS`.  ``FC*`` is the aggregate over the (lazy) FC
    tasks.  Everything here is plain floats/ints so traces pickle through
    fork sweep workers and digest deterministically.
    """

    spec: DynamismSpec
    period_s: float
    times: List[float] = field(default_factory=list)
    active_cameras: List[int] = field(default_factory=list)
    series: Dict[str, Dict[str, List[float]]] = field(default_factory=dict)
    quality: Optional[Dict[str, float]] = None

    # -- recording (called by the scenario's telemetry tick) ------------- #
    def task_row(self, name: str) -> Dict[str, List[float]]:
        row = self.series.get(name)
        if row is None:
            row = self.series[name] = {f: [] for f in TRACE_FIELDS}
        return row

    def sample_task(self, task) -> None:
        """Append one sample for a pipeline Task (allocation-lean: appends
        onto preallocated lists, no per-sample objects)."""
        row = self.task_row(task.name)
        stats = task.stats
        row["beta"].append(task.budget.min_budget())
        row["queue"].append(_queue_depth(task))
        for fld, attr in STAT_FIELDS:
            row[fld].append(getattr(stats, attr))

    def sample_keyed(self, name: str, values: Dict[str, float]) -> None:
        """Append one sample for a *keyed* row that is not backed by a
        pipeline Task — the multi-query tenancy plane records one row per
        tracking query (``Q:<id>``) this way, with the same
        :data:`TRACE_FIELDS` shape as every task row.  Rows created
        mid-trace (queries submitted after sampling started) are backfilled
        (``beta`` with ``inf`` — no budget yet — and counters with 0) so
        every row stays aligned with ``times``."""
        row = self.task_row(name)
        n = len(self.times) - 1  # samples recorded before this one
        for f in TRACE_FIELDS:
            col = row[f]
            fill = math.inf if f == "beta" else 0.0
            if len(col) < n:
                col.extend([fill] * (n - len(col)))
            col.append(float(values.get(f, fill)))

    def sample_aggregate(self, name, tasks) -> None:
        """Append one sample aggregating ``tasks`` under one row ``name``
        (min budget, summed queue depths and counters) — used for the lazy
        per-camera FC plane, where a per-task series would be 10k columns."""
        tasks = list(tasks)
        row = self.task_row(name)
        row["beta"].append(
            min((t.budget.min_budget() for t in tasks), default=math.inf)
        )
        row["queue"].append(sum(_queue_depth(t) for t in tasks))
        for fld, attr in STAT_FIELDS:
            row[fld].append(sum(getattr(t.stats, attr) for t in tasks))

    # -- analysis -------------------------------------------------------- #
    def tasks(self, prefix: str = "") -> List[str]:
        return sorted(n for n in self.series if n.startswith(prefix))

    def min_beta(self, prefix: str = "CR") -> List[float]:
        """Min over the matching tasks' budgets at each sample time."""
        names = self.tasks(prefix)
        if not names:
            return []
        cols = [self.series[n]["beta"] for n in names]
        return [min(c[i] for c in cols) for i in range(len(self.times))]

    def mean_batch(self, prefix: str = "CR") -> List[float]:
        """Mean batch size within each sampling interval (executed/batches
        deltas over the matching tasks)."""
        names = self.tasks(prefix)
        out: List[float] = []
        prev_e = prev_b = 0.0
        for i in range(len(self.times)):
            e = sum(self.series[n]["executed"][i] for n in names)
            b = sum(self.series[n]["batches"][i] for n in names)
            de, db = e - prev_e, b - prev_b
            out.append(de / db if db else 0.0)
            prev_e, prev_b = e, b
        return out

    def dropped_total(self, task: str) -> int:
        row = self.series[task]
        if not row["dp1"]:
            return 0
        return int(row["dp1"][-1] + row["dp2"][-1] + row["dp3"][-1])

    def _total_drops_at(self, i: int) -> int:
        return int(
            sum(
                row["dp1"][i] + row["dp2"][i] + row["dp3"][i]
                for row in self.series.values()
            )
        )

    def dropped_between(self, t0: float, t1: float) -> int:
        """Drops (all tasks, all drop points) accumulated between the last
        samples at or before ``t0`` and ``t1`` — the drop *wave* a
        perturbation window causes, as opposed to the run totals."""

        def idx_at_or_before(t: float) -> int:
            k = -1
            for i, ts in enumerate(self.times):
                if ts <= t:
                    k = i
                else:
                    break
            return k

        a = idx_at_or_before(t0)
        b = idx_at_or_before(t1)
        start = self._total_drops_at(a) if a >= 0 else 0
        end = self._total_drops_at(b) if b >= 0 else 0
        return end - start

    def budget_recovery(
        self, prefix: str = "CR", until: Optional[float] = None
    ) -> Dict[str, float]:
        """Budget trajectory around the spec's perturbation windows, over
        the min-budget series of the ``prefix`` module.

        ``pre`` is the last finite sample before the first window opens;
        ``dip`` the lowest sample from the window opening to the end of the
        trace (budget damage lags the window via signal round trips);
        ``low`` the trace-wide minimum (a bootstrap-era collapse, §4.5,
        shows up here even when it predates the window); ``post`` the final
        sample; ``recovery = post / pre`` (nan without a finite pre).  The
        acceptance bar for an adaptive batcher is ``recovery >= 0.9``
        (§4.5.2: probes + accepts re-inflate a collapsed budget).

        ``until`` bounds the series: samples after it are ignored, so
        ``post`` becomes the last finite sample at or before ``until``.
        The multi-query admission benchmark passes the generation horizon
        here — once sourcing stops, the drain window always re-inflates
        budgets, which would mask "still overloaded while serving".

        Caveat: drops upstream of ``prefix`` shield it — a bandwidth
        collapse whose late events die at the VA drop points leaves the CR
        series flat.  Check where the wave landed with
        :meth:`dropped_between` / the per-task ``dp*`` columns before
        reading a flat series as "unaffected".
        """
        windows = self.windows_or_default()
        t0 = min(w[0] for w in windows)
        beta = self.min_beta(prefix)
        pre = dip = low = post = math.nan
        for t, b in zip(self.times, beta):
            if until is not None and t > until:
                break
            if math.isinf(b):
                continue
            low = b if math.isnan(low) else min(low, b)
            if t < t0:
                pre = b
            else:
                dip = b if math.isnan(dip) else min(dip, b)
            post = b
        recovery = post / pre if pre and not math.isnan(pre) else math.nan
        return {"pre": pre, "dip": dip, "low": low, "post": post, "recovery": recovery}

    def windows_or_default(self) -> List[Tuple[float, float]]:
        windows = self.spec.windows()
        if not windows:
            windows = [(0.0, 0.0)]
        # An open-ended window "ends" at the last sample for analysis.
        last = self.times[-1] if self.times else 0.0
        return [(s, e if not math.isinf(e) else last) for s, e in windows]

    def digest(self) -> str:
        """Deterministic fingerprint of the whole trace (times, active-set
        series, every per-task series, quality metrics).  Floats are
        round-tripped through ``repr`` so equal traces hash equal and any
        single-sample drift changes the digest — the golden-trace test
        freezes this value."""
        h = hashlib.sha256()
        h.update(repr(self.times).encode())
        h.update(repr(self.active_cameras).encode())
        for name in sorted(self.series):
            row = self.series[name]
            h.update(name.encode())
            for f in TRACE_FIELDS:
                h.update(repr(row[f]).encode())
        if self.quality is not None:
            h.update(repr(sorted(self.quality.items())).encode())
        return h.hexdigest()

    def summary(self) -> Dict[str, float]:
        """Compact, picklable view for benchmark records and sweep rows."""
        out: Dict[str, float] = {"samples": len(self.times)}
        if self.times:
            rec = self.budget_recovery("CR")
            # Keys with no data (nan — e.g. drops-off runs never initialize
            # a budget) are omitted rather than emitted as nan/None: nan
            # breaks dict equality (frozen-summary tests), None breaks
            # float() parsers downstream.
            for key, val in (
                ("beta_pre", rec["pre"]),
                ("beta_low", rec["low"]),
                ("beta_post", rec["post"]),
                ("beta_recovery", rec["recovery"]),
            ):
                if not math.isnan(val):
                    out[key] = round(val, 4)
            out.update(
                # Task rows only: a per-query ``Q:<id>`` row's "queue" is
                # that query's whole-pipeline in-flight count, not a task
                # queue depth — it would dominate the max and misreport
                # pipeline queue pressure in multi-query runs.
                peak_queue=max(
                    (
                        max(row["queue"])
                        for name, row in self.series.items()
                        if not name.startswith("Q:")
                    ),
                    default=0,
                ),
                probes=sum(
                    int(row["probes"][-1]) for row in self.series.values() if row["probes"]
                ),
            )
        if self.quality is not None:
            out.update(self.quality)
        return out
