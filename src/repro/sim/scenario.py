"""End-to-end tracking scenario (paper §5 experiments).

Wires the full Anveshak dataflow over the discrete-event engine:

    cameras --frames--> FC (one per camera, edge hosts)
      --> VA instances (hash by camera) --> CR instances --> UV sink
    UV --detections--> TL --(de)activate--> FC states      (feedback)

Execution times are charged through each task's ``xi(b)`` cost model
(calibrated to the paper: CR ~120 ms/event streaming for App 1, ~63% more
for App 2), network transits through :class:`NetworkModel`, and all of the
paper's knobs are exposed: batching strategy, drops on/off, TL strategy,
entity peak speed ``es``, bandwidth schedule, clock skews.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.batching import DynamicBatcher, NOBBatcher, StaticBatcher
from repro.core.budget import TaskBudget
from repro.core.clock import Clock
from repro.core.events import Event, EventHeader, new_event_id, source_header
from repro.core.pipeline import SinkTask, Task
from repro.core.tracking import (
    Detection,
    TLBFS,
    TLBase,
    TLProbabilistic,
    TLWBFS,
    TrackingLogic,
)
from .cameras import CameraNetwork, Frame
from .simulator import DiscreteEventSimulator, NetworkModel
from .world import WorldBundle, WorldKey, get_world

__all__ = ["ScenarioConfig", "ScenarioResult", "TrackingScenario", "linear_xi"]


def _constant_partitioner(name: str) -> Callable:
    def partition(ev) -> str:
        return name

    return partition


def _table_partitioner(table: Dict) -> Callable:
    def partition(ev) -> str:
        return table[ev.key]

    return partition


def linear_xi(c0: float, c1: float) -> Callable[[int], float]:
    """Affine batch cost model ``xi(b) = c0 + c1 * b`` (monotone, amortizes
    the fixed model-invocation overhead — paper §2.2.2)."""

    def xi(b: int) -> float:
        return c0 + c1 * max(int(b), 0)

    return xi


@dataclass
class ScenarioConfig:
    # Workload (paper §5.1)
    num_cameras: int = 1000
    duration_s: float = 600.0
    fps: float = 1.0
    entity_speed_mps: float = 1.0
    fov_radius_m: float = 6.0
    seed: int = 0
    # Road-network size.  None keeps the paper's 1000-vertex/2817-edge OSM
    # statistics (and grows the graph proportionally once ``num_cameras``
    # exceeds the vertex count, so 5k/10k-camera sweeps have a vertex per
    # camera).
    road_vertices: Optional[int] = None
    # QoS
    gamma: float = 15.0
    epsilon_max: float = 1.0
    # Tracking logic knob
    tl: str = "bfs"  # base | bfs | wbfs | prob
    tl_peak_speed: float = 4.0  # es (m/s)
    tl_update_period: float = 1.0
    tl_min_radius_m: float = 0.0
    # Batching knob
    batching: str = "dynamic"  # dynamic | static | nob
    static_batch: int = 1
    m_max: int = 25
    # Dropping knob
    drops_enabled: bool = False
    avoid_drop_positives: bool = False
    # Deployment (paper: 10 VA + 10 CR on 10 compute nodes)
    num_va: int = 10
    num_cr: int = 10
    num_nodes: int = 10
    # Cost models: (c0, c1) of xi(b) = c0 + c1 b, seconds.
    fc_cost: Tuple[float, float] = (0.0002, 0.0008)
    va_cost: Tuple[float, float] = (0.020, 0.010)
    # CR streaming cost xi(1) = 0.067 + 0.053 = 120 ms/event (App 1, §5.2.1);
    # batched capacity ~19 events/s (§5.2.3).
    cr_cost: Tuple[float, float] = (0.067, 0.053)
    # Detection model
    p_true_positive: float = 0.9
    # Network dynamics (Fig. 9): t -> bandwidth multiplier.
    bandwidth_schedule: Optional[Callable[[float], float]] = None
    # Clock skew per compute node (§4.6.2); source/sink stay at skew 0.
    node_clock_skews: Optional[Sequence[float]] = None
    # Shared immutable world (road + walk + cameras + transit tables).  When
    # None the scenario fetches it from the process-wide world cache; sweep
    # runners attach a prebuilt bundle so concurrent configs share one build.
    world: Optional[WorldBundle] = field(default=None, repr=False, compare=False)
    # Frame embeddings: 0 keeps the synthetic boolean frames; > 0 attaches a
    # per-frame embedding so VA runs the batched re-ID matcher on real
    # tensors (bucket-padded through repro.kernels.dispatch).
    embed_dim: int = 0
    reid_threshold: float = 0.5


@dataclass
class ScenarioResult:
    config: ScenarioConfig
    active_timeline: List[Tuple[float, int]]
    latencies: List[Tuple[float, float]]  # (sink time, end-to-end latency)
    on_time: int
    delayed: int
    source_events: int
    dropped: int
    drops_by_task: Dict[str, int]
    batch_sizes: Dict[str, List[int]]
    positives_generated: int
    positives_completed: int
    positives_dropped: int
    detections_on_time: int
    reid_matched: int = 0

    @property
    def peak_active(self) -> int:
        return max((c for _, c in self.active_timeline), default=0)

    @property
    def median_latency(self) -> float:
        if not self.latencies:
            return 0.0
        return float(np.median([l for _, l in self.latencies]))

    @property
    def p99_latency(self) -> float:
        if not self.latencies:
            return 0.0
        return float(np.percentile([l for _, l in self.latencies], 99))

    @property
    def delayed_fraction(self) -> float:
        total = self.on_time + self.delayed
        return self.delayed / total if total else 0.0

    @property
    def dropped_fraction(self) -> float:
        return self.dropped / self.source_events if self.source_events else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "source_events": self.source_events,
            "on_time": self.on_time,
            "delayed": self.delayed,
            "dropped": self.dropped,
            "delayed_frac": round(self.delayed_fraction, 4),
            "dropped_frac": round(self.dropped_fraction, 4),
            "median_latency_s": round(self.median_latency, 3),
            "p99_latency_s": round(self.p99_latency, 3),
            "peak_active": self.peak_active,
            "positives_generated": self.positives_generated,
            "positives_completed": self.positives_completed,
        }


class TrackingScenario:
    """Builds and runs one configured tracking experiment."""

    def __init__(self, config: ScenarioConfig) -> None:
        self.cfg = config
        t_init = time.perf_counter()
        # The scenario no longer owns world geometry: the road network, walk
        # and camera placement live in a shared immutable WorldBundle, built
        # once per key and reused by every config of a sweep.
        key = WorldKey.from_config(config)
        world = config.world
        if world is None:
            t0 = time.perf_counter()
            world = get_world(key)
            self.world_build_seconds = time.perf_counter() - t0
        else:
            if world.key != key:
                raise ValueError(
                    f"config.world was built for {world.key}, but this config "
                    f"needs {key}"
                )
            self.world_build_seconds = 0.0
        self.world = world
        self.road = world.road
        self.walk = world.walk
        if config.embed_dim:
            # Embedding draws consume the camera RNG, so an embedding-enabled
            # camera network is stateful and cannot be shared across
            # scenarios; rebuild it (road + walk still come from the bundle).
            self.cameras = CameraNetwork(
                self.road,
                self.walk,
                num_cameras=config.num_cameras,
                fov_radius_m=config.fov_radius_m,
                fps=config.fps,
                embed_dim=config.embed_dim,
                seed=config.seed + 13,
            )
        else:
            self.cameras = world.cameras
        network = NetworkModel()
        if config.bandwidth_schedule is not None:
            network.bandwidth_schedule = config.bandwidth_schedule
        # The static (src, dst) -> (latency, over-network) classification
        # depends only on the deployment shape, so scenarios sharing a world
        # share the memoized table too.
        self.sim = DiscreteEventSimulator(
            network,
            transit_cache=world.transit_table(
                config.num_va, config.num_cr, config.num_nodes
            ),
        )
        self._reid_enabled = config.embed_dim > 0
        self._reid_query = (
            self.cameras.entity_embedding[None, :] if self._reid_enabled else None
        )
        self._build_tl()
        self._build_pipeline()
        self._stats_active: List[Tuple[float, int]] = []
        self._positives_generated = 0
        self._positives_completed = 0
        self._reid_matched = 0
        self._detections_on_time = 0
        self._pending_detections: List[Detection] = []
        self._source_events = 0
        # Active-set mirrors so the per-tick loops are O(active cameras),
        # not O(all cameras): `_fc_active` tracks the FC states that are
        # *currently* active (control latency applied); `_ctrl_target` is the
        # last activation set TL asked for (so ticks only schedule control
        # events for the delta).
        self._fc_active: Set[int] = set(self.tl.active)
        self._ctrl_target: Set[int] = set(self.tl.active)
        #: Construction wall-time (world fetch + pipeline build), split from
        #: run() wall-time so per-event rates aren't polluted by one-off
        #: builds (benchmarks record both).
        self.build_seconds = time.perf_counter() - t_init

    # ------------------------------------------------------------------ #
    def _build_tl(self) -> None:
        cfg = self.cfg
        kw = dict(
            entity_speed=cfg.tl_peak_speed,
            min_radius_m=cfg.tl_min_radius_m,
        )
        cams = self.cameras.camera_vertices
        if cfg.tl == "base":
            self.tl: TrackingLogic = TLBase(self.road, cams, **kw)
        elif cfg.tl == "bfs":
            self.tl = TLBFS(self.road, cams, fixed_edge_length_m=84.5, **kw)
        elif cfg.tl == "wbfs":
            self.tl = TLWBFS(self.road, cams, **kw)
        elif cfg.tl == "prob":
            self.tl = TLProbabilistic(self.road, cams, **kw)
        else:
            raise ValueError(f"unknown tl strategy {cfg.tl!r}")
        # The query names a last-seen location (Fig. 1: start with only the
        # camera covering it active).
        cam_ids = list(cams)
        cam_pos = self.road.positions[np.fromiter(cams.values(), dtype=np.int64)]
        d = np.linalg.norm(cam_pos - self.road.positions[self.walk.vertices[0]], axis=1)
        start_cam = cam_ids[int(np.argmin(d))]
        self.tl.last_seen_camera = start_cam
        self.tl.last_seen_time = 0.0
        self.tl.active = self.tl.spotlight(0.0) if self.cfg.tl != "base" else set(cams)

    def _make_batcher(self, xi: Callable[[int], float]):
        cfg = self.cfg
        if cfg.batching == "dynamic":
            return DynamicBatcher(xi, m_max=cfg.m_max)
        if cfg.batching == "static":
            return StaticBatcher(xi, batch_size=cfg.static_batch)
        if cfg.batching == "nob":
            return NOBBatcher(xi, m_max=cfg.m_max)
        raise ValueError(f"unknown batching {cfg.batching!r}")

    def _build_pipeline(self) -> None:
        cfg = self.cfg
        sim = self.sim
        skews = list(cfg.node_clock_skews or [0.0] * cfg.num_nodes)
        if len(skews) < cfg.num_nodes:
            skews += [0.0] * (cfg.num_nodes - len(skews))

        self.sink = SinkTask(
            "UV",
            sim,
            gamma=cfg.gamma,
            epsilon_max=cfg.epsilon_max,
            on_event=self._on_sink_event,
            clock=Clock(0.0),  # kappa_n == kappa_1 (§4.6.2)
            node="head",
            # Budgets are only consulted by the drop points; skip the accept
            # machinery entirely in no-drop runs.
            learn_budgets=cfg.drops_enabled,
            # _on_sink_event only reads ev.value/ev.header inline and never
            # retains the event, so recycling headers at the sink is safe.
            recycle_headers=True,
        )
        sim.host_of["UV"] = "head"

        fc_xi = linear_xi(*cfg.fc_cost)
        va_xi = linear_xi(*cfg.va_cost)
        cr_xi = linear_xi(*cfg.cr_cost)

        self.cr_tasks: List[Task] = []
        for i in range(cfg.num_cr):
            node = f"node{i % cfg.num_nodes}"
            t = Task(
                f"CR-{i}",
                sim,
                cr_xi,
                self._make_batcher(cr_xi),
                logic=self._cr_logic,
                clock=Clock(skews[i % cfg.num_nodes]),
                budget=TaskBudget(f"CR-{i}", cr_xi, m_max=cfg.m_max),
                drops_enabled=cfg.drops_enabled,
                node=node,
            )
            t.output_event_bytes = 256.0  # metadata only (§2.2.3)
            t.connect(self.sink)
            t.partitioner = _constant_partitioner("UV")
            # CR logic has no completion-time state reads: safe to fuse its
            # streaming (b=1) executions with the outbound transit.
            t.fuse_streaming = not cfg.drops_enabled and getattr(
                sim, "transit_is_static", False
            )
            self.cr_tasks.append(t)
            sim.host_of[t.name] = node

        self.va_tasks: List[Task] = []
        for i in range(cfg.num_va):
            node = f"node{i % cfg.num_nodes}"
            t = Task(
                f"VA-{i}",
                sim,
                va_xi,
                self._make_batcher(va_xi),
                logic=self._va_logic,
                clock=Clock(skews[i % cfg.num_nodes]),
                budget=TaskBudget(f"VA-{i}", va_xi, m_max=cfg.m_max),
                drops_enabled=cfg.drops_enabled,
                node=node,
            )
            for cr in self.cr_tasks:
                t.connect(cr)
            # Keys are camera ids, a small fixed universe: precompute the
            # routing table instead of formatting a string per event.
            if not hasattr(self, "_cr_route"):
                self._cr_route = {
                    cam: f"CR-{hash(cam) % cfg.num_cr}"
                    for cam in self.cameras.camera_vertices
                }
            t.partitioner = _table_partitioner(self._cr_route)
            t.fuse_streaming = not cfg.drops_enabled and getattr(
                sim, "transit_is_static", False
            )
            self.va_tasks.append(t)
            sim.host_of[t.name] = node

        # FC tasks are created lazily: a 10k-camera scenario with a spotlight
        # TL only ever activates a small moving subset, so building a Task
        # (+ its budget, batcher, wiring) per camera upfront dominated
        # construction time.  `_make_fc` is called on first activation or
        # first sourced frame.
        self._fc_xi = fc_xi
        self.fc_tasks: Dict[int, Task] = {}
        # Full FC fusion: with drops off, a static network and a frame period
        # longer than xi_fc(1), the FC stage reduces exactly to "arrive at
        # the VA at t + xi_fc(1) + transit with xi_bar advanced" — the
        # per-camera Task machinery is bypassed wholesale (it still runs for
        # drops-enabled or dynamic-bandwidth configs).
        self._fc_xi1 = fc_xi(1)
        self._fuse_fc = (
            not cfg.drops_enabled
            and getattr(sim, "transit_is_static", False)
            and 1.0 / cfg.fps > self._fc_xi1
        )
        if self._fuse_fc:
            # All FC->VA transits are edge-host -> compute-node MAN hops with
            # the same payload size: one delay for every camera.
            self._fc_transit = sim.network.transit_delay(
                "edge*", "node*", 2900.0, 0.0
            )
            self._va_of = {
                cam: self.va_tasks[hash(cam) % cfg.num_va]
                for cam in self.cameras.camera_vertices
            }

    def _make_fc(self, cam: int) -> Task:
        cfg = self.cfg
        sim = self.sim
        # FC co-located with the camera on an edge host; round-robin the
        # *downstream* VA by camera id (paper: FCs scheduled round-robin).
        fc_xi = self._fc_xi
        t = Task(
            f"FC-{cam}",
            sim,
            fc_xi,
            StaticBatcher(fc_xi, batch_size=1),  # FC logic is simple/edge
            logic=self._fc_logic,
            clock=Clock(0.0),  # source clock kappa_1
            budget=TaskBudget(f"FC-{cam}", fc_xi, m_max=1),
            drops_enabled=cfg.drops_enabled,
            node=f"edge{cam}",
        )
        for va in self.va_tasks:
            t.connect(va)
        # Each FC has a fixed key (its camera), so its destination VA is
        # a constant.
        t.partitioner = _constant_partitioner(f"VA-{hash(cam) % cfg.num_va}")
        t.state["isActive"] = cam in self._fc_active
        # FC control updates land >= man_latency after a tick while xi(1) is
        # sub-millisecond, so arrival-time state reads match finish-time
        # reads: safe to fuse the execute+transmit hops (see pipeline.py).
        t.fuse_streaming = not cfg.drops_enabled and getattr(
            sim, "transit_is_static", False
        )
        self.fc_tasks[cam] = t
        sim.host_of[t.name] = f"edge{cam}"
        return t

    # ------------------------------------------------------------------ #
    # Module logics                                                       #
    # ------------------------------------------------------------------ #
    def _fc_logic(self, events: List[Event], state: Dict) -> List[Event]:
        if not state.get("isActive", True):
            return []
        # FC may inspect frame content (§2.2.1); a cheap edge-side candidate
        # filter flags likely positives so no drop point sheds them (§4.3.3).
        if self.cfg.avoid_drop_positives:
            for ev in events:
                if getattr(ev.value, "has_entity", False):
                    ev.header.avoid_drop = True
        return events

    def _va_logic(self, events: List[Event], state: Dict) -> List[Event]:
        # Object detection: every frame yields candidate boxes (1:1).  A
        # high-confidence candidate match flags the event avoid-drop (§4.3.3)
        # so the downstream drop points cannot shed it.
        if self._reid_enabled:
            self._va_reid(events)
        if self.cfg.avoid_drop_positives:
            for ev in events:
                if getattr(ev.value, "has_entity", False):
                    ev.header.avoid_drop = True
        return events

    def _va_reid(self, events: List[Event]) -> None:
        """Batched re-ID over the batch's frame embeddings: one bucket-padded
        ``reid_match`` call per VA batch (gallery = the frames' embeddings,
        query = the tracked entity's embedding).  Matches count toward
        ``ScenarioResult.reid_matched`` and — like the ground-truth candidate
        filter — flag avoid-drop when the config asks for it (§4.3.3)."""
        from repro.kernels import dispatch

        embs = [getattr(ev.value, "embedding", None) for ev in events]
        idx = [i for i, e in enumerate(embs) if e is not None]
        if not idx:
            return
        gallery = np.stack([embs[i] for i in idx])
        _, _, matched = dispatch.reid_match(
            gallery, self._reid_query, threshold=self.cfg.reid_threshold
        )
        matched = np.asarray(matched)
        avoid = self.cfg.avoid_drop_positives
        for j, i in enumerate(idx):
            if matched[j]:
                self._reid_matched += 1
                if avoid:
                    events[i].header.avoid_drop = True

    def _cr_logic(self, events: List[Event], state: Dict) -> List[Event]:
        rng = state.get("rng")
        if rng is None:
            rng = state["rng"] = np.random.default_rng(self.cfg.seed + 101)
        p_tp = self.cfg.p_true_positive
        avoid = self.cfg.avoid_drop_positives
        for ev in events:
            frame: Frame = ev.value
            # NB: the rng is consumed only on entity frames (short-circuit),
            # keeping the random stream identical across refactors.
            positive = bool(frame.has_entity) and (float(rng.uniform()) <= p_tp)
            if positive and avoid:
                ev.header.avoid_drop = True
            # 1:1 transform: reuse the event object, swap the frame payload
            # for the CR verdict.  Clear the slowest-of-batch mark from the
            # upstream stage — the runtime re-marks this stage's slowest.
            ev.batch_slowest = False
            ev.value = Detection(
                camera_id=frame.camera_id, positive=positive, timestamp=frame.timestamp
            )
        return events

    # ------------------------------------------------------------------ #
    # Sink + TL feedback                                                  #
    # ------------------------------------------------------------------ #
    def _on_sink_event(self, ev: Event, now: float) -> None:
        det: Detection = ev.value
        if det.positive:
            self._positives_completed += 1
            if now - ev.header.source_arrival <= self.cfg.gamma:
                self._detections_on_time += 1
        self._pending_detections.append(det)

    def _apply_fc_active(self, cam: int, want: bool) -> None:
        """Control-event delivery (runs ``man_latency_s`` after the TL tick)."""
        if self._fuse_fc:
            # Fused FC mode keeps no per-camera tasks; the mirror set is the
            # entire FC state.
            if want:
                self._fc_active.add(cam)
            else:
                self._fc_active.discard(cam)
            return
        if want:
            fc = self.fc_tasks.get(cam)
            if fc is None:
                self._fc_active.add(cam)  # _make_fc reads the mirror
                self._make_fc(cam)
            else:
                fc.state["isActive"] = True
                self._fc_active.add(cam)
        else:
            fc = self.fc_tasks.get(cam)
            if fc is not None:
                fc.state["isActive"] = False
            self._fc_active.discard(cam)

    def _tl_tick(self) -> None:
        now = self.sim.time
        dets, self._pending_detections = self._pending_detections, []
        new_active = self.tl.update(dets, now)
        self._stats_active.append((now, len(new_active)))
        # Control events to FCs (TL -> FC, §2.2.1) after a control latency.
        # Only the delta against the previously requested set is scheduled,
        # so a tick costs O(|changed|), not O(num_cameras).
        latency = self.sim.network.man_latency_s
        prev = self._ctrl_target
        for cam in new_active - prev:
            self.sim.schedule(latency, self._apply_fc_active, cam, True)
        for cam in prev - new_active:
            self.sim.schedule(latency, self._apply_fc_active, cam, False)
        self._ctrl_target = new_active
        if now + self.cfg.tl_update_period <= self.cfg.duration_s:
            self.sim.schedule(self.cfg.tl_update_period, self._tl_tick)

    # ------------------------------------------------------------------ #
    # Frame generation                                                    #
    # ------------------------------------------------------------------ #
    def _frame_tick(self) -> None:
        t = self.sim.time
        if self._fc_active:
            # Batched sourcing: one position interpolation + one vectorized
            # FOV test for the whole active set (ascending camera order, same
            # as the old per-camera loop).
            ids = np.fromiter(self._fc_active, dtype=np.int64, count=len(self._fc_active))
            ids.sort()
            frames = self.cameras.frames_at(t, ids)
            n_pos = 0
            if self._fuse_fc:
                # FC stage fused into the source: identical arrival times and
                # headers, no per-camera Task hops (see _build_pipeline).
                xi1 = self._fc_xi1
                avoid = self.cfg.avoid_drop_positives
                va_of = self._va_of
                groups: Dict[Task, List[Event]] = {}
                for frame in frames:
                    has = frame.has_entity
                    if has:
                        n_pos += 1
                    cam = frame.camera_id
                    header = source_header(new_event_id(), t)
                    header.xi_bar = xi1
                    if has and avoid:
                        header.avoid_drop = True
                    ev = Event(header=header, key=cam, value=frame)
                    ev.batch_slowest = True  # a b=1 batch's sole event
                    va = va_of[cam]
                    g = groups.get(va)
                    if g is None:
                        groups[va] = [ev]
                    else:
                        g.append(ev)
                depart = t + xi1
                for va, evs in groups.items():
                    self.sim.schedule_at(depart + self._fc_transit, va._deliver_many, evs)
            else:
                fc_tasks = self.fc_tasks
                make_fc = self._make_fc
                for frame in frames:
                    if frame.has_entity:
                        n_pos += 1
                    cam = frame.camera_id
                    fc = fc_tasks.get(cam)
                    if fc is None:
                        fc = make_fc(cam)
                    header = source_header(new_event_id(), t)
                    fc.on_arrival(Event(header=header, key=cam, value=frame))
            self._positives_generated += n_pos
            self._source_events += len(frames)
        if t + 1.0 / self.cfg.fps <= self.cfg.duration_s:
            self.sim.schedule(1.0 / self.cfg.fps, self._frame_tick)

    # ------------------------------------------------------------------ #
    def run(self) -> ScenarioResult:
        cfg = self.cfg
        self.sim.schedule(0.0, self._frame_tick)
        self.sim.schedule(cfg.tl_update_period, self._tl_tick)
        # Allow in-flight events to drain past the generation horizon.
        self.sim.run(until=cfg.duration_s + 3.0 * cfg.gamma)

        drops: Dict[str, int] = {}
        batch_sizes: Dict[str, List[int]] = {"VA": [], "CR": []}
        total_dropped = 0
        for t in list(self.va_tasks) + list(self.cr_tasks) + list(self.fc_tasks.values()):
            if t.stats.dropped:
                drops[t.name] = t.stats.dropped
                total_dropped += t.stats.dropped
        for t in self.va_tasks:
            batch_sizes["VA"].extend(t.stats.batch_sizes)
        for t in self.cr_tasks:
            batch_sizes["CR"].extend(t.stats.batch_sizes)

        return ScenarioResult(
            config=cfg,
            active_timeline=self._stats_active,
            latencies=list(self.sink.latencies),
            on_time=self.sink.on_time,
            delayed=self.sink.delayed,
            source_events=self._source_events,
            dropped=total_dropped,
            drops_by_task=drops,
            batch_sizes=batch_sizes,
            positives_generated=self._positives_generated,
            positives_completed=self._positives_completed,
            positives_dropped=self._positives_generated - self._positives_completed,
            detections_on_time=self._detections_on_time,
            reid_matched=self._reid_matched,
        )
