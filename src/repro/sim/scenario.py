"""End-to-end tracking scenario: a thin driver over a compiled app.

The executable unit is a :class:`~repro.core.dataflow.TrackingApp`: the app
compiler (:func:`repro.core.compile.compile_app`) lowers it + a shared
:class:`~repro.sim.world.WorldBundle` + a
:class:`~repro.core.compile.DeploymentSpec` onto the Task DAG

    cameras --frames--> FC (one per camera, edge hosts)
      --> VA instances (hash by camera) --> CR instances --> UV sink
    UV --detections--> TL --(de)activate--> FC states      (feedback)
    UV --positives--> QF --fused query--> VA/CR states     (feedback)

and this module drives it: sources frames from the camera network, ticks
the TL control loop, applies activation/query control events after the
control-network latency, and assembles the :class:`ScenarioResult`.

:class:`ScenarioConfig` remains the historical knob surface (paper §5):
``to_app()`` turns it into the equivalent preset ``TrackingApp`` (FC
``isActive`` gate, pass-through VA, seeded-verdict CR, the ``tl:`` knob's
strategy) and ``deployment()`` into the matching ``DeploymentSpec`` —
``TrackingScenario(cfg)`` compiles and runs exactly the pipeline it always
did, bit-identically.  Custom apps run the same road:
``TrackingScenario(cfg, app=my_app, deployment=my_deployment)``.

Execution times are charged through each module's resolved ``xi(b)`` cost
model (calibrated to the paper: CR ~120 ms/event streaming for App 1, ~63%
more for App 2), network transits through :class:`NetworkModel`, and all of
the paper's knobs are exposed: batching strategy, drops on/off, TL
strategy, entity peak speed ``es``, bandwidth schedule, clock skews.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.compile import (
    CompiledApp,
    DeploymentSpec,
    as_detection,
    compile_app,
    linear_xi,
    resolve_module,
)
from repro.core.dataflow import ModuleSpec, TrackingApp, fc_is_active
from repro.core.events import Event, new_event_id, source_header
from repro.core.pipeline import Task
from repro.core.tracking import (
    Detection,
    TLBFS,
    TLBase,
    TLProbabilistic,
    TLWBFS,
    TrackingLogic,
)
from .cameras import CameraNetwork, Frame
from .dynamism import DynamismSpec, DynamismTrace
from .simulator import DiscreteEventSimulator, NetworkModel
from .world import WorldBundle, WorldKey, get_world

__all__ = [
    "ScenarioConfig",
    "ScenarioResult",
    "TrackingScenario",
    "linear_xi",
    "make_scenario_cr",
    "va_passthrough",
]


# --------------------------------------------------------------------- #
# Preset module logics (the historical hard-wired scenario pipeline,     #
# now expressed in the DSL so ScenarioConfig is just an app factory)     #
# --------------------------------------------------------------------- #
def va_passthrough(camera_id, frames, state):
    """Preset VA: object detection with 1:1 selectivity — every frame
    yields its candidate boxes; the payload travels unchanged (the synthetic
    frames already carry ground truth + optional embeddings)."""
    return [(camera_id, frame) for frame in frames]


# Lowering override (see repro.core.compile._event_level): pass-through VA
# is the identity at event level — the compiler's hot path must not pay a
# keyed-adapter round trip per event for a no-op transform.
va_passthrough.task_logic = lambda events, state: events


def make_scenario_cr(seed: int, p_true_positive: float):
    """Preset CR: cross-camera re-id verdict per frame, 1:1, with the
    per-instance RNG stream the scenario always used (seeded ``seed + 101``
    in each CR task's state; consumed only on entity frames so the random
    stream is identical across refactors).

    Carries a ``task_logic`` lowering override: the event-level transform
    is the pipeline's hottest user code (once per event), and the override
    is the historical ``_cr_logic`` loop verbatim — event objects reused,
    upstream ``batch_slowest`` marks cleared on transform.
    """

    def cr(camera_id, frames, state):
        rng = state.get("rng")
        if rng is None:
            rng = state["rng"] = np.random.default_rng(seed + 101)
        out = []
        for frame in frames:
            positive = bool(frame.has_entity) and (
                float(rng.uniform()) <= p_true_positive
            )
            out.append(
                (
                    camera_id,
                    Detection(
                        camera_id=frame.camera_id,
                        positive=positive,
                        timestamp=frame.timestamp,
                    ),
                )
            )
        return out

    def cr_task_logic(events, state):
        rng = state.get("rng")
        if rng is None:
            rng = state["rng"] = np.random.default_rng(seed + 101)
        for ev in events:
            frame: Frame = ev.value
            # NB: the rng is consumed only on entity frames (short-circuit),
            # keeping the random stream identical across refactors.
            positive = bool(frame.has_entity) and (
                float(rng.uniform()) <= p_true_positive
            )
            # 1:1 transform: reuse the event object, swap the frame payload
            # for the CR verdict.  Clear the slowest-of-batch mark from the
            # upstream stage — the runtime re-marks this stage's slowest.
            ev.batch_slowest = False
            ev.value = Detection(
                camera_id=frame.camera_id, positive=positive, timestamp=frame.timestamp
            )
        return events

    cr.task_logic = cr_task_logic
    return cr


@dataclass
class ScenarioConfig:
    # Workload (paper §5.1)
    num_cameras: int = 1000
    duration_s: float = 600.0
    fps: float = 1.0
    entity_speed_mps: float = 1.0
    fov_radius_m: float = 6.0
    seed: int = 0
    # Road-network size.  None keeps the paper's 1000-vertex/2817-edge OSM
    # statistics (and grows the graph proportionally once ``num_cameras``
    # exceeds the vertex count, so 5k/10k-camera sweeps have a vertex per
    # camera).
    road_vertices: Optional[int] = None
    # QoS
    gamma: float = 15.0
    epsilon_max: float = 1.0
    # Tracking logic knob
    tl: str = "bfs"  # base | bfs | wbfs | prob
    tl_peak_speed: float = 4.0  # es (m/s)
    tl_update_period: float = 1.0
    tl_min_radius_m: float = 0.0
    # Batching knob
    batching: str = "dynamic"  # dynamic | static | nob
    static_batch: int = 1
    m_max: int = 25
    # Dropping knob
    drops_enabled: bool = False
    avoid_drop_positives: bool = False
    # Deployment (paper: 10 VA + 10 CR on 10 compute nodes)
    num_va: int = 10
    num_cr: int = 10
    num_nodes: int = 10
    # Cost models: (c0, c1) of xi(b) = c0 + c1 b, seconds.
    fc_cost: Tuple[float, float] = (0.0002, 0.0008)
    va_cost: Tuple[float, float] = (0.020, 0.010)
    # CR streaming cost xi(1) = 0.067 + 0.053 = 120 ms/event (App 1, §5.2.1);
    # batched capacity ~19 events/s (§5.2.3).
    cr_cost: Tuple[float, float] = (0.067, 0.053)
    # Detection model
    p_true_positive: float = 0.9
    # Network dynamics (Fig. 9): t -> bandwidth multiplier.
    bandwidth_schedule: Optional[Callable[[float], float]] = None
    # Clock skew per compute node (§4.6.2); source/sink stay at skew 0.
    node_clock_skews: Optional[Sequence[float]] = None
    # Shared immutable world (road + walk + cameras + transit tables).  When
    # None the scenario fetches it from the process-wide world cache; sweep
    # runners attach a prebuilt bundle so concurrent configs share one build.
    world: Optional[WorldBundle] = field(default=None, repr=False, compare=False)
    # Frame embeddings: 0 keeps the synthetic boolean frames; > 0 attaches a
    # per-frame embedding so VA runs the batched re-ID matcher on real
    # tensors (bucket-padded through repro.kernels.dispatch).
    embed_dim: int = 0
    reid_threshold: float = 0.5
    # Dynamism plane (§4.3–§4.5, Figs. 7/9): composable seeded perturbations
    # (bandwidth collapse, compute stragglers, input spikes, camera churn)
    # plus per-task telemetry + ground-truth tracking quality.  None keeps
    # the scenario bit-identical to its undisturbed trajectory.
    dynamism: Optional[DynamismSpec] = None
    # Execution engine for the per-tick hot loop.  "interpreted" drives the
    # discrete-event pipeline tick by tick (the reference semantics);
    # "megastep" lowers eligible configs to the fused device-resident tick
    # engine (`repro.core.megastep`), which executes frames -> VA -> CR ->
    # TL spotlight -> budget update for all queries and K ticks per dispatch
    # and must be bit-identical.  Ineligible configs (faults, dynamism,
    # non-static xi, ...) silently fall back to the interpreted pipeline.
    engine: str = "interpreted"
    # Observability plane (repro.obs): optional span tracer installed on the
    # compiled pipeline (EventTracer duck type — on_arrival/on_drop/on_retry/
    # on_sink hooks).  Excluded from repr/compare so WorldKey hashing and
    # config equality (goldens, journal identity) are unaffected.
    tracer: Optional[Any] = field(default=None, repr=False, compare=False)

    # ------------------------------------------------------------------ #
    # App-compiler factories: the config is a preset-app description      #
    # ------------------------------------------------------------------ #
    def make_tl(self, road, camera_vertices: Dict[int, int]) -> TrackingLogic:
        """Instantiate the ``tl:`` knob's strategy over a road network."""
        kw = dict(
            entity_speed=self.tl_peak_speed,
            min_radius_m=self.tl_min_radius_m,
        )
        if self.tl == "base":
            return TLBase(road, camera_vertices, **kw)
        if self.tl == "bfs":
            return TLBFS(road, camera_vertices, fixed_edge_length_m=84.5, **kw)
        if self.tl == "wbfs":
            return TLWBFS(road, camera_vertices, **kw)
        if self.tl == "prob":
            return TLProbabilistic(road, camera_vertices, **kw)
        raise ValueError(f"unknown tl strategy {self.tl!r}")

    def to_app(
        self,
        world: Optional[WorldBundle] = None,
        cameras: Optional[CameraNetwork] = None,
    ) -> TrackingApp:
        """The preset :class:`TrackingApp` equivalent to this config's
        historical hard-wired pipeline: ``isActive``-gated FC, pass-through
        VA, seeded-verdict CR, the ``tl:`` knob's strategy, no QF.  Module
        instance counts, batching and cost models ride along as per-module
        :class:`ModuleSpec` overrides, so compiling this app against
        ``self.deployment()`` reproduces the scenario bit-identically."""
        if world is None:
            world = get_world(WorldKey.from_config(self))
        cams = cameras if cameras is not None else world.cameras
        return TrackingApp(
            name=f"scenario-{self.tl}",
            fc=fc_is_active,
            va=va_passthrough,
            cr=make_scenario_cr(self.seed, self.p_true_positive),
            tl=self.make_tl(world.road, cams.camera_vertices),
            qf=None,
            specs={
                "FC": ModuleSpec(xi=linear_xi(*self.fc_cost), resource_tier="edge"),
                "VA": ModuleSpec(
                    instances=self.num_va,
                    resource_tier="fog",
                    xi=linear_xi(*self.va_cost),
                    batching=self.batching,
                    static_batch=self.static_batch,
                    m_max=self.m_max,
                ),
                "CR": ModuleSpec(
                    instances=self.num_cr,
                    resource_tier="cloud",
                    xi=linear_xi(*self.cr_cost),
                    batching=self.batching,
                    static_batch=self.static_batch,
                    m_max=self.m_max,
                ),
            },
            gamma=self.gamma,
        )

    def deployment(self) -> DeploymentSpec:
        """The platform-side knobs of this config as a ``DeploymentSpec``."""
        return DeploymentSpec(
            num_nodes=self.num_nodes,
            drops_enabled=self.drops_enabled,
            avoid_drop_positives=self.avoid_drop_positives,
            epsilon_max=self.epsilon_max,
            node_clock_skews=self.node_clock_skews,
        )


@dataclass
class ScenarioResult:
    config: ScenarioConfig
    active_timeline: List[Tuple[float, int]]
    latencies: List[Tuple[float, float]]  # (sink time, end-to-end latency)
    on_time: int
    delayed: int
    source_events: int
    dropped: int
    drops_by_task: Dict[str, int]
    batch_sizes: Dict[str, List[int]]
    positives_generated: int
    positives_completed: int
    positives_dropped: int
    detections_on_time: int
    reid_matched: int = 0
    query_pushes: int = 0
    # Dynamism plane outputs: the sampled telemetry trace and the
    # ground-truth quality report (both None for undisturbed runs, keeping
    # summary() — and the frozen goldens over it — unchanged).
    trace: Optional[DynamismTrace] = None
    quality: Optional[Dict[str, float]] = None

    @property
    def peak_active(self) -> int:
        return max((c for _, c in self.active_timeline), default=0)

    @property
    def median_latency(self) -> float:
        if not self.latencies:
            return 0.0
        return float(np.median([l for _, l in self.latencies]))

    @property
    def p99_latency(self) -> float:
        if not self.latencies:
            return 0.0
        return float(np.percentile([l for _, l in self.latencies], 99))

    @property
    def delayed_fraction(self) -> float:
        total = self.on_time + self.delayed
        return self.delayed / total if total else 0.0

    @property
    def dropped_fraction(self) -> float:
        return self.dropped / self.source_events if self.source_events else 0.0

    def summary(self) -> Dict[str, float]:
        out = {
            "source_events": self.source_events,
            "on_time": self.on_time,
            "delayed": self.delayed,
            "dropped": self.dropped,
            "delayed_frac": round(self.delayed_fraction, 4),
            "dropped_frac": round(self.dropped_fraction, 4),
            "median_latency_s": round(self.median_latency, 3),
            "p99_latency_s": round(self.p99_latency, 3),
            "peak_active": self.peak_active,
            "positives_generated": self.positives_generated,
            "positives_completed": self.positives_completed,
        }
        # Dynamism-plane extras ride along only when the run carried a spec,
        # so undisturbed summaries stay bit-identical to the frozen goldens.
        if self.trace is not None:
            out.update(self.trace.summary())
        elif self.quality is not None:
            out.update(self.quality)
        return out


class TrackingScenario:
    """Builds and runs one configured tracking experiment.

    ``config`` describes the workload (cameras, duration, entity walk, QoS)
    and — absent explicit ``app``/``deployment`` — the preset pipeline via
    ``config.to_app()`` / ``config.deployment()``.  ``app`` may be a
    :class:`TrackingApp` or a factory ``(world, cameras) -> TrackingApp``
    (sweep grids use factories so fork workers build JAX-touching apps in
    their own process).
    """

    def __init__(
        self,
        config: ScenarioConfig,
        app: Optional[Any] = None,
        deployment: Optional[DeploymentSpec] = None,
    ) -> None:
        self.cfg = config
        t_init = time.perf_counter()
        # The scenario does not own world geometry: the road network, walk
        # and camera placement live in a shared immutable WorldBundle, built
        # once per key and reused by every config of a sweep.
        key = WorldKey.from_config(config)
        world = config.world
        if world is None:
            t0 = time.perf_counter()
            world = get_world(key)
            self.world_build_seconds = time.perf_counter() - t0
        else:
            if world.key != key:
                raise ValueError(
                    f"config.world was built for {world.key}, but this config "
                    f"needs {key}"
                )
            self.world_build_seconds = 0.0
        self.world = world
        self.road = world.road
        self.walk = world.walk
        if config.embed_dim:
            # Embedding draws consume the camera RNG, so an embedding-enabled
            # camera network is stateful and cannot be shared across
            # scenarios; rebuild it (road + walk still come from the bundle).
            self.cameras = CameraNetwork(
                self.road,
                self.walk,
                num_cameras=config.num_cameras,
                fov_radius_m=config.fov_radius_m,
                fps=config.fps,
                embed_dim=config.embed_dim,
                seed=config.seed + 13,
            )
        else:
            self.cameras = world.cameras

        # ---- the executable unit: app + deployment ------------------- #
        if callable(app) and not isinstance(app, TrackingApp):
            app = app(world, self.cameras)
        self.app: TrackingApp = app or config.to_app(world, self.cameras)
        self.deployment = deployment or config.deployment()
        self.tl: TrackingLogic = self.app.tl

        network = NetworkModel()
        spec = config.dynamism
        if spec is not None:
            # Compose the dynamism plane's bandwidth perturbations over any
            # explicit schedule the config carries (both may be None).
            schedule = spec.bandwidth_schedule(config.bandwidth_schedule)
        else:
            schedule = config.bandwidth_schedule
        if schedule is not None:
            network.bandwidth_schedule = schedule
        # The static (src, dst) -> (latency, over-network) classification
        # depends only on the deployment shape, so scenarios sharing a world
        # share the memoized table too.
        num_va = resolve_module(self.app, self.deployment, "VA").instances
        num_cr = resolve_module(self.app, self.deployment, "CR").instances
        self.sim = DiscreteEventSimulator(
            network,
            transit_cache=world.transit_table(
                num_va, num_cr, self.deployment.num_nodes
            ),
        )
        # Compute stragglers scale actual execution durations inside the
        # engine; installed before compile_app so every Task (and the
        # compiler's fusion decisions) sees the dynamic-xi regime.
        if spec is not None:
            self.sim.xi_multiplier = spec.xi_multiplier()
            # Fault plane (HostCrash / NetworkPartition): like the xi
            # multiplier it must exist before compile_app — tasks snapshot
            # it at construction, and its presence turns off the static
            # transit fast paths so every send is fault-checked.
            self.sim.faults = spec.fault_plane(config.seed)
        self._rate_mult = spec.rate_multiplier() if spec is not None else None
        # Rate-window edges: a slowdown (factor < 1) stretches the tick
        # interval, and an unclamped interval computed just before a window
        # closes would overshoot it — or the end of the run — stalling the
        # source clock for good.  Ticks are clamped to the next boundary.
        self._rate_boundaries: List[float] = []
        if self._rate_mult is not None:
            bounds = set()
            for p in spec.perturbations:
                if hasattr(p, "rate_multiplier") and hasattr(p, "window"):
                    for b in p.window():
                        if 0.0 < b < config.duration_s:
                            bounds.add(float(b))
            self._rate_boundaries = sorted(bounds)
        self._reid_enabled = config.embed_dim > 0
        self._reid_query = (
            self.cameras.entity_embedding[None, :] if self._reid_enabled else None
        )
        # Multi-query tenancy hooks (repro.query.MultiQueryScenario): when
        # `_mask_of` is set (camera id -> live-query bitmask), sourced events
        # are tagged with it and zero-mask cameras (no live query interested)
        # are skipped; `_source_hook(frames, t)` observes each tick's sourced
        # frames for per-query accounting.  Both None in single-query runs —
        # the source loop pays one attribute test per tick.
        self._mask_of: Optional[Dict[int, int]] = None
        self._source_hook: Optional[Callable[[List[Frame], float], None]] = None

        # ---- lower the app onto the pipeline ------------------------- #
        self.compiled: CompiledApp = compile_app(
            self.app,
            world,
            self.deployment,
            self.sim,
            cameras=self.cameras,
            on_detection=self._on_sink_event,
            va_batch_hook=self._va_reid if self._reid_enabled else None,
            # _on_sink_event only reads ev.value/ev.header inline and never
            # retains the event, so recycling headers at the sink is safe.
            sink_recycle_headers=True,
        )
        self.sink = self.compiled.sink
        #: Observability plane: install the span tracer (if any) on every
        #: task of the compiled app.  Installing disables the bulk static
        #: delivery fast path so each hop is observed individually.
        self.tracer = config.tracer
        if self.tracer is not None:
            self.compiled.install_tracer(self.tracer)
        self._seed_tl()

        #: Simulation horizon: generation stops at duration_s; in-flight
        #: events (and telemetry) drain until here.
        self._horizon = config.duration_s + 3.0 * self.app.gamma
        self._ticks_scheduled = False
        self._stats_active: List[Tuple[float, int]] = []
        self._positives_generated = 0
        self._positives_completed = 0
        self._reid_matched = 0
        self._detections_on_time = 0
        self._pending_detections: List[Detection] = []
        self._source_events = 0

        # ---- dynamism plane: telemetry, quality, churn ---------------- #
        self._trace: Optional[DynamismTrace] = None
        if spec is not None and spec.telemetry_period_s > 0:
            self._trace = DynamismTrace(spec=spec, period_s=spec.telemetry_period_s)
        self._quality_on = spec is not None and spec.quality
        if self._quality_on:
            # Ground truth: every (camera, tick) pair where the entity is
            # inside the FOV — including cameras the TL left inactive, which
            # is exactly what separates *track* recall from drop accounting.
            self._truth_ids = np.arange(self.cameras.num_cameras, dtype=np.int64)
            self._truth_pairs: Set[Tuple[int, float]] = set()
            self._sink_positive_pairs: List[Tuple[int, float]] = []
        self._churns = []
        if spec is not None:
            for i, ch in enumerate(spec.churns()):
                rng = np.random.default_rng(ch.seed + 1009 * i + config.seed)
                self._churns.append((ch, rng))
        # Active-set mirrors so the per-tick loops are O(active cameras),
        # not O(all cameras): the compiled app's `fc_active` tracks the FC
        # states that are *currently* active (control latency applied);
        # `_ctrl_target` is the last activation set TL asked for (so ticks
        # only schedule control events for the delta).
        self.compiled.fc_active |= set(self.tl.active)
        self._ctrl_target: Set[int] = set(self.tl.active)
        #: Construction wall-time (world fetch + app lowering), split from
        #: run() wall-time so per-event rates aren't polluted by one-off
        #: builds (benchmarks record both).
        self.build_seconds = time.perf_counter() - t_init

    # ------------------------------------------------------------------ #
    def _seed_tl(self) -> None:
        """Point the TL at the query's last-seen location (Fig. 1: start
        with only the camera covering it active).  Apps that pre-seeded
        their TL keep their own state."""
        tl = self.tl
        if tl.last_seen_camera is not None:
            return  # the app brought its own warm-start state, active set incl.
        cams = self.cameras.camera_vertices
        cam_ids = list(cams)
        cam_pos = self.road.positions[np.fromiter(cams.values(), dtype=np.int64)]
        d = np.linalg.norm(
            cam_pos - self.road.positions[self.walk.vertices[0]], axis=1
        )
        tl.last_seen_camera = cam_ids[int(np.argmin(d))]
        tl.last_seen_time = 0.0
        tl.active = tl.spotlight(0.0)

    # ------------------------------------------------------------------ #
    # Driver-side instrumentation hooks                                   #
    # ------------------------------------------------------------------ #
    def _va_reid(self, events: List[Event], state: Dict) -> None:
        """Batched re-ID over the batch's frame embeddings: one bucket-padded
        ``reid_match`` call per VA batch (gallery = the frames' embeddings,
        query = the tracked entity's embedding).  Matches count toward
        ``ScenarioResult.reid_matched`` and — like the ground-truth candidate
        filter — flag avoid-drop when the config asks for it (§4.3.3)."""
        from repro.kernels import dispatch

        embs = [getattr(ev.value, "embedding", None) for ev in events]
        idx = [i for i, e in enumerate(embs) if e is not None]
        if not idx:
            return
        gallery = np.stack([embs[i] for i in idx])
        _, _, matched = dispatch.reid_match(
            gallery, self._reid_query, threshold=self.cfg.reid_threshold
        )
        matched = np.asarray(matched)
        avoid = self.deployment.avoid_drop_positives
        for j, i in enumerate(idx):
            if matched[j]:
                self._reid_matched += 1
                if avoid:
                    events[i].header.avoid_drop = True

    # ------------------------------------------------------------------ #
    # Sink + TL feedback                                                  #
    # ------------------------------------------------------------------ #
    def _on_sink_event(self, ev: Event, now: float) -> None:
        det = ev.value
        if not isinstance(det, Detection):
            det = as_detection(ev)
        if det.positive:
            self._positives_completed += 1
            if now - ev.header.source_arrival <= self.app.gamma:
                self._detections_on_time += 1
            if self._quality_on:
                self._sink_positive_pairs.append((det.camera_id, det.timestamp))
        self._pending_detections.append(det)

    def _tl_tick(self) -> None:
        now = self.sim.time
        dets, self._pending_detections = self._pending_detections, []
        new_active = self.tl.update(dets, now)
        self._stats_active.append((now, len(new_active)))
        # Control events to FCs (TL -> FC, §2.2.1) after a control latency.
        # Only the delta against the previously requested set is scheduled,
        # so a tick costs O(|changed|), not O(num_cameras).
        latency = self.sim.network.man_latency_s
        set_active = self.compiled.set_fc_active
        prev = self._ctrl_target
        for cam in new_active - prev:
            self.sim.schedule(latency, set_active, cam, True)
        for cam in prev - new_active:
            self.sim.schedule(latency, set_active, cam, False)
        self._ctrl_target = new_active
        if now + self.cfg.tl_update_period <= self.cfg.duration_s:
            self.sim.schedule(self.cfg.tl_update_period, self._tl_tick)

    # ------------------------------------------------------------------ #
    # Frame generation                                                    #
    # ------------------------------------------------------------------ #
    def _frame_tick(self) -> None:
        t = self.sim.time
        compiled = self.compiled
        fc_active = compiled.fc_active
        if self._quality_on:
            vis = self.cameras.visible_batch(self._truth_ids, t)
            for c in np.nonzero(vis)[0]:
                self._truth_pairs.add((int(c), t))
        if fc_active:
            # Batched sourcing: one position interpolation + one vectorized
            # FOV test for the whole active set (ascending camera order, same
            # as the old per-camera loop).
            ids = np.fromiter(fc_active, dtype=np.int64, count=len(fc_active))
            ids.sort()
            frames = self.cameras.frames_at(t, ids)
            mask_of = self._mask_of
            if mask_of is not None:
                # Multi-query mode: a camera still active only because a
                # cancelled query's control deltas are in flight sources
                # nothing — no live query would consume the frame.
                frames = [f for f in frames if mask_of.get(f.camera_id, 0)]
            n_pos = 0
            if compiled.fuse_fc:
                # FC stage fused into the source: identical arrival times and
                # headers, no per-camera Task hops (see CompiledApp).
                xi1 = compiled.fc_xi1
                avoid = self.deployment.avoid_drop_positives
                va_of = compiled.va_of
                groups: Dict[Task, List[Event]] = {}
                for frame in frames:
                    has = frame.has_entity
                    if has:
                        n_pos += 1
                    cam = frame.camera_id
                    header = source_header(new_event_id(), t)
                    header.xi_bar = xi1
                    if has and avoid:
                        header.avoid_drop = True
                    ev = Event(header=header, key=cam, value=frame)
                    if mask_of is not None:
                        ev.query_mask = mask_of[cam]
                    ev.batch_slowest = True  # a b=1 batch's sole event
                    va = va_of[cam]
                    g = groups.get(va)
                    if g is None:
                        groups[va] = [ev]
                    else:
                        g.append(ev)
                depart = t + xi1
                for va, evs in groups.items():
                    self.sim.schedule_at(
                        depart + compiled.fc_transit, va._deliver_many, evs
                    )
            else:
                fc_tasks = compiled.fc_tasks
                make_fc = compiled.make_fc
                for frame in frames:
                    if frame.has_entity:
                        n_pos += 1
                    cam = frame.camera_id
                    fc = fc_tasks.get(cam)
                    if fc is None:
                        fc = make_fc(cam)
                    header = source_header(new_event_id(), t)
                    ev = Event(header=header, key=cam, value=frame)
                    if mask_of is not None:
                        ev.query_mask = mask_of[cam]
                    fc.on_arrival(ev)
            self._positives_generated += n_pos
            self._source_events += len(frames)
            if self._source_hook is not None:
                self._source_hook(frames, t)
        if self._rate_mult is None:
            dt = 1.0 / self.cfg.fps
        else:
            # Input-rate spike: the source plane ticks faster while the
            # multiplier is > 1 (flash-crowd input at the FC sources).
            # Spec perturbations validate factor > 0; the floor guards
            # custom multiplier objects against a stalled/reversed clock.
            dt = 1.0 / (self.cfg.fps * max(self._rate_mult(t), 1e-9))
            # Never overshoot the next window edge: the multiplier sampled
            # *now* only holds until then (a sub-1 factor would otherwise
            # skip past its own window's end, or the run's).
            for b in self._rate_boundaries:
                if b > t + 1e-9:
                    if t + dt > b:
                        dt = b - t
                    break
        if t + dt <= self.cfg.duration_s:
            self.sim.schedule(dt, self._frame_tick)

    # ------------------------------------------------------------------ #
    # Dynamism plane ticks                                                #
    # ------------------------------------------------------------------ #
    def _sample_telemetry_now(self) -> None:
        trace = self._trace
        trace.times.append(self.sim.time)
        trace.active_cameras.append(len(self.compiled.fc_active))
        self.compiled.sample_telemetry(trace)

    def _telemetry_tick(self) -> None:
        self._sample_telemetry_now()
        # Keep sampling through the drain window (run() horizon) so budget
        # recovery after a perturbation closes is visible in the trace.
        if self.sim.time + self._trace.period_s <= self._horizon:
            self.sim.schedule(self._trace.period_s, self._telemetry_tick)

    def _churn_tick(self, idx: int) -> None:
        ch, rng = self._churns[idx]
        now = self.sim.time
        if ch.fraction > 0.0 and ch.t_start <= now < ch.t_end:
            # Candidates: cameras the TL currently wants AND that are up.
            target = sorted(self._ctrl_target & self.compiled.fc_active)
            if target:
                # Round up to one camera for any positive fraction;
                # fraction == 0 is the undisturbed baseline of a sweep axis.
                k = min(len(target), max(1, int(round(ch.fraction * len(target)))))
                picks = rng.choice(len(target), size=k, replace=False)
                for j in sorted(int(p) for p in picks):
                    cam = target[j]
                    self.compiled.set_fc_active(cam, False)
                    self.sim.schedule(ch.outage_s, self._churn_restore, cam)
        if now + ch.period_s <= min(ch.t_end, self.cfg.duration_s):
            self.sim.schedule(ch.period_s, self._churn_tick, idx)

    def _churn_restore(self, cam: int) -> None:
        # The camera comes back only if the TL still wants it (otherwise the
        # next TL delta would immediately deactivate it anyway).
        if cam in self._ctrl_target:
            self.compiled.set_fc_active(cam, True)

    def _quality_report(self) -> Dict[str, float]:
        truth = self._truth_pairs
        detected = set(self._sink_positive_pairs)
        tp = len(detected & truth)
        return {
            "truth_events": len(truth),
            "track_recall": round(tp / len(truth), 4) if truth else 1.0,
            "track_precision": round(tp / len(detected), 4) if detected else 1.0,
        }

    def _crash_flush(self, crash) -> None:
        """Crash onset: events queued or batching on the dying host are lost
        — they live in process memory, which the crash wipes.  An executing
        batch is allowed to finish (the GPU kernel ran), but its outputs hit
        the sender-down check in ``Task._send`` and are lost there too."""
        for t in self.sim.tasks.values():
            if not crash.matches(t.node):
                continue
            batcher = t.batcher
            if batcher._current:
                for pe in batcher.take():
                    t._fault_drop(pe.event)
            rq = t._run_queue
            while rq:
                for pe in rq.popleft():
                    t._fault_drop(pe.event)

    def _schedule_ticks(self) -> None:
        """Arm the periodic drivers (sources, TL, telemetry, churn, crash
        flushes).  Idempotent so ``run_until`` segments and a final ``run``
        over the same scenario never double-schedule a tick chain."""
        if self._ticks_scheduled:
            return
        self._ticks_scheduled = True
        cfg = self.cfg
        self.sim.schedule(0.0, self._frame_tick)
        self.sim.schedule(cfg.tl_update_period, self._tl_tick)
        if self._trace is not None:
            self.sim.schedule(0.0, self._telemetry_tick)
        for idx, (ch, _) in enumerate(self._churns):
            # First tick right at the window opening (not one period in), so
            # windows shorter than period_s still perturb and the trace's
            # pre/during split lines up with the first dropout.
            self.sim.schedule_at(ch.t_start, self._churn_tick, idx)
        spec = cfg.dynamism
        if spec is not None:
            for crash in spec.crashes():
                self.sim.schedule_at(crash.t_start, self._crash_flush, crash)

    def run_until(self, t: float) -> None:
        """Advance the simulation to ``t`` (capped at the drain horizon)
        without finalizing — the serving plane uses this to model a driver
        process that is killed mid-run, and ``run()`` continues from here."""
        self._schedule_ticks()
        self.sim.run(until=min(t, self._horizon))

    # ------------------------------------------------------------------ #
    def run(self) -> ScenarioResult:
        cfg = self.cfg
        self._schedule_ticks()
        # Allow in-flight events to drain past the generation horizon.
        self.sim.run(until=self._horizon)

        if self._trace is not None:
            # Final sample after the drain: cumulative counters (drops,
            # probes) now reconcile exactly with the ScenarioResult totals.
            # If the last periodic tick already sampled this timestamp,
            # replace it (same-time events may have processed *after* it)
            # rather than appending a zero-width duplicate interval.
            tr = self._trace
            if tr.times and tr.times[-1] == self.sim.time:
                tr.times.pop()
                tr.active_cameras.pop()
                for row in tr.series.values():
                    for col in row.values():
                        col.pop()
            self._sample_telemetry_now()
        quality = self._quality_report() if self._quality_on else None
        if self._trace is not None:
            self._trace.quality = quality
        compiled = self.compiled
        drops = compiled.drops_by_task()
        return ScenarioResult(
            config=cfg,
            active_timeline=self._stats_active,
            latencies=list(self.sink.latencies),
            on_time=self.sink.on_time,
            delayed=self.sink.delayed,
            source_events=self._source_events,
            dropped=sum(drops.values()),
            drops_by_task=drops,
            batch_sizes=compiled.batch_sizes(),
            positives_generated=self._positives_generated,
            positives_completed=self._positives_completed,
            positives_dropped=self._positives_generated - self._positives_completed,
            detections_on_time=self._detections_on_time,
            reid_matched=self._reid_matched,
            query_pushes=compiled.query_pushes,
            trace=self._trace,
            quality=quality,
        )

    def publish_metrics(self, registry, res: ScenarioResult) -> None:
        """Publish this run's telemetry into an obs-plane metrics registry.

        Thin delegation to :func:`repro.obs.collect_scenario` (lazy import so
        the sim layer never depends on the obs package at module load).
        """
        from repro.obs import collect_scenario

        collect_scenario(registry, self, res)
