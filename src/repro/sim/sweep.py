"""Sweep engine: run a whole grid of scenario configs — or compiled apps —
in one pass.

A benchmark sweep (the paper's Figs. 5-13) is a list of ``(name, case)``
pairs where ``case`` is either a plain ``ScenarioConfig`` (the preset app)
or an :class:`AppCase` pairing a ``TrackingApp`` factory + ``DeploymentSpec``
with a workload config — so all four Table-1 apps run through the same
engine, lowered by ``repro.core.compile.compile_app``.  :class:`SweepRunner`
executes the grid with the shared-world machinery:

* distinct :class:`~repro.sim.world.WorldKey`\\ s are prebuilt **once** in
  the parent and attached to the configs, so no grid point rebuilds
  geometry it shares with another;
* on platforms with ``fork`` the configs run concurrently in a process
  pool — the prebuilt worlds are inherited copy-on-write, and configs are
  indexed through a module-level list so grids carrying unpicklable
  members (e.g. a ``bandwidth_schedule`` lambda) still work;
* everywhere else (or with ``mode="serial"``) the grid runs serially in
  process, producing the **same records**.

Every scenario is self-contained — its RNG streams derive only from its
own config seed and its world is deterministic in its key — so each
per-config ``summary()`` is bit-identical between serial and concurrent
execution, and to a plain sequential ``TrackingScenario(cfg).run()``.

Workers disable the cyclic GC around ``run()`` (the event runtime is
allocation-lean and acyclic; collection pauses only add wall-clock noise);
results carry construction and run wall-times separately.
"""

from __future__ import annotations

import gc
import multiprocessing
import os
import time
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

from .scenario import ScenarioConfig, TrackingScenario
from .world import WorldKey, clear_world_cache, get_world, world_cache_stats

__all__ = ["AppCase", "CaseRecord", "QueryCase", "SweepResult", "SweepRunner"]


@dataclass
class AppCase:
    """One app-grid point: a ``TrackingApp`` (or factory) + deployment over
    a workload.

    ``app`` is either a :class:`~repro.core.dataflow.TrackingApp` or a
    factory ``(world, cameras) -> TrackingApp`` — grids prefer factories so
    fork workers construct JAX-touching apps (towers, kernels) inside their
    own process, and so each case's TL strategy gets its own instance bound
    to the case's world geometry.  ``workload`` is a ``ScenarioConfig``
    describing cameras/duration/walk/QoS; its module knobs (``num_va``,
    ``batching``, costs...) are ignored in favor of the app's specs merged
    over ``deployment``.  ``needs_jax`` routes auto-mode grids away from
    fork pools (see ``SweepRunner._resolve_mode``).
    """

    app: object  # TrackingApp | (world, cameras) -> TrackingApp
    workload: ScenarioConfig
    deployment: Optional[object] = None  # DeploymentSpec | None -> workload's
    needs_jax: bool = False


@dataclass
class QueryCase:
    """One multi-query grid point: N concurrent tracking queries (an int or
    a sequence of ``repro.query.QuerySpec``) fused over one shared pipeline
    on ``workload``, optionally behind an admission policy/controller.

    Runs through ``repro.query.MultiQueryScenario`` (imported lazily so the
    sweep engine has no hard dependency on the tenancy plane); the record's
    summary is the fused run's global summary plus the per-query extras
    (``queries``, ``union_peak_active``, admission counters...).
    """

    queries: object  # int | Sequence[repro.query.QuerySpec]
    workload: ScenarioConfig
    admission: Optional[object] = None  # AdmissionPolicy | AdmissionController
    spotlight_mode: str = "per-query"


@dataclass
class CaseRecord:
    """Per-config result: the summary plus split wall-times (picklable)."""

    name: str
    summary: Dict
    build_s: float  # scenario construction (world fetch + pipeline build)
    run_s: float  # TrackingScenario.run() only
    world_build_s: float  # non-zero only when this case built its world
    seed: int

    @property
    def us_per_event(self) -> float:
        return self.run_s * 1e6 / max(self.summary.get("source_events", 0), 1)


@dataclass
class SweepResult:
    records: List[CaseRecord]
    wall_s: float  # whole-sweep wall-clock (world prebuild + all cases)
    mode: str  # "fork" | "serial"
    workers: int
    worlds_built: int
    world_build_s: float


def _workload(case) -> ScenarioConfig:
    """The ScenarioConfig a grid entry runs over (identity for plain
    configs, the embedded workload for app/query cases)."""
    return case.workload if isinstance(case, (AppCase, QueryCase)) else case


def _run_case(name: str, case) -> CaseRecord:
    t0 = time.perf_counter()
    if isinstance(case, QueryCase):
        from repro.query import MultiQueryScenario

        scenario = MultiQueryScenario(
            case.workload,
            case.queries,
            admission=case.admission,
            spotlight_mode=case.spotlight_mode,
        )
        cfg = case.workload
    elif isinstance(case, AppCase):
        scenario = TrackingScenario(
            case.workload, app=case.app, deployment=case.deployment
        )
        cfg = case.workload
    else:
        scenario = TrackingScenario(case)
        cfg = case
    build_s = time.perf_counter() - t0
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        t0 = time.perf_counter()
        result = scenario.run()
        run_s = time.perf_counter() - t0
    finally:
        if gc_was_enabled:
            gc.enable()
    return CaseRecord(
        name=name,
        summary=result.summary(),
        build_s=build_s,
        run_s=run_s,
        world_build_s=scenario.world_build_seconds,
        seed=cfg.seed,
    )


# Fork-inherited grid: worker processes index into this instead of having
# cases pickled to them (configs and apps may carry lambdas/towers, and the
# attached WorldBundles travel copy-on-write through fork for free).
_ACTIVE_GRID: List[Tuple[str, object]] = []


def _run_case_at(idx: int) -> CaseRecord:
    name, cfg = _ACTIVE_GRID[idx]
    return _run_case(name, cfg)


def _cost_hint(case) -> float:
    """Rough relative cost of a case, used only to order pool submission
    (longest first minimizes makespan).  Source events dominate: a base TL
    sources every camera each tick; spotlight TLs source an active set that
    grows with the entity peak speed.  App cases are estimated from their
    workload (the app's own TL strategy isn't constructed until the worker
    builds the world)."""
    cfg = _workload(case)
    ticks = cfg.duration_s * cfg.fps
    dyn = getattr(cfg, "dynamism", None)
    if dyn is not None:
        # Input-rate spikes multiply the source tick count over their
        # window — the actual cost driver for dynamism grid points.
        for p in dyn.perturbations:
            if hasattr(p, "rate_multiplier") and hasattr(p, "window"):
                s, e = p.window()
                s = max(0.0, min(s, cfg.duration_s))
                e = min(e, cfg.duration_s)
                if e > s:
                    ticks += (p.rate_multiplier((s + e) / 2.0) - 1.0) * (e - s) * cfg.fps
    if cfg.tl == "base":
        per_tick = float(cfg.num_cameras)
    else:
        per_tick = 3.0 * cfg.tl_peak_speed**2
    overload = 2.0 if cfg.drops_enabled else 1.0
    return ticks * per_tick * overload


class SweepRunner:
    """Executes a grid of scenario configs with shared worlds.

    ``mode``: ``"auto"`` picks a fork pool when the platform supports it
    and the grid has more than one case, else serial; ``"fork"`` forces
    the pool; ``"serial"`` runs in process.  ``share_worlds=False``
    disables world prebuilding *and* clears the world/road caches before
    every case — the faithful "rebuild everything per config" sequential
    baseline the sweep engine is measured against.
    """

    def __init__(
        self,
        mode: str = "auto",
        max_workers: Optional[int] = None,
        share_worlds: bool = True,
    ) -> None:
        if mode not in ("auto", "fork", "serial"):
            raise ValueError(f"unknown sweep mode {mode!r}")
        if mode == "fork" and not share_worlds:
            raise ValueError(
                "share_worlds=False is the sequential cold baseline; "
                "it cannot run in a fork pool"
            )
        self.mode = mode
        self.max_workers = max_workers
        self.share_worlds = share_worlds

    # ------------------------------------------------------------------ #
    @staticmethod
    def fork_available() -> bool:
        return "fork" in multiprocessing.get_all_start_methods()

    def _resolve_mode(self, n_cases: int, needs_jax: bool = False) -> Tuple[str, int]:
        workers = self.max_workers or os.cpu_count() or 1
        workers = max(1, min(workers, n_cases))
        if self.mode == "fork":
            # Forced pool: never degrade silently (a 1-worker pool is still
            # a fork pool — results must be identical either way).
            if not self.fork_available():
                raise RuntimeError("fork start method unavailable on this platform")
            return "fork", workers
        if self.mode == "serial" or workers == 1 or not self.fork_available():
            return "serial", 1
        if needs_jax:
            # JAX (multithreaded XLA) in a forked child of a JAX-initialized
            # parent can deadlock; grids whose scenarios dispatch kernels
            # (embed_dim re-id) run serially unless fork is forced.
            return "serial", 1
        return "fork", workers

    # ------------------------------------------------------------------ #
    def run(self, grid: Sequence[Tuple[str, object]]) -> SweepResult:
        grid = list(grid)
        t_sweep = time.perf_counter()
        builds_before = world_cache_stats()["builds"]
        world_build_s = 0.0
        if self.share_worlds and grid:
            # Prebuild each distinct world once (deduplicated by key) and
            # attach the bundle so no case rebuilds shared geometry.
            bundles: Dict[WorldKey, object] = {}
            attached = []
            for name, case in grid:
                cfg = _workload(case)
                if cfg.world is not None:
                    attached.append((name, case))
                    continue
                key = WorldKey.from_config(cfg)
                bundle = bundles.get(key)
                if bundle is None:
                    t0 = time.perf_counter()
                    bundle = get_world(key)
                    world_build_s += time.perf_counter() - t0
                    bundles[key] = bundle
                cfg = replace(cfg, world=bundle)
                if isinstance(case, (AppCase, QueryCase)):
                    case = replace(case, workload=cfg)
                else:
                    case = cfg
                attached.append((name, case))
            grid = attached
        # True builds only: LRU/disk hits during the prebuild don't count.
        worlds_built = world_cache_stats()["builds"] - builds_before
        world_build_total = world_build_s
        needs_jax = any(
            _workload(case).embed_dim > 0
            or (isinstance(case, AppCase) and case.needs_jax)
            for _, case in grid
        )
        if not self.share_worlds:
            # The cold baseline is by definition sequential (per-case cache
            # clearing cannot be meaningful across concurrent workers).
            mode, workers = "serial", 1
        else:
            mode, workers = self._resolve_mode(len(grid), needs_jax=needs_jax)
        if mode == "fork":
            records = self._run_fork(grid, workers)
        elif self.share_worlds:
            records = [_run_case(name, cfg) for name, cfg in grid]
        else:
            # Cold baseline: every config rebuilds its world from scratch —
            # in-memory caches cleared per case AND the on-disk cache masked
            # (benchmarks default it on; a disk hit would warm the baseline).
            from repro.core.roadnet import clear_network_cache

            disk_env = os.environ.get("REPRO_WORLD_CACHE")
            os.environ["REPRO_WORLD_CACHE"] = "0"
            try:
                records = []
                for name, cfg in grid:
                    clear_world_cache()
                    clear_network_cache()
                    records.append(_run_case(name, cfg))
            finally:
                if disk_env is None:
                    del os.environ["REPRO_WORLD_CACHE"]
                else:
                    os.environ["REPRO_WORLD_CACHE"] = disk_env
            # Cold mode: every case built its own world; the per-case
            # clearing also reset the global stats, so report from records.
            worlds_built = len(records)
            world_build_total = sum(r.world_build_s for r in records)
        return SweepResult(
            records=records,
            wall_s=time.perf_counter() - t_sweep,
            mode=mode,
            workers=workers,
            worlds_built=worlds_built,
            world_build_s=world_build_total,
        )

    def _run_fork(
        self, grid: List[Tuple[str, object]], workers: int
    ) -> List[CaseRecord]:
        global _ACTIVE_GRID
        ctx = multiprocessing.get_context("fork")
        prev, _ACTIVE_GRID = _ACTIVE_GRID, grid
        # Longest-expected-first submission (with chunksize=1) minimizes the
        # makespan when the grid mixes heavy and light cases; the records
        # are restored to grid order below, so output is order-stable.
        order = sorted(
            range(len(grid)), key=lambda i: -_cost_hint(grid[i][1])
        )
        try:
            with ctx.Pool(processes=workers) as pool:
                out = pool.map(_run_case_at, order, chunksize=1)
        finally:
            _ACTIVE_GRID = prev
        records: List[Optional[CaseRecord]] = [None] * len(grid)
        for pos, idx in enumerate(order):
            records[idx] = out[pos]
        return records  # type: ignore[return-value]
