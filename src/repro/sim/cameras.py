"""Camera network + entity random-walk workload (paper §5.1).

The paper simulates 1000 camera feeds at 1 fps over a 7 km^2 road network:
the tracked entity random-walks the roads at 1 m/s; a camera's frame is a
*true positive* (contains the entity) while the entity is inside its FOV,
else a *true negative* drawn from CUHK03.  We reproduce the generator with
synthetic frame payloads: a frame carries ``has_entity`` plus (optionally) a
feature embedding so the JAX re-id models have real tensors to chew on.

The scenario engine sources frames once per tick for the whole *active* set,
so :meth:`CameraNetwork.frames_at` evaluates visibility for a batch of
cameras with one vectorized distance computation instead of one numpy call
per camera.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.roadnet import RoadNetwork

__all__ = ["Frame", "EntityWalk", "CameraNetwork"]


@dataclass(slots=True)
class Frame:
    """One camera frame event payload."""

    camera_id: int
    timestamp: float
    has_entity: bool
    # Median 2.9 kB JPG in the paper; used for network transit modelling.
    size_bytes: float = 2900.0
    embedding: Optional[np.ndarray] = None


class EntityWalk:
    """Random walk of the tracked entity along road edges at fixed speed.

    Precomputes the trajectory (vertex path + positions over time) so every
    query ``position(t)`` / ``at_vertex_near(t)`` is deterministic.
    """

    def __init__(
        self,
        network: RoadNetwork,
        start_vertex: int,
        speed_mps: float = 1.0,
        duration_s: float = 900.0,
        seed: int = 7,
    ) -> None:
        self.network = network
        self.speed = float(speed_mps)
        rng = np.random.default_rng(seed)
        self.times: List[float] = [0.0]
        self.vertices: List[int] = [start_vertex]
        t, u, prev = 0.0, start_vertex, -1
        while t < duration_s:
            nbrs = network.adjacency[u]
            choices = [(v, w) for v, w in nbrs if v != prev] or list(nbrs)
            v, w = choices[int(rng.integers(len(choices)))]
            t += w / self.speed
            self.times.append(t)
            self.vertices.append(v)
            prev, u = u, v
        # Vectorized lookup tables for position(t).
        self._times_arr = np.asarray(self.times, dtype=np.float64)
        verts = np.asarray(self.vertices, dtype=np.int64)
        self._seg_p0 = network.positions[verts[:-1]]
        self._seg_p1 = network.positions[verts[1:]]

    def position(self, t: float) -> np.ndarray:
        """Entity (x, y) at time t, linearly interpolated along the edge."""
        idx = int(np.searchsorted(self._times_arr, t, side="right")) - 1
        idx = max(0, min(idx, len(self.vertices) - 2))
        t0, t1 = self.times[idx], self.times[idx + 1]
        p0 = self._seg_p0[idx]
        p1 = self._seg_p1[idx]
        a = 0.0 if t1 <= t0 else min(max((t - t0) / (t1 - t0), 0.0), 1.0)
        return p0 * (1 - a) + p1 * a


class CameraNetwork:
    """Cameras placed on road vertices surrounding the walk's start vertex.

    ``visible(camera_id, t)`` — is the entity inside that camera's FOV at t.
    ``frames_at(t, camera_ids)`` — batched per-tick frame sourcing.
    """

    def __init__(
        self,
        network: RoadNetwork,
        walk: EntityWalk,
        num_cameras: int = 1000,
        fov_radius_m: float = 25.0,
        fps: float = 1.0,
        embed_dim: int = 0,
        seed: int = 13,
    ) -> None:
        self.network = network
        self.walk = walk
        self.fov_radius = float(fov_radius_m)
        self.fps = float(fps)
        self.embed_dim = int(embed_dim)
        self._rng = np.random.default_rng(seed)
        # Place cameras on the vertices nearest the start (paper: "placed on
        # vertices surrounding the starting vertex").
        start_pos = network.positions[walk.vertices[0]]
        order = np.argsort(np.sum((network.positions - start_pos) ** 2, axis=1))
        chosen = order[: min(num_cameras, network.num_vertices)]
        self.camera_vertices: Dict[int, int] = {
            cam_id: int(v) for cam_id, v in enumerate(chosen)
        }
        # Camera id -> position lookup (camera ids are contiguous 0..N-1 by
        # construction, so a plain array indexes by camera id).
        self._cam_positions = network.positions[np.asarray(chosen, dtype=np.int64)]
        self._entity_embedding = (
            self._rng.normal(size=(embed_dim,)).astype(np.float32) if embed_dim else None
        )

    @property
    def num_cameras(self) -> int:
        return len(self.camera_vertices)

    def visible(self, camera_id: int, t: float) -> bool:
        pos = self.walk.position(t)
        cam_pos = self.network.positions[self.camera_vertices[camera_id]]
        return float(np.linalg.norm(pos - cam_pos)) <= self.fov_radius

    def visible_batch(self, camera_ids: np.ndarray, t: float) -> np.ndarray:
        """Vectorized ``visible`` for a batch of camera ids at one instant.

        Matches the scalar path bit-for-bit: the per-camera distance is the
        same ``sqrt(dx^2 + dy^2)`` float64 computation.
        """
        pos = self.walk.position(t)
        diff = self._cam_positions[camera_ids] - pos
        dist = np.sqrt(diff[:, 0] ** 2 + diff[:, 1] ** 2)
        return dist <= self.fov_radius

    def frame(self, camera_id: int, t: float) -> Frame:
        has = self.visible(camera_id, t)
        emb: Optional[np.ndarray] = None
        if self.embed_dim:
            emb = self._draw_embedding(has)
        return Frame(camera_id=camera_id, timestamp=t, has_entity=has, embedding=emb)

    def frames_at(self, t: float, camera_ids: np.ndarray) -> List[Frame]:
        """Frames for all ``camera_ids`` at time ``t`` (one entity-position
        interpolation + one vectorized FOV test for the whole batch)."""
        if self.embed_dim:
            # Embedding draws consume the RNG per camera in id order; keep
            # the scalar path so the stream stays identical.
            return [self.frame(int(c), t) for c in camera_ids]
        has = self.visible_batch(camera_ids, t)
        return [
            Frame(camera_id=int(c), timestamp=t, has_entity=bool(h))
            for c, h in zip(camera_ids, has)
        ]

    def _draw_embedding(self, has_entity: bool) -> np.ndarray:
        if has_entity:
            noise = self._rng.normal(scale=0.1, size=(self.embed_dim,))
            return (self._entity_embedding + noise).astype(np.float32)
        return self._rng.normal(size=(self.embed_dim,)).astype(np.float32)

    @property
    def entity_embedding(self) -> Optional[np.ndarray]:
        return self._entity_embedding
