"""Training substrate: optimizer, schedules, loop, data, checkpointing."""

from .checkpoint import load_checkpoint, save_checkpoint
from .data import SyntheticLM, lm_batches
from .optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_update,
    cosine_schedule,
    init_adamw,
    make_schedule,
    wsd_schedule,
)
from .train_loop import TrainConfig, cross_entropy, loss_fn, make_train_step, train_loop

__all__ = [
    "AdamWConfig", "AdamWState", "SyntheticLM", "TrainConfig", "adamw_update",
    "cosine_schedule", "cross_entropy", "init_adamw", "lm_batches",
    "load_checkpoint", "loss_fn", "make_schedule", "make_train_step",
    "save_checkpoint", "train_loop", "wsd_schedule",
]
