"""AdamW + LR schedules, implemented in-house (no optax dependency).

Schedules: cosine-with-warmup (default) and **WSD** (Warmup-Stable-Decay,
MiniCPM arXiv:2404.06395 §4) — minicpm-2b's assigned schedule.
Functional style: ``init_adamw`` builds the state pytree; ``adamw_update``
is pure and jit/pjit-safe (all hyperparameters are static or scalars).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "init_adamw",
    "adamw_update",
    "cosine_schedule",
    "wsd_schedule",
    "make_schedule",
    "global_norm",
    "clip_by_global_norm",
]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Any  # first moment (pytree like params)
    nu: Any  # second moment


def init_adamw(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    params: Any,
    grads: Any,
    state: AdamWState,
    cfg: AdamWConfig,
    lr: jax.Array,
) -> Tuple[Any, AdamWState]:
    """One AdamW step with decoupled weight decay on matrix params only."""
    if cfg.grad_clip > 0:
        grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay > 0:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


# --------------------------------------------------------------------- #
# Schedules                                                              #
# --------------------------------------------------------------------- #
def cosine_schedule(
    base_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(math.pi * t))
        return base_lr * jnp.where(s < warmup_steps, warm, cos)

    return lr


def wsd_schedule(
    base_lr: float,
    warmup_steps: int,
    total_steps: int,
    decay_fraction: float = 0.1,
    min_ratio: float = 0.01,
) -> Callable[[jax.Array], jax.Array]:
    """Warmup-Stable-Decay (MiniCPM): linear warmup, long flat stage, then a
    short exponential decay over the final ``decay_fraction`` of training."""
    decay_steps = max(int(total_steps * decay_fraction), 1)
    stable_end = total_steps - decay_steps

    def lr(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        in_decay = jnp.clip((s - stable_end) / decay_steps, 0.0, 1.0)
        decay = jnp.power(jnp.asarray(min_ratio, jnp.float32), in_decay)
        val = jnp.where(s < warmup_steps, warm, decay)
        return base_lr * val

    return lr


def make_schedule(kind: str, base_lr: float, warmup: int, total: int):
    if kind == "wsd":
        return wsd_schedule(base_lr, warmup, total)
    return cosine_schedule(base_lr, warmup, total)
