"""Training step + loop: cross-entropy, MoE aux, AdamW, schedules.

``make_train_step`` returns a pure ``(params, opt_state, batch) -> (...)``
function suitable for ``jax.jit`` with in/out shardings (the dry-run lowers
exactly this function for the ``train_4k`` shape).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.core.clock import monotonic
from repro.models import forward
from .optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw, make_schedule

__all__ = ["TrainConfig", "cross_entropy", "loss_fn", "make_train_step", "train_loop"]


@dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    warmup_steps: int = 20
    total_steps: int = 300
    remat: bool = False
    label_smoothing: float = 0.0


def cross_entropy(
    logits: jax.Array, labels: jax.Array, vocab_size: int, smoothing: float = 0.0
) -> jax.Array:
    """Mean next-token CE over valid labels (label == -1 is padding)."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    valid = labels >= 0
    safe_labels = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    if smoothing > 0:
        uniform = -jnp.mean(logp[..., :vocab_size], axis=-1)
        nll = (1 - smoothing) * nll + smoothing * uniform
    nll = jnp.where(valid, nll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


def loss_fn(
    params: Any, cfg: ModelConfig, batch: Dict[str, jax.Array], *, remat: bool = False
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, cfg, batch, remat=remat)
    ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    loss = ce
    if cfg.moe.enabled:
        loss = loss + cfg.moe.router_aux_coef * aux["lb_loss"]
        loss = loss + cfg.moe.router_z_coef * aux["z_loss"]
    metrics = {"loss": loss, "ce": ce, **aux}
    return loss, metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Build the jit-able train step (forward + backward + AdamW)."""
    schedule = make_schedule(
        cfg.lr_schedule, tcfg.adamw.lr, tcfg.warmup_steps, tcfg.total_steps
    )

    def train_step(params, opt_state: AdamWState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=tcfg.remat), has_aux=True
        )(params)
        # 1-based step for the schedule: warmup starts at lr/warmup_steps,
        # not 0 (an lr-0 first step is wasted work).
        lr = schedule(opt_state.step + 1)
        new_params, new_state = adamw_update(params, grads, opt_state, tcfg.adamw, lr)
        metrics = dict(metrics)
        metrics["lr"] = lr
        return new_params, new_state, metrics

    return train_step


def train_loop(
    params: Any,
    cfg: ModelConfig,
    tcfg: TrainConfig,
    batches: Iterable[Dict[str, jax.Array]],
    *,
    steps: Optional[int] = None,
    log_every: int = 10,
    log_fn: Callable[[str], None] = print,
) -> Tuple[Any, AdamWState, list]:
    """Simple single-host loop (examples + tests); returns metric history."""
    opt_state = init_adamw(params)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    history = []
    t0 = monotonic()
    for i, batch in enumerate(batches):
        if steps is not None and i >= steps:
            break
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or (steps is not None and i == steps - 1):
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            log_fn(
                f"step {i:5d} loss={m['loss']:.4f} ce={m['ce']:.4f} "
                f"lr={m['lr']:.2e} ({monotonic()-t0:.1f}s)"
            )
    return params, opt_state, history
