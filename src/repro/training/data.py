"""Synthetic data pipeline.

Offline container: no corpora.  The LM stream is a deterministic *learnable*
language — a Zipf-weighted Markov chain over the vocabulary with a few
high-probability bigram templates — so cross-entropy demonstrably decreases
during the example training runs (quickstart asserts this).  Frontend-stub
archs (audio/vlm) get Gaussian frame/patch embeddings paired with aligned
labels drawn from the same chain.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.config.base import ModelConfig

__all__ = ["SyntheticLM", "lm_batches"]


@dataclass
class SyntheticLM:
    """Deterministic Markov-chain token source."""

    vocab_size: int
    order_states: int = 64  # chain runs over token % order_states
    zipf_a: float = 1.3
    seed: int = 0

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        V, K = self.vocab_size, self.order_states
        ranks = np.arange(1, V + 1, dtype=np.float64)
        base = 1.0 / np.power(ranks, self.zipf_a)
        base /= base.sum()
        # Per-state emission: a rotated, renormalized Zipf (states strongly
        # prefer a small, state-specific token set => learnable bigrams).
        self._emission = np.stack(
            [np.roll(base, rng.integers(0, V)) for _ in range(K)]
        )
        self._emission /= self._emission.sum(axis=1, keepdims=True)

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        V, K = self.vocab_size, self.order_states
        out = np.empty((batch, seq), np.int64)
        state = rng.integers(0, K, size=batch)
        for t in range(seq):
            # Vectorized categorical draw per row.
            u = rng.random(batch)
            cdf = np.cumsum(self._emission[state], axis=1)
            tok = (u[:, None] < cdf).argmax(axis=1)
            out[:, t] = tok
            state = tok % K
        return out


def lm_batches(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    *,
    seed: int = 0,
    embed_dim: Optional[int] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of {tokens/embeds/frames, labels} batches."""
    src = SyntheticLM(vocab_size=cfg.vocab_size, seed=seed)
    rng = np.random.default_rng(seed + 1)
    d = embed_dim or cfg.d_model
    while True:
        toks = src.sample(rng, batch, seq + 1)
        inputs, labels = toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)
        if cfg.arch_type == "encdec":
            frames = rng.normal(scale=0.02, size=(batch, cfg.encoder_seq, d)).astype(np.float32)
            yield {"frames": frames, "tokens": inputs, "labels": labels}
        elif cfg.frontend_stub:
            embeds = rng.normal(scale=0.02, size=(batch, seq, d)).astype(np.float32)
            yield {"embeds": embeds, "labels": labels}
        else:
            yield {"tokens": inputs, "labels": labels}
