"""Checkpointing: flat-key npz of any pytree + exact-restore round trip.

Sharded arrays are gathered to host before saving (fine at example scale;
a production deployment would swap in a tensorstore backend behind the same
two functions).
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint"]

_SEP = "::"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(path: str, tree: Any, metadata: Dict[str, Any] | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".json"
    with open(meta_path, "w") as f:
        json.dump(metadata or {}, f, indent=2, default=str)


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (keys/shapes/dtypes validated:
    missing *and* unexpected checkpoint keys both fail loudly)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    expected = set()
    for path_elems, leaf in paths_and_leaves:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems
        )
        expected.add(key)
        if key not in npz:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = npz[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    extra = sorted(set(npz.files) - expected)
    if extra:
        # A silently-ignored surplus key usually means the checkpoint was
        # written against a different structure (renamed field, stale file).
        raise KeyError(f"checkpoint has unexpected keys: {extra}")
    return jax.tree_util.tree_unflatten(treedef, leaves)
