"""Version shim for ``shard_map`` — resolved once, at import time.

jax >= 0.5 exposes ``jax.shard_map`` whose replication checker is toggled
with ``check_vma``; older releases only ship the experimental entry point
``jax.experimental.shard_map.shard_map`` with the equivalent ``check_rep``
knob.  Callers that combine shards with an ``all_gather`` + deterministic
reduction produce outputs the varying-axes checker cannot prove replicated,
so they need the toggle — under whichever name this jax spells it.

Every ``shard_map`` in this repo routes through :func:`shard_map` below
(analyzer rule JAX004 enforces it): the version probe runs exactly once at
module import instead of per call, and the ``check_rep``/``check_vma``
rename is spelled in one place.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

__all__ = ["shard_map", "SHARD_MAP_IMPL"]


def _resolve() -> tuple[Callable[..., Any], str, str]:
    if hasattr(jax, "shard_map"):  # repro: noqa[JAX004] — this IS the shim
        return jax.shard_map, "check_vma", "jax.shard_map"
    from jax.experimental.shard_map import shard_map as _sm  # repro: noqa[JAX004]

    return _sm, "check_rep", "jax.experimental.shard_map"


_IMPL, _CHECK_KW, SHARD_MAP_IMPL = _resolve()


def shard_map(
    fn: Callable[..., Any],
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check: bool = True,
) -> Callable[..., Any]:
    """``shard_map(fn)`` under either jax spelling.

    ``check=False`` disables the replication/varying-axes checker
    (``check_rep`` on old jax, ``check_vma`` on new) — use it when every
    shard provably computes the identical output via a deterministic
    combine, which the checker cannot infer.
    """
    kw = {_CHECK_KW: check}
    return _IMPL(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
