"""Context-parallel decode attention: shard_map + log-sum-exp combine.

For ``long_500k`` (batch=1, KV cache of 524288 tokens) the batch axis cannot
shard, so the KV *sequence* shards across the ``data`` axis.  A softmax over
a sharded axis is not a plain partial sum — GSPMD resolves it by all-gathering
the cache (collective-bound).  The hand-scheduled alternative implemented
here:

1. each shard computes attention over ITS slice of the cache, returning the
   partial output plus per-row ``(m, l)`` softmax statistics (max logit,
   sum of exps),
2. one tiny ``all_gather`` of the (B, Hq) statistics + partial outputs
   (``Hq x D`` floats per shard — not the cache!),
3. the exact softmax is reassembled:  with global ``m* = max_i m_i``,
   ``out = sum_i exp(m_i - m*) l_i out_i / sum_i exp(m_i - m*) l_i``.

This is the flash-attention combine identity applied across devices; the
collective volume drops from O(cache) to O(B x Hq x D x shards).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map

__all__ = ["decode_attention_partial", "combine_partials", "context_parallel_decode_attention"]


def decode_attention_partial(
    q: jax.Array,  # (B, Hq, D)
    k_shard: jax.Array,  # (B, Hkv, T_shard, D) — this shard's cache slice
    v_shard: jax.Array,
    valid: jax.Array,  # (B, T_shard) bool — validity of each local slot
    *,
    scale: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-shard attention; returns ``(out, m, l)`` with unnormalized out.

    out: (B, Hq, D) = sum_t p_t v_t with p = exp(s - m); m/l: (B, Hq).
    """
    B, Hq, D = q.shape
    _, Hkv, T, _ = k_shard.shape
    groups = Hq // Hkv
    qg = q.reshape(B, Hkv, groups, D).astype(k_shard.dtype)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k_shard, preferred_element_type=jnp.float32)
    s = s * scale
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)  # (B, Hkv, g)
    m_safe = jnp.where(jnp.isinf(m), 0.0, m)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum(
        "bkgt,bktd->bkgd", p.astype(v_shard.dtype), v_shard,
        preferred_element_type=jnp.float32,
    )
    return (
        out.reshape(B, Hq, D),
        m.reshape(B, Hq),
        l.reshape(B, Hq),
    )


def combine_partials(
    outs: jax.Array,  # (S, B, Hq, D) — per-shard unnormalized outputs
    ms: jax.Array,  # (S, B, Hq)
    ls: jax.Array,  # (S, B, Hq)
) -> jax.Array:
    """Exact softmax reassembly across shards (flash combine identity)."""
    m_star = jnp.max(ms, axis=0)  # (B, Hq)
    m_safe = jnp.where(jnp.isinf(m_star), 0.0, m_star)
    corr = jnp.exp(ms - m_safe[None])  # (S, B, Hq); exp(-inf)=0 for empty shards
    corr = jnp.where(jnp.isinf(ms), 0.0, corr)
    l_tot = jnp.sum(ls * corr, axis=0)  # (B, Hq)
    out = jnp.sum(outs * corr[..., None], axis=0)
    return out / jnp.maximum(l_tot[..., None], 1e-30)


def context_parallel_decode_attention(
    mesh: Mesh,
    axis: str,  # mesh axis the KV sequence is sharded over (e.g. "data")
    q: jax.Array,  # (B, Hq, D) — replicated over `axis`
    k_cache: jax.Array,  # (B, Hkv, T, D) — T sharded over `axis`
    v_cache: jax.Array,
    length: jax.Array,  # (B,) int32 — global valid length
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """shard_map decode attention over a sequence-sharded cache.

    Collectives: one ``all_gather`` of (B, Hq(D+2)) floats per shard instead
    of GSPMD's cache-sized gather — the §Perf H2/H3-style fix expressed as
    an explicit schedule.
    """
    B, Hq, D = q.shape
    T = k_cache.shape[2]
    n = mesh.shape[axis]
    scale_ = scale if scale is not None else D ** -0.5

    def shard_fn(q_l, k_l, v_l, length_l):
        idx = jax.lax.axis_index(axis)
        T_loc = k_l.shape[2]
        pos = idx * T_loc + jnp.arange(T_loc)[None, :]  # global positions
        valid = pos < length_l[:, None]
        out, m, l = decode_attention_partial(q_l, k_l, v_l, valid, scale=scale_)
        outs = jax.lax.all_gather(out, axis)  # (S, B, Hq, D)
        ms = jax.lax.all_gather(m, axis)
        ls = jax.lax.all_gather(l, axis)
        return combine_partials(outs, ms, ls)

    # The all_gather + deterministic combine makes every shard's output
    # identical; the varying-axes checker cannot infer that (check=False).
    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(), P(None, None, axis, None), P(None, None, axis, None), P()),
        out_specs=P(),
        check=False,
    )
    return fn(q, k_cache, v_cache, length).astype(q.dtype)
