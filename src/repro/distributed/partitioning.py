"""Logical-axis partitioning: activation constraints + parameter specs.

Model code calls :func:`constrain` with *logical* axis names; a
:class:`MeshRules` context (installed by the launcher / dry-run) maps them to
mesh axes and applies ``with_sharding_constraint``.  Outside any context the
call is a no-op, so unit tests and CPU smoke runs never touch device state.

Parameter sharding is *path-based*: :func:`param_specs` walks the params
pytree and assigns a ``PartitionSpec`` from the leaf's key-path and rank
(DESIGN.md §5):

* FFN / attention projections: tensor-parallel on the hidden/head dim over
  ``model``, FSDP on the embed dim over ``data`` (when divisible).
* Embedding / LM head: vocab (padded to /256) over ``model``.
* Expert stacks (E, d, f): tensor-parallel *inside* experts (f over
  ``model``) — 60 and 64 experts do not both divide the 16-wide axis.
* Norms / biases / scalars: replicated.

Divisibility is always checked; a dim that does not divide evenly over its
mesh axes is left unsharded rather than failing at lowering time.
"""

from __future__ import annotations

import contextlib
import re
import threading
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "MeshRules",
    "mesh_rules",
    "current_rules",
    "constrain",
    "logical_to_spec",
    "param_specs",
    "cache_specs",
    "batch_spec",
    "camera_mesh",
]

AxisName = Union[str, Tuple[str, ...], None]


@dataclass
class MeshRules:
    """Mapping logical axis name -> mesh axis (or tuple, or None).

    Non-divisible dims are still left unsharded (failing at lowering time
    helps nobody), but never *silently*: every drop bumps
    ``sharding_drops`` and the first drop per ``(path, axis)`` raises a
    ``UserWarning`` naming the param path, the axis, and the sizes — a
    60-expert stack quietly replicating over a 16-wide axis is a capacity
    bug, not a layout choice.
    """

    mesh: Mesh
    rules: Dict[str, AxisName] = field(default_factory=dict)
    sharding_drops: int = 0
    dropped: List[Tuple[str, str, int]] = field(
        default_factory=list, repr=False, compare=False)
    _warned: Set[Tuple[str, str]] = field(
        default_factory=set, repr=False, compare=False)

    def axis_size(self, axis: AxisName) -> int:
        if axis is None:
            return 1
        if isinstance(axis, str):
            return self.mesh.shape[axis]
        size = 1
        for a in axis:
            size *= self.mesh.shape[a]
        return size

    def _note_drop(self, path: str, axis: AxisName, dim: int) -> None:
        ax = axis if isinstance(axis, str) else "x".join(axis)
        self.sharding_drops += 1
        self.dropped.append((path or "<anonymous>", ax, dim))
        key = (path or "<anonymous>", ax)
        if key in self._warned:
            return
        self._warned.add(key)
        warnings.warn(
            f"sharding dropped: {path or '<anonymous>'} dim {dim} does not "
            f"divide mesh axis {ax!r} (size {self.axis_size(axis)}); "
            f"leaving it unsharded (replicated)",
            UserWarning,
            stacklevel=3,
        )

    def resolve(self, logical: Sequence[AxisName], shape: Sequence[int],
                *, path: str = "") -> P:
        """Logical names -> PartitionSpec, dropping non-divisible axes.

        Drops are counted in ``sharding_drops`` and warned once per
        ``(path, axis)`` — see the class docstring.
        """
        parts: List[AxisName] = []
        for name, dim in zip(logical, shape):
            axis = self.rules.get(name) if isinstance(name, str) else name
            if axis is not None and dim % self.axis_size(axis) != 0:
                self._note_drop(path, axis, dim)
                axis = None
            parts.append(axis)
        return P(*parts)


_local = threading.local()


def current_rules() -> Optional[MeshRules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def mesh_rules(rules: Optional[MeshRules]):
    prev = getattr(_local, "rules", None)
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def constrain(x: jax.Array, logical: Sequence[AxisName]) -> jax.Array:
    """Sharding-constrain ``x`` by logical axis names (no-op w/o rules)."""
    r = current_rules()
    if r is None:
        return x
    spec = r.resolve(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def default_rules(mesh: Mesh) -> MeshRules:
    axes = set(mesh.axis_names)
    batch_axes: Tuple[str, ...] = tuple(a for a in ("pod", "data") if a in axes)
    return MeshRules(
        mesh=mesh,
        rules={
            "batch": batch_axes if batch_axes else None,
            "seq": None,
            "model": "model" if "model" in axes else None,
            "fsdp": "data" if "data" in axes else None,
            "expert": None,
            "vocab": "model" if "model" in axes else None,
            "kv_seq": None,  # context-parallel decode overrides to "data"
            "kv_heads": None,  # serving mesh view: "kv" (§Perf H3)
            "kv_latent": None,  # MLA latent sharding (§Perf H2)
            "q_seq": None,  # row-parallel attention/SSD blocks (§Perf H1)
        },
    )


# --------------------------------------------------------------------- #
# Parameter specs (path-based)                                           #
# --------------------------------------------------------------------- #
def _spec_for_leaf(path: str, ndim: int, rules: MeshRules) -> Sequence[AxisName]:
    """Logical axes for one parameter leaf.  The leading scan/layer axis of
    stacked group params is always unsharded."""

    def lead(*names: AxisName) -> Sequence[AxisName]:
        # Group-stacked leaves carry a leading layer axis.
        extra = ndim - len(names)
        return tuple([None] * extra + list(names))

    if path.endswith("embedding") or path.endswith("meta_tokens"):
        return lead("vocab", "fsdp") if ndim >= 2 else lead(None)
    if path.endswith("lm_head"):
        return lead("fsdp", "vocab")
    if re.search(r"(wq|wk|wv)/kernel$", path):
        return lead("fsdp", "model")
    if re.search(r"wo/kernel$", path):
        return lead("model", "fsdp")
    if re.search(r"(w_gate|w_up)/kernel$", path):
        return lead("fsdp", "model")
    if re.search(r"w_down/kernel$", path):
        return lead("model", "fsdp")
    if re.search(r"experts/(w_gate|w_up|w_down)$", path):
        # (E, d, f) (stacked: (L, E, d, f)).  Default: tensor-parallel inside
        # experts (hidden dim over model — 60 experts don't divide the axis).
        # With rules["expert"] = "model" (E divides): expert-parallel
        # placement instead — each shard owns E/16 whole experts (§Perf H2).
        if rules.rules.get("expert") is not None:
            return lead("expert", None, None)
        if path.endswith("w_down"):
            return lead("model", "fsdp")
        return lead("fsdp", "model")  # E unsharded via lead()
    if re.search(r"router/kernel$", path):
        return lead("fsdp", None)
    if re.search(r"(w_dkv|w_uk|w_uv|wq)/kernel$", path):
        return lead("fsdp", "model")
    if re.search(r"in_proj/kernel$", path):
        return lead("fsdp", "model")
    if re.search(r"out_proj/kernel$", path):
        return lead("model", "fsdp")
    if re.search(r"conv_w$", path):
        return lead("model", None)  # (cdim, K)
    # norms, biases, scalars (dt_bias, A_log, D, conv_b, scale)
    return tuple([None] * ndim)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params: Any, rules: MeshRules) -> Any:
    """PartitionSpec pytree matching ``params``."""

    def leaf_spec(path, leaf):
        p = _path_str(path)
        logical = _spec_for_leaf(p, np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim, rules)
        return rules.resolve(logical, leaf.shape, path=p)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def cache_specs(caches: Any, rules: MeshRules, *, context_parallel: bool = False) -> Any:
    """PartitionSpec pytree for KV/state caches.

    Layout: leading layer axis unsharded, batch over ("pod","data") when it
    divides, else (context parallel) the sequence axis over "data".
    """

    def leaf_spec(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        ndim = leaf.ndim
        # Stacked caches: (L, B, T, ...) for kv; (L, B, ...) for states.
        batch_axis_pos = 1
        logical: List[AxisName] = [None] * ndim
        batch = rules.rules.get("batch")
        if batch is not None and shape[batch_axis_pos] % rules.axis_size(batch) == 0:
            logical[batch_axis_pos] = "batch"
        elif context_parallel and ndim == 4 and (p.endswith("c_kv") or p.endswith("k_rope")):
            logical[2] = "kv_seq"
        # Self KV caches are head-major (L, B, H, T, D): shard H over the
        # kv axis (serving mesh view) or model (§Perf H3).  Whisper cross
        # caches keep (L, B, T, H, D); mamba states (L, B, H, P, N) get
        # their head dim sharded the same way.
        key = p.split("/")[-1]
        if ndim == 5 and key in ("k", "v") and "cross" not in p:
            logical[2] = "kv_heads" if rules.rules.get("kv_heads") else "model"
            if context_parallel:
                logical[3] = "kv_seq"
        elif ndim == 5 and key in ("k", "v"):  # cross cache (L,B,T,H,D)
            logical[3] = "kv_heads" if rules.rules.get("kv_heads") else "model"
        elif ndim == 5 and key == "ssm":
            logical[2] = "kv_heads" if rules.rules.get("kv_heads") else "model"
        # MLA latent cache (L, B, T, r) and rope-key cache (L, B, T, rope):
        # shard the last dim (§Perf H2) so the cache lives sharded.
        if ndim == 4 and (p.endswith("c_kv") or p.endswith("k_rope")):
            logical[3] = "kv_latent"
        return rules.resolve(logical, shape, path=p)

    return jax.tree_util.tree_map_with_path(leaf_spec, caches)


def batch_spec(rules: MeshRules) -> P:
    return rules.resolve(("batch", None), (0, 0))  # placeholder; callers build their own


def camera_mesh(devices: Optional[Sequence[Any]] = None,
                *, axis: str = "cameras") -> MeshRules:
    """1-D mesh over ``devices`` for the sharded tracking planes.

    The sharded mega-step (``repro.kernels.megastep.sharded``) partitions
    camera-blocks — frame tables, activity masks, road-network planes —
    over a single ``cameras`` axis; the query registry and tag bits stay
    replicated.  ``MultiQueryScenario(cfg, specs, mesh=camera_mesh())``
    is the entry point (README §Sharded mesh).
    """
    if devices is None:
        devices = jax.devices()
    return MeshRules(
        mesh=Mesh(np.array(devices), (axis,)),
        rules={axis: axis, "cameras": axis},
    )
