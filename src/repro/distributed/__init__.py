"""Distribution: partitioning rules + hand-scheduled context parallelism."""

from .compat import SHARD_MAP_IMPL, shard_map
from .context_parallel import (
    combine_partials,
    context_parallel_decode_attention,
    decode_attention_partial,
)
from .partitioning import (
    MeshRules,
    cache_specs,
    camera_mesh,
    constrain,
    current_rules,
    default_rules,
    mesh_rules,
    param_specs,
)

__all__ = [
    "MeshRules", "SHARD_MAP_IMPL", "cache_specs", "camera_mesh",
    "combine_partials", "constrain", "context_parallel_decode_attention",
    "current_rules", "decode_attention_partial", "default_rules",
    "mesh_rules", "param_specs", "shard_map",
]
