"""DET — determinism rules.

The platform's replay contract (seed-0 goldens, journal exact-recovery,
mega-step bit-identity) dies the moment event order or float accumulation
order depends on anything but the seed.  These rules catch the classic
order/entropy leaks at review time:

* DET001 — iteration over a *syntactically unordered* collection (a set
  display, ``set()``/``frozenset()`` call, set comprehension, or a union of
  them) feeding event scheduling or float accumulation, in the scheduling
  planes (``core/``, ``sim/``, ``query/``).  Python sets iterate in hash
  order, which varies across runs/processes for str keys — dicts are
  insertion-ordered and fine.
* DET002 — wall-clock reads (``time.time``, ``datetime.now``, ...).  All
  timing goes through :func:`repro.core.clock.monotonic`; simulation time
  comes from the DES.  Benchmark-legit call sites carry explicit
  suppressions.
* DET003 — unseeded *global* RNG (``random.random()``,
  ``np.random.rand()``, ``np.random.seed``): process-global entropy that no
  ``seed=`` config reaches.  Seeded generator objects
  (``random.Random(s)``, ``np.random.default_rng(s)``) are the sanctioned
  pattern.
* DET004 — ``id()``/``hash()`` used as a sort key: CPython ``id`` is an
  address and str ``hash`` is salted per process, so the resulting order is
  not replayable.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set, Tuple

from .engine import Finding, SourceModule, register

#: Packages whose iteration order feeds the event calendar / accounting.
_DET001_SCOPE = ("core/", "sim/", "query/")

#: Calls that put work on the event calendar (scheduling sinks).
_SCHEDULE_FNS = {"schedule", "heappush", "push_event", "submit", "arrive"}

#: Module-level wall-clock reads: (module, attr).
_WALL_FNS = {
    ("time", "time"),
    ("time", "time_ns"),
    ("time", "ctime"),
    ("time", "localtime"),
    ("time", "gmtime"),
    ("datetime", "now"),
    ("datetime", "utcnow"),
    ("datetime", "today"),
    ("date", "today"),
}

#: np.random.<attr> calls that are NOT the global RNG (constructors of
#: explicitly-seeded generators and bit generators).
_NP_RANDOM_OK = {
    "default_rng",
    "Generator",
    "RandomState",
    "SeedSequence",
    "PCG64",
    "PCG64DXSM",
    "Philox",
    "MT19937",
    "SFC64",
    "BitGenerator",
}

#: random.<attr> that construct an independent, seedable generator.
_PY_RANDOM_OK = {"Random", "SystemRandom", "getstate", "setstate"}


def _is_set_expr(node: ast.AST) -> bool:
    """True for expressions that are unordered by construction."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        fn = node.func
        name = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else None
        )
        if name in ("set", "frozenset", "union", "intersection", "difference",
                    "symmetric_difference"):
            return True
    if isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)
    ):
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


def _call_name(node: ast.Call) -> Optional[str]:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def _body_sinks(nodes) -> Iterator[Tuple[ast.AST, str]]:
    """Yield (node, kind) for scheduling calls / float accumulation inside a
    loop body."""
    for stmt in nodes:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                name = _call_name(node)
                if name in _SCHEDULE_FNS:
                    yield node, f"schedules events ({name})"
                elif name == "sum":
                    yield node, "accumulates (sum)"
            elif isinstance(node, ast.AugAssign) and isinstance(
                node.op, (ast.Add, ast.Sub)
            ):
                yield node, "accumulates (+=)"


@register(
    "DET001",
    "unordered set iteration feeding event scheduling or float accumulation",
)
def det001(mod: SourceModule) -> Iterator[Finding]:
    if not mod.in_packages(*_DET001_SCOPE):
        return
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.For, ast.AsyncFor)) and _is_set_expr(node.iter):
            for _sink, kind in _body_sinks(node.body):
                yield mod.finding(
                    "DET001",
                    node,
                    f"loop over an unordered set {kind}: set iteration order "
                    "is not replayable — sort it or keep an ordered dict",
                )
                break
        # sum(<genexp over a set>) — accumulation order is the hash order.
        if isinstance(node, ast.Call) and _call_name(node) == "sum":
            for arg in node.args[:1]:
                if isinstance(arg, (ast.GeneratorExp, ast.ListComp)) and any(
                    _is_set_expr(gen.iter) for gen in arg.generators
                ):
                    yield mod.finding(
                        "DET001",
                        node,
                        "float accumulation over an unordered set: reduction "
                        "order is not replayable — sort the iterable",
                    )


def _import_aliases(tree: ast.AST) -> Dict[str, Tuple[str, str]]:
    """local name -> (module, attr) for `from X import Y [as Z]`; attr '' for
    plain `import X [as Z]`."""
    out: Dict[str, Tuple[str, str]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (a.name, "")
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = (node.module, a.name)
    return out


@register("DET002", "wall-clock read outside the monotonic clock helper")
def det002(mod: SourceModule) -> Iterator[Finding]:
    aliases = _import_aliases(mod.tree)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        hit: Optional[Tuple[str, str]] = None
        if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
            base = aliases.get(fn.value.id, (fn.value.id, ""))[0].split(".")[-1]
            if (base, fn.attr) in _WALL_FNS:
                hit = (base, fn.attr)
        elif isinstance(fn, ast.Name) and fn.id in aliases:
            module, attr = aliases[fn.id]
            if (module.split(".")[-1], attr) in _WALL_FNS:
                hit = (module.split(".")[-1], attr)
        if hit:
            yield mod.finding(
                "DET002",
                node,
                f"wall-clock read {hit[0]}.{hit[1]}(): use "
                "repro.core.clock.monotonic() for timing (sim time comes "
                "from the DES)",
            )


@register("DET003", "unseeded global RNG")
def det003(mod: SourceModule) -> Iterator[Finding]:
    aliases = _import_aliases(mod.tree)
    np_names = {
        local
        for local, (module, attr) in aliases.items()
        if module == "numpy" and attr == ""
    } | {"numpy"}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        # random.<f>() on the module-global RNG
        if isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name):
            base_mod = aliases.get(fn.value.id, (None, None))[0]
            if (
                (base_mod == "random" or fn.value.id == "random")
                and fn.attr not in _PY_RANDOM_OK
            ):
                yield mod.finding(
                    "DET003",
                    node,
                    f"global RNG random.{fn.attr}(): process-global entropy "
                    "no seed= reaches — use random.Random(seed)",
                )
                continue
        # np.random.<f>()
        if (
            isinstance(fn, ast.Attribute)
            and isinstance(fn.value, ast.Attribute)
            and fn.value.attr == "random"
            and isinstance(fn.value.value, ast.Name)
            and fn.value.value.id in np_names
            and fn.attr not in _NP_RANDOM_OK
        ):
            yield mod.finding(
                "DET003",
                node,
                f"global RNG np.random.{fn.attr}(): use "
                "np.random.default_rng(seed)",
            )
            continue
        # from random import random/randint/... ; bare call
        if isinstance(fn, ast.Name) and fn.id in aliases:
            module, attr = aliases[fn.id]
            if module == "random" and attr and attr not in _PY_RANDOM_OK:
                yield mod.finding(
                    "DET003",
                    node,
                    f"global RNG random.{attr}(): use random.Random(seed)",
                )


def _key_uses_object_hash(key: ast.AST) -> Optional[str]:
    if isinstance(key, ast.Name) and key.id in ("id", "hash"):
        return key.id
    if isinstance(key, ast.Lambda):
        for node in ast.walk(key.body):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                if node.func.id in ("id", "hash"):
                    return node.func.id
    return None


@register("DET004", "id()/object-hash sort key")
def det004(mod: SourceModule) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name not in ("sorted", "sort", "min", "max", "nsmallest", "nlargest"):
            continue
        for kw in node.keywords:
            if kw.arg != "key":
                continue
            used = _key_uses_object_hash(kw.value)
            if used:
                yield mod.finding(
                    "DET004",
                    node,
                    f"sort key uses {used}(): object identity/hash order is "
                    "per-process, not replayable — sort on a stable field",
                )
