"""CLI front door: ``python -m repro.analysis [paths...]``.

Exit codes: 0 = clean (modulo baseline), 1 = new findings, 2 = usage /
baseline error.  ``--write-baseline`` snapshots the current findings as
the new baseline (every entry then needs a human-written justification —
``load_baseline`` rejects entries without one).
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Optional

from .engine import (
    filter_baselined,
    iter_py_files,
    load_baseline,
    rule_catalog,
    save_baseline,
    scan_paths,
)

DEFAULT_BASELINE = "ANALYSIS_BASELINE.json"


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Replay-safety static analyzer (DET/JAX/EXC/KRN rules).",
    )
    parser.add_argument("paths", nargs="*", default=["src/repro"],
                        help="files or trees to scan (default: src/repro)")
    parser.add_argument("--baseline", default=None,
                        help=f"baseline JSON (default: {DEFAULT_BASELINE} "
                             "when it exists)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="snapshot current findings as the baseline")
    parser.add_argument("--select", default=None,
                        help="comma-separated rule ids to run (e.g. "
                             "DET002,EXC001)")
    parser.add_argument("--tests", default="tests",
                        help="test tree for the KRN004 reference check")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rid, desc in rule_catalog().items():
            print(f"{rid}  {desc}")
        return 0

    paths = args.paths or ["src/repro"]
    for p in paths:
        if not os.path.exists(p):
            print(f"error: no such path {p!r}", file=sys.stderr)
            return 2

    select = (
        {r.strip() for r in args.select.split(",") if r.strip()}
        if args.select
        else None
    )
    tests_dir = args.tests if os.path.isdir(args.tests) else None

    t0 = time.perf_counter()
    findings = scan_paths(paths, select=select, tests_dir=tests_dir)
    wall = time.perf_counter() - t0

    baseline_path = args.baseline or (
        DEFAULT_BASELINE if os.path.exists(DEFAULT_BASELINE) else None
    )
    if args.write_baseline:
        out = args.baseline or DEFAULT_BASELINE
        save_baseline(out, findings)
        print(f"wrote {len(findings)} finding(s) to {out} "
              "(add a justification to every entry)")
        return 0

    baseline: List[dict] = []
    if baseline_path:
        try:
            baseline = load_baseline(baseline_path)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2

    new, stale = filter_baselined(findings, baseline)
    for f in new:
        print(f.render())
    n_files = sum(1 for root in paths for _ in iter_py_files(root))
    suppressed = len(findings) - len(new)
    print(
        f"[repro.analysis] {n_files} files, {len(new)} new finding(s)"
        + (f", {suppressed} baselined" if suppressed else "")
        + (f", {len(stale)} stale baseline entr"
           f"{'y' if len(stale) == 1 else 'ies'} (prune them)" if stale else "")
        + f" in {wall:.2f}s"
    )
    for e in stale:
        print(f"  stale: {e['rule']} {e['path']}:{e['line']}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
