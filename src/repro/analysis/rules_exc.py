"""EXC — silent exception swallows.

A broad ``except Exception:`` that neither re-raises, records, nor even
*looks at* the exception turns a real failure (a kernel backend dying, a
cache write failing, a corrupted plan) into silence — the exact failure
mode the fault plane exists to surface.  EXC001 flags handlers that catch
broadly and drop the exception on the floor; a genuinely-intended broad
catch keeps the behaviour with a ``# repro: noqa[EXC001]`` + justification
and, ideally, a recorded reason (counter, ``last_error()`` accessor).
"""

from __future__ import annotations

import ast
from typing import Iterator

from .engine import Finding, SourceModule, register

_BROAD = {"Exception", "BaseException"}


def _catches_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:  # bare except:
        return True
    types = t.elts if isinstance(t, ast.Tuple) else [t]
    for node in types:
        name = (
            node.id
            if isinstance(node, ast.Name)
            else node.attr
            if isinstance(node, ast.Attribute)
            else None
        )
        if name in _BROAD:
            return True
    return False


def _is_silent(handler: ast.ExceptHandler) -> bool:
    """Silent = no re-raise anywhere in the body AND the bound exception
    (if any) is never read.  Printing/logging/recording the exception, or
    ``raise``-ing anything, counts as handling it."""
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return False
        if (
            handler.name
            and isinstance(node, ast.Name)
            and node.id == handler.name
            and isinstance(node.ctx, ast.Load)
        ):
            return False
    return True


@register("EXC001", "broad except that silently swallows the exception")
def exc001(mod: SourceModule) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if _catches_broad(node) and _is_silent(node):
            caught = "bare except" if node.type is None else "except Exception"
            yield mod.finding(
                "EXC001",
                node,
                f"{caught} swallows the failure silently: narrow the type, "
                "re-raise, or record the error (noqa + justification if the "
                "broad catch is genuinely intended)",
            )
