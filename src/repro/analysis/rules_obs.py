"""OBS — observability-plane contract checks.

The obs plane (``repro.obs``) promises that every metric the tree emits is
*discoverable*: a static scan can enumerate the full metric catalog, with
help text, without running anything.  That only holds if registrations are
literal:

* OBS001 — every ``.counter(...)`` / ``.gauge(...)`` / ``.histogram(...)``
  registration call must pass a **string-literal** metric name matching
  ``^repro_[a-z][a-z0-9_]*$`` and a **non-empty literal help string**
  (second positional argument or ``help=``).  A computed name or missing
  help text makes the metric invisible to static catalog tooling (and to
  reviewers deciding which determinism domain it belongs in).

``obs/metrics.py`` itself is exempt — it *defines* the registration
surface; its ``counter``/``gauge``/``histogram`` are method definitions and
internal plumbing, not emissions.
"""

from __future__ import annotations

import ast
import re
from typing import Iterator, Optional

from .engine import Finding, SourceModule, register

#: Metric name contract — mirrors ``repro.obs.metrics._NAME_RE``.
_NAME_RE = re.compile(r"^repro_[a-z][a-z0-9_]*$")

#: Registry methods that mint a new metric family.
_REGISTER_FNS = ("counter", "gauge", "histogram")

#: The module that defines the registration surface (exempt).
_EXEMPT_SUFFIX = "obs/metrics.py"


def _str_literal(node: Optional[ast.AST]) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _help_arg(node: ast.Call) -> Optional[ast.AST]:
    """The help-text argument: second positional, or ``help=`` keyword."""
    for kw in node.keywords:
        if kw.arg == "help":
            return kw.value
    if len(node.args) >= 2:
        return node.args[1]
    return None


@register(
    "OBS001",
    "metric registration must use a literal repro_* name with help text",
)
def obs001(mod: SourceModule) -> Iterator[Finding]:
    if mod.path.replace("\\", "/").endswith(_EXEMPT_SUFFIX):
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if not (isinstance(fn, ast.Attribute) and fn.attr in _REGISTER_FNS):
            continue
        name_node = node.args[0] if node.args else None
        for kw in node.keywords:
            if kw.arg == "name":
                name_node = kw.value
        if name_node is None:
            # No name argument at all — not a registration call shape we
            # can audit; most likely an unrelated API (e.g. itertools-style
            # ``.counter()``).  Zero-arg calls are ignored.
            continue
        name = _str_literal(name_node)
        if name is None:
            yield mod.finding(
                "OBS001",
                node,
                f"metric name passed to .{fn.attr}() is not a string "
                "literal: computed names are invisible to the static "
                "metric catalog — register with a literal repro_* name",
            )
            continue
        if not _NAME_RE.match(name):
            yield mod.finding(
                "OBS001",
                node,
                f"metric name {name!r} does not match "
                "^repro_[a-z][a-z0-9_]*$ — all obs-plane metrics share the "
                "repro_ namespace",
            )
        help_text = _str_literal(_help_arg(node))
        if not help_text:
            yield mod.finding(
                "OBS001",
                node,
                f"metric {name!r} registered without literal help text: "
                "pass a non-empty help string (second argument or help=)",
            )
