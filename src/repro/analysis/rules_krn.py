"""KRN — kernel-contract checks (tree-level).

Every ``kernels/<name>/`` package follows one contract, and the whole
bit-exactness story hangs off it:

* KRN001 — the package is a **kernel/ops/ref triple**: ``kernel.py`` (the
  Pallas kernel), ``ops.py`` (the dispatch wrapper), ``ref.py`` (the pure
  host reference the kernel is bit-checked against).
* KRN002 — ``ref.py`` is a *reference*: it parses, defines at least one
  function, and never imports Pallas (a ref that needs the kernel stack
  cannot arbitrate the kernel's correctness).
* KRN003 — ``kernel.py`` is **interpret-gated**: it exposes an
  ``interpret`` parameter and threads it into ``pallas_call`` so the
  kernel runs (and is tested) on CPU in interpret mode.
* KRN004 — the kernel is referenced by at least one test module (the
  bit-exactness gate actually exists).

These are directory-shape checks, so they run once per scanned
``kernels/`` root rather than per file; findings anchor on the offending
file (line 1) and are suppressed via the baseline, not ``noqa``.
"""

from __future__ import annotations

import ast
import os
from typing import Iterator, List, Optional

from .engine import Finding, SourceModule, register

_TRIPLE = ("kernel.py", "ops.py", "ref.py")


def _noop(mod: SourceModule):
    """KRN rules are tree-level; the per-module hook only exists so the ids
    show up in the rule catalog (see :func:`check_kernel_tree`)."""
    return ()


register("KRN001", "kernels/<name>/ must be a kernel/ops/ref triple")(_noop)
register("KRN002", "ref.py must be an importable pure-host reference")(_noop)
register("KRN003", "kernel.py must be interpret-gated for CPU")(_noop)
register("KRN004", "kernel must be referenced by at least one test")(_noop)


def _posix(path: str) -> str:
    return path.replace(os.sep, "/")


def _check_ref(path: str) -> Iterator[Finding]:
    with open(path, "r", encoding="utf-8") as fh:
        text = fh.read()
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        yield Finding(
            "KRN002", _posix(path), int(e.lineno or 1),
            f"ref.py does not parse: {e.msg}",
        )
        return
    has_fn = any(
        isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        for n in ast.walk(tree)
    )
    if not has_fn:
        yield Finding(
            "KRN002", _posix(path), 1,
            "ref.py defines no function: nothing to bit-check the kernel "
            "against",
        )
    for node in ast.walk(tree):
        names: List[str] = []
        if isinstance(node, ast.Import):
            names = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            names = [node.module] + [a.name for a in node.names]
        if any("pallas" in n for n in names):
            yield Finding(
                "KRN002", _posix(path), node.lineno,
                "ref.py imports pallas: the host reference must not depend "
                "on the kernel stack it arbitrates",
            )


def _check_kernel(path: str) -> Iterator[Finding]:
    with open(path, "r", encoding="utf-8") as fh:
        text = fh.read()
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        yield Finding(
            "KRN003", _posix(path), int(e.lineno or 1),
            f"kernel.py does not parse: {e.msg}",
        )
        return
    has_interpret_param = False
    passes_interpret = False
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            all_args = args.args + args.kwonlyargs + args.posonlyargs
            if any(a.arg == "interpret" for a in all_args):
                has_interpret_param = True
        if isinstance(node, ast.Call):
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else None
            )
            if name == "pallas_call" and any(
                kw.arg == "interpret" for kw in node.keywords
            ):
                passes_interpret = True
    if not (has_interpret_param and passes_interpret):
        yield Finding(
            "KRN003", _posix(path), 1,
            "kernel.py is not interpret-gated: expose interpret= and thread "
            "it into pallas_call so the kernel runs on CPU",
        )


def _tests_reference(name: str, tests_dir: str) -> bool:
    for dirpath, dirnames, filenames in os.walk(tests_dir):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fname in filenames:
            if not (fname.startswith("test") and fname.endswith(".py")):
                continue
            with open(os.path.join(dirpath, fname), "r", encoding="utf-8") as fh:
                if name in fh.read():
                    return True
    return False


def check_kernel_tree(
    kernels_root: str, *, tests_dir: Optional[str] = None
) -> Iterator[Finding]:
    """Run KRN001-KRN004 over one ``kernels/`` package root.

    ``tests_dir`` points at the test tree for KRN004; when it is missing
    (e.g. scanning an installed package) the reference check is skipped.
    """
    root = _posix(kernels_root.rstrip("/"))
    for entry in sorted(os.listdir(kernels_root)):
        pkg = os.path.join(kernels_root, entry)
        if not os.path.isdir(pkg) or entry == "__pycache__":
            continue
        if not any(f.endswith(".py") for f in os.listdir(pkg)):
            continue
        missing = [f for f in _TRIPLE if not os.path.exists(os.path.join(pkg, f))]
        if missing:
            yield Finding(
                "KRN001", f"{root}/{entry}", 1,
                f"kernel package is missing {', '.join(missing)}: every "
                "kernels/<name>/ is a kernel/ops/ref triple",
            )
        ref = os.path.join(pkg, "ref.py")
        if os.path.exists(ref):
            yield from _check_ref(ref)
        kern = os.path.join(pkg, "kernel.py")
        if os.path.exists(kern):
            yield from _check_kernel(kern)
        if tests_dir and os.path.isdir(tests_dir):
            if not _tests_reference(entry, tests_dir):
                yield Finding(
                    "KRN004", f"{root}/{entry}", 1,
                    f"kernel '{entry}' is referenced by no test module: the "
                    "bit-exactness gate does not exist",
                )
