"""JAX — device-hygiene rules.

The kernel plane keeps two hard promises: compile caches stay *bounded*
(every padded kernel and the mega-step scan register their bucket shapes
with ``repro.kernels.dispatch.bound_jit_cache``), and results stay
*bit-identical* to the host references (float accounting in the mega-step
engine is f64 in reference order).  These rules catch the constructions
that silently break either promise:

* JAX001 — ``jax.jit(...)`` / ``pallas_call(...)`` constructed inside a
  function body in the hot planes.  A fresh jit object per call means a
  fresh compile cache per call: unbounded compilation that the
  ``bound_jit_cache`` LRU never sees.  Module-scope construction
  (decorators, module-level assignment) is fine; modules that register
  with ``bound_jit_cache`` own their caching and are exempt, as is
  ``kernels/<name>/kernel.py`` (the sanctioned Pallas definition site,
  covered by the KRN interpret-gate contract).
* JAX002 — implicit host pulls (``.item()``, ``float(x)``,
  ``np.asarray``/``np.array``, ``jax.device_get``,
  ``.block_until_ready()``) inside *traced* code: jit-decorated functions,
  functions handed to ``jax.jit``/``lax.scan``, and their nested helpers.
  Inside a trace these either fail on tracers or silently fall back to
  host round-trips per step.
* JAX003 — f32 accumulation where the mega-step f64 reference-order
  accounting contract applies (``kernels/megastep/``, ``core/megastep.py``):
  an f32 dtype on an accumulation constructor or ``.astype`` breaks
  bit-identity with the interpreted pipeline.
* JAX004 — un-shimmed ``shard_map``: jax renamed both the entry point
  (``jax.experimental.shard_map`` -> ``jax.shard_map``) and the
  replication-checker kwarg (``check_rep`` -> ``check_vma``); every use
  must route through ``repro.distributed.compat.shard_map``, which probes
  once at import time.  Direct imports re-inline the version shim per call
  site — the bug this rule exists to keep fixed.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from .engine import Finding, SourceModule, register

_HOT_SCOPE = ("core/", "sim/", "query/", "kernels/", "serving/")
_F64_SCOPE = ("kernels/megastep/", "core/megastep.py")

#: Accumulation constructors whose dtype fixes the reduction precision.
_ACC_FNS = {"zeros", "ones", "full", "asarray", "array", "sum", "cumsum",
            "dot", "einsum", "add", "matmul"}


def _is_jit_or_pallas(fn: ast.AST) -> Optional[str]:
    if isinstance(fn, ast.Attribute):
        if fn.attr in ("jit", "pallas_call"):
            return fn.attr
        return None
    if isinstance(fn, ast.Name) and fn.id in ("jit", "pallas_call"):
        return fn.id
    return None


class _JitConstructionVisitor(ast.NodeVisitor):
    """Collect jit/pallas constructions that happen inside a function body
    (decorator lists are visited at the *enclosing* depth: a ``@jax.jit``
    decorator is a one-time module/scope-level construction)."""

    def __init__(self) -> None:
        self.depth = 0
        self.hits: List[ast.Call] = []

    def _visit_fn(self, node) -> None:
        for dec in node.decorator_list:
            self.visit(dec)
        self.depth += 1
        for stmt in node.body:
            self.visit(stmt)
        self.depth -= 1

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_Call(self, node: ast.Call) -> None:
        kind = _is_jit_or_pallas(node.func)
        if kind and self.depth > 0:
            self.hits.append(node)
        self.generic_visit(node)


@register(
    "JAX001",
    "jit/pallas_call constructed outside the bound_jit_cache contract",
)
def jax001(mod: SourceModule) -> Iterator[Finding]:
    if not mod.in_packages(*_HOT_SCOPE):
        return
    if "bound_jit_cache" in mod.text:
        return  # dispatch-contract module: owns its cache registration
    parts = mod.pkgpath.split("/")
    if len(parts) == 3 and parts[0] == "kernels" and parts[2] == "kernel.py":
        return  # sanctioned Pallas definition site (KRN003 gates interpret)
    visitor = _JitConstructionVisitor()
    visitor.visit(mod.tree)
    for call in visitor.hits:
        kind = _is_jit_or_pallas(call.func)
        yield mod.finding(
            "JAX001",
            call,
            f"{kind}(...) constructed inside a function body: a fresh "
            "compile cache per call, invisible to dispatch.bound_jit_cache — "
            "construct at module scope or register the shape with "
            "bound_jit_cache",
        )


def _traced_functions(tree: ast.AST) -> Set[ast.AST]:
    """Function defs that run under a jax trace: jit-decorated, or passed
    (by name) as the first argument to jit/lax.scan, plus every function
    nested inside one of those."""
    by_name = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, node)
    traced: Set[ast.AST] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                # @jax.jit, @jit, @functools.partial(jax.jit, ...)
                if _is_jit_or_pallas(target) == "jit":
                    traced.add(node)
                elif (
                    isinstance(dec, ast.Call)
                    and isinstance(target, ast.Attribute)
                    and target.attr == "partial"
                    and dec.args
                    and _is_jit_or_pallas(dec.args[0]) == "jit"
                ):
                    traced.add(node)
        if isinstance(node, ast.Call):
            name = (
                node.func.attr
                if isinstance(node.func, ast.Attribute)
                else node.func.id
                if isinstance(node.func, ast.Name)
                else None
            )
            if name in ("jit", "scan", "fori_loop", "while_loop", "cond"):
                for arg in node.args:
                    if isinstance(arg, ast.Name) and arg.id in by_name:
                        traced.add(by_name[arg.id])
    # Close over nesting: helpers defined inside a traced fn are traced.
    closed: Set[ast.AST] = set()
    for fn in traced:
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                closed.add(node)
    return closed


_PULL_ATTRS = {"item", "block_until_ready", "device_get"}
_NP_PULL_FNS = {"asarray", "array"}


@register("JAX002", "implicit host pull in traced (scan-adjacent) code")
def jax002(mod: SourceModule) -> Iterator[Finding]:
    if not mod.in_packages(*_HOT_SCOPE):
        return
    traced = _traced_functions(mod.tree)
    seen: Set[int] = set()
    for fn in traced:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call) or id(node) in seen:
                continue
            seen.add(id(node))
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr in _PULL_ATTRS:
                # jnp.asarray(...).item() etc.; device_get via jax.device_get
                yield mod.finding(
                    "JAX002",
                    node,
                    f".{f.attr}() inside traced code pulls to host per "
                    "step — keep the value on device and pull after the "
                    "scan/jit boundary",
                )
            elif (
                isinstance(f, ast.Attribute)
                and f.attr in _NP_PULL_FNS
                and isinstance(f.value, ast.Name)
                and f.value.id in ("np", "numpy")
            ):
                yield mod.finding(
                    "JAX002",
                    node,
                    f"np.{f.attr}(...) inside traced code forces a host "
                    "round-trip (or fails on tracers) — use jnp",
                )
            elif (
                isinstance(f, ast.Name)
                and f.id == "float"
                and node.args
                and not isinstance(node.args[0], ast.Constant)
            ):
                yield mod.finding(
                    "JAX002",
                    node,
                    "float(x) inside traced code concretizes a tracer "
                    "(host pull / trace error) — keep it an array",
                )


def _is_f32(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and node.value == "float32":
        return True
    if isinstance(node, ast.Attribute) and node.attr == "float32":
        return True
    if isinstance(node, ast.Name) and node.id == "float32":
        return True
    return False


@register(
    "JAX003",
    "f32 accumulation where the mega-step f64 reference-order contract applies",
)
def jax003(mod: SourceModule) -> Iterator[Finding]:
    if not mod.in_packages(*_F64_SCOPE):
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "astype":
            if any(_is_f32(a) for a in node.args):
                yield mod.finding(
                    "JAX003",
                    node,
                    ".astype(float32) in the mega-step plane: float "
                    "accounting is f64 in reference order (bit-identity "
                    "contract with the interpreted pipeline)",
                )
            continue
        name = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else None
        )
        if name not in _ACC_FNS:
            continue
        for kw in node.keywords:
            if kw.arg == "dtype" and _is_f32(kw.value):
                yield mod.finding(
                    "JAX003",
                    node,
                    f"{name}(dtype=float32) in the mega-step plane: "
                    "accumulators must be f64 (reference-order accounting "
                    "contract)",
                )


_SHIM = "distributed/compat.py"


@register(
    "JAX004",
    "shard_map imported/used outside the distributed.compat version shim",
)
def jax004(mod: SourceModule) -> Iterator[Finding]:
    if mod.pkgpath == _SHIM:
        return  # the shim itself: the one sanctioned probe site
    msg = (
        "direct shard_map use re-inlines the jax version shim "
        "(jax.shard_map/check_vma vs jax.experimental.shard_map/check_rep) "
        "— import it from repro.distributed.compat instead"
    )
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom):
            module = node.module or ""
            if module.startswith("jax") and (
                "shard_map" in module
                or any(a.name == "shard_map" for a in node.names)
            ):
                yield mod.finding("JAX004", node, msg)
        elif isinstance(node, ast.Import):
            for a in node.names:
                if "shard_map" in a.name:
                    yield mod.finding("JAX004", node, msg)
        elif (
            isinstance(node, ast.Attribute)
            and node.attr == "shard_map"
            and isinstance(node.value, ast.Name)
            and node.value.id == "jax"
        ):
            yield mod.finding("JAX004", node, msg)
