"""Replay-safety analyzer: determinism, JAX-hygiene, and kernel-contract
static analysis for the tracking platform.

The dynamic gates (seed-0 goldens, journal digests, mega-step bit-identity
tests) catch a determinism violation *after* it lands; this package checks
the underlying invariants at review time:

* ``python -m repro.analysis src/repro`` — scan the tree (rule families
  DET/JAX/EXC/KRN), honoring ``# repro: noqa[RULE]`` suppressions and the
  checked-in ``ANALYSIS_BASELINE.json`` so CI gates *new* violations.
* :mod:`repro.analysis.graphcheck` — the compile-time dataflow-graph
  verifier (GRF rules), wired into ``compile_app(..., verify=True)``.

See ``ANALYSIS.md`` at the repo root for the rule catalog.
"""

from .engine import (
    Finding,
    SourceModule,
    filter_baselined,
    load_baseline,
    rule_catalog,
    save_baseline,
    scan_paths,
    scan_source,
)
from .graphcheck import (
    GraphContractError,
    check_compiled,
    verify_compiled,
    verify_megastep,
)

__all__ = [
    "Finding",
    "SourceModule",
    "GraphContractError",
    "check_compiled",
    "filter_baselined",
    "load_baseline",
    "rule_catalog",
    "save_baseline",
    "scan_paths",
    "scan_source",
    "verify_compiled",
    "verify_megastep",
]
