"""Rule engine for the replay-safety analyzer.

Every guarantee the platform ships — seed-0 bit-identical goldens, the
journal's exact-recovery contract, the mega-step engine's bit-identity to
the interpreted pipeline — rests on determinism and device-hygiene
invariants that the golden digests only catch *after* a violation lands.
This module is the static half of that contract: an AST-based scanner with

* a rule registry (``DET``/``JAX``/``EXC`` per-file families plus the
  ``KRN`` kernel-contract tree checks in :mod:`.rules_krn`),
* ``# repro: noqa[RULE]`` line suppressions (same line, or an immediately
  preceding pure-comment line, so a justification can sit above the code),
* a checked-in JSON baseline so CI gates *new* violations only.

The CLI front door is :mod:`repro.analysis.__main__`; the compile-time
dataflow-graph verifier lives in :mod:`repro.analysis.graphcheck`.
"""

from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Finding",
    "SourceModule",
    "register",
    "rule_catalog",
    "scan_source",
    "scan_paths",
    "load_baseline",
    "save_baseline",
    "filter_baselined",
]


# --------------------------------------------------------------------- #
# Findings                                                               #
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str          # e.g. "DET002"
    path: str          # path as scanned (posix separators)
    line: int          # 1-based physical line
    message: str

    def key(self) -> Tuple[str, str, int]:
        return (self.rule, self.path, self.line)

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclass
class SourceModule:
    """A parsed source file plus everything rules need to scope themselves."""

    path: str           # as given to the scanner (posix)
    pkgpath: str        # path relative to the `repro` package root ("" if outside)
    text: str
    tree: ast.AST
    lines: List[str] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.lines:
            self.lines = self.text.splitlines()

    def in_packages(self, *prefixes: str) -> bool:
        """True when the module lives under any ``repro/<prefix>`` subtree
        (``prefixes`` are posix, e.g. ``"core/"`` or ``"kernels/megastep/"``
        or an exact file like ``"core/megastep.py"``)."""
        return any(
            self.pkgpath == p or self.pkgpath.startswith(p) for p in prefixes
        )

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(rule, self.path, int(line), message)


# --------------------------------------------------------------------- #
# Rule registry                                                          #
# --------------------------------------------------------------------- #
#: rule id -> (one-line description, per-module check)
_RULES: Dict[str, Tuple[str, Callable[[SourceModule], Iterable[Finding]]]] = {}


def register(rule_id: str, description: str):
    """Decorator: register a per-module check under ``rule_id``.  A check
    receives a :class:`SourceModule` and yields :class:`Finding`\\ s; it is
    free to yield findings for related sub-ids (``KRN00x``) too."""

    def wrap(fn):
        _RULES[rule_id] = (description, fn)
        return fn

    return wrap


def rule_catalog() -> Dict[str, str]:
    """rule id -> description, for ``--list-rules`` and the docs test."""
    _load_rule_modules()
    return {rid: desc for rid, (desc, _) in sorted(_RULES.items())}


_LOADED = False


def _load_rule_modules() -> None:
    global _LOADED
    if _LOADED:
        return
    # Import for side effect: each module registers its rules.
    from . import rules_det, rules_exc, rules_jax, rules_krn, rules_obs  # noqa: F401

    _LOADED = True


# --------------------------------------------------------------------- #
# Suppressions                                                           #
# --------------------------------------------------------------------- #
_NOQA_RE = re.compile(r"#\s*repro:\s*noqa\[([A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)\]")


def _suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    """line (1-based) -> rule ids suppressed on that line.

    A ``# repro: noqa[RULE]`` on a pure-comment line also covers the next
    line, so a justification comment can sit directly above the flagged
    statement.
    """
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _NOQA_RE.search(line)
        if not m:
            continue
        ids = {r.strip() for r in m.group(1).split(",")}
        out.setdefault(i, set()).update(ids)
        if line.lstrip().startswith("#"):  # pure comment: covers the code below
            out.setdefault(i + 1, set()).update(ids)
    return out


# --------------------------------------------------------------------- #
# Scanning                                                               #
# --------------------------------------------------------------------- #
def _pkgpath(path: str) -> str:
    """Path relative to the last ``repro`` package component (posix)."""
    parts = path.replace(os.sep, "/").split("/")
    for i in range(len(parts) - 1, -1, -1):
        if parts[i] == "repro":
            return "/".join(parts[i + 1 :])
    # No package root in the path: treat the whole (relative) path as the
    # package path so fixture snippets can scope themselves directly.
    return "/".join(parts).lstrip("/")


def scan_source(
    text: str,
    path: str = "<string>",
    *,
    pkgpath: Optional[str] = None,
    select: Optional[Set[str]] = None,
) -> List[Finding]:
    """Scan one source string; the unit the fixture tests drive."""
    _load_rule_modules()
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding("PAR001", path, int(e.lineno or 1), f"syntax error: {e.msg}")]
    mod = SourceModule(
        path=path.replace(os.sep, "/"),
        pkgpath=pkgpath if pkgpath is not None else _pkgpath(path),
        text=text,
        tree=tree,
    )
    noqa = _suppressions(mod.lines)
    findings: List[Finding] = []
    for rid, (_desc, check) in sorted(_RULES.items()):
        if select and rid not in select:
            continue
        for f in check(mod):
            if f.rule in noqa.get(f.line, ()):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def iter_py_files(root: str) -> Iterator[str]:
    if os.path.isfile(root):
        yield root
        return
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(
            d for d in dirnames if d not in ("__pycache__", ".git")
        )
        for name in sorted(filenames):
            if name.endswith(".py"):
                yield os.path.join(dirpath, name)


def scan_paths(
    paths: Sequence[str],
    *,
    select: Optional[Set[str]] = None,
    tests_dir: Optional[str] = None,
) -> List[Finding]:
    """Scan files/trees; also runs the KRN tree checks for any scanned
    ``kernels/`` package root."""
    _load_rule_modules()
    findings: List[Finding] = []
    kernel_roots: List[str] = []
    for root in paths:
        for fp in iter_py_files(root):
            with open(fp, "r", encoding="utf-8") as fh:
                text = fh.read()
            findings.extend(scan_source(text, fp, select=select))
        # Tree-level kernel-contract checks need the directory layout.
        if os.path.isdir(root):
            cand = (
                root
                if os.path.basename(root.rstrip("/")) == "kernels"
                else os.path.join(root, "kernels")
            )
            if os.path.isdir(cand):
                kernel_roots.append(cand)
    from .rules_krn import check_kernel_tree

    for kroot in kernel_roots:
        for f in check_kernel_tree(kroot, tests_dir=tests_dir):
            if select and f.rule not in select:
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


# --------------------------------------------------------------------- #
# Baseline                                                               #
# --------------------------------------------------------------------- #
def load_baseline(path: str) -> List[dict]:
    """A baseline is a JSON list of ``{rule, path, line, justification}``
    entries; every entry MUST carry a non-empty justification — the
    baseline exists to grandfather *known* debt, not to hide findings."""
    if not os.path.exists(path):
        return []
    with open(path, "r", encoding="utf-8") as fh:
        entries = json.load(fh)
    if not isinstance(entries, list):
        raise ValueError(f"baseline {path}: expected a JSON list")
    for e in entries:
        if not isinstance(e, dict) or not {"rule", "path", "line"} <= set(e):
            raise ValueError(f"baseline {path}: malformed entry {e!r}")
        just = str(e.get("justification", "")).strip()
        if not just or just.upper().startswith("TODO"):
            raise ValueError(
                f"baseline {path}: entry {e['rule']} @ {e['path']}:{e['line']} "
                "has no justification (snapshot entries stay rejected until "
                "a human replaces the TODO)"
            )
    return entries


def save_baseline(path: str, findings: Sequence[Finding]) -> None:
    entries = [
        {
            "rule": f.rule,
            "path": f.path,
            "line": f.line,
            "message": f.message,
            "justification": "TODO: justify or fix",
        }
        for f in findings
    ]
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(entries, fh, indent=2)
        fh.write("\n")


def filter_baselined(
    findings: Sequence[Finding], baseline: Sequence[dict]
) -> Tuple[List[Finding], List[dict]]:
    """Split findings into (new, stale-baseline-entries).

    A finding matches a baseline entry on (rule, path, line).  Entries that
    no longer match anything are returned so the CLI can nag about pruning
    the baseline (stale entries are informational, not a failure).
    """
    keyed = {(e["rule"], e["path"].replace(os.sep, "/"), int(e["line"])) for e in baseline}
    new = [f for f in findings if f.key() not in keyed]
    found = {f.key() for f in findings}
    stale = [
        e
        for e in baseline
        if (e["rule"], e["path"].replace(os.sep, "/"), int(e["line"])) not in found
    ]
    return new, stale
