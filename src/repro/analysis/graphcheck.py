"""Compile-time dataflow-graph verifier (GRF rules).

The compiler (:mod:`repro.core.compile`) lowers a ``TrackingApp`` onto the
task DAG; this module *verifies* the lowered graph before a single event
runs, so a miswired app fails at compile time with a readable diagnostic
instead of at replay time with a digest mismatch:

* GRF001 — **edge compatibility**: VA tasks feed exactly the CR stage, CR
  feeds exactly the UV sink, no stage dangles, and every routing-table
  destination exists.
* GRF002 — **undeclared feedback**: the task graph must be acyclic.  The
  only sanctioned loop closure is the QF→VA/CR query-push control edge,
  which is a *state* push (not a ``downstream`` edge) — any cycle in the
  event-edge graph is an undeclared feedback loop.
* GRF003 — **fusion-gate consistency**: ``fuse_streaming``/``fuse_fc`` are
  only sound when drops are off and the sim's transit *and* xi are static
  (`xi_is_static`); a fused task under a dynamic-xi sim replays
  differently than it runs.
* GRF004 — **spec sanity**: unknown module names in ``app.specs``,
  non-callable logics, a TL without the TrackingLogic surface.
* GRF005 — **mega-step totality**: a config that *requests*
  ``engine="megastep"`` must classify to a backend or carry a recorded
  ``engine_fallback_reason`` — "no backend, no reason" is the unobservable
  state the engine contract forbids.

Entry points: :func:`verify_compiled` returns findings,
:func:`check_compiled` raises :class:`GraphContractError` with all of them
(used by ``compile_app(..., verify=True)`` and the
``REPRO_ANALYSIS_VERIFY=1`` env hook); :func:`verify_megastep` covers
GRF005 pre- and post-run.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional, Set

from .engine import Finding

__all__ = [
    "GraphContractError",
    "verify_compiled",
    "check_compiled",
    "verify_megastep",
]


class GraphContractError(Exception):
    """A compiled app violates the dataflow-graph contract; ``findings``
    holds every violation, the message renders all of them."""

    def __init__(self, findings: List[Finding]):
        self.findings = findings
        lines = [f"compiled app violates the dataflow-graph contract "
                 f"({len(findings)} finding{'s' if len(findings) != 1 else ''}):"]
        lines += [f"  - {f.rule}: {f.message}" for f in findings]
        super().__init__("\n".join(lines))


def _f(rule: str, app_name: str, message: str) -> Finding:
    return Finding(rule, f"<app:{app_name}>", 0, message)


# --------------------------------------------------------------------- #
# GRF001/GRF002: edges and cycles                                        #
# --------------------------------------------------------------------- #
def _check_edges(compiled, name: str) -> List[Finding]:
    out: List[Finding] = []
    cr_names = {t.name for t in compiled.cr_tasks}
    sink = compiled.sink
    sink_name = sink.name if sink is not None else None
    if sink is None:
        out.append(_f("GRF001", name, "compiled app has no UV sink"))
        return out
    for va in compiled.va_tasks:
        dst = set(va.downstream)
        if not dst:
            out.append(_f("GRF001", name, f"{va.name} has no downstream: "
                          "the VA stage dangles (events die on the floor)"))
        elif dst != cr_names:
            out.append(_f(
                "GRF001", name,
                f"{va.name} feeds {sorted(dst)} but the CR stage is "
                f"{sorted(cr_names)}: VA must feed exactly the CR tasks",
            ))
    for cr in compiled.cr_tasks:
        dst = set(cr.downstream)
        if dst != {sink_name}:
            out.append(_f(
                "GRF001", name,
                f"{cr.name} feeds {sorted(dst)}: CR must feed exactly the "
                f"UV sink ({sink_name!r})",
            ))
    for fc in compiled.fc_tasks.values():
        dst = set(fc.downstream)
        va_names = {t.name for t in compiled.va_tasks}
        if not dst <= va_names or not dst:
            out.append(_f(
                "GRF001", name,
                f"{fc.name} feeds {sorted(dst)}: FC must feed the VA stage "
                f"({sorted(va_names)})",
            ))
    if set(sink.downstream):
        out.append(_f(
            "GRF001", name,
            f"sink {sink_name} has downstream edges "
            f"{sorted(sink.downstream)}: the sink terminates the graph "
            "(feedback goes through the QF control edge, not an event edge)",
        ))
    # Routing tables must resolve inside the edge set.
    route = getattr(compiled, "_cr_route", None)
    if route:
        bad = sorted(set(route.values()) - cr_names)
        if bad:
            out.append(_f(
                "GRF001", name,
                f"VA->CR routing table targets missing tasks {bad}: every "
                "routed destination must exist",
            ))
    return out


def _find_cycle(tasks) -> Optional[List[str]]:
    graph: Dict[str, List[str]] = {
        t.name: sorted(t.downstream) for t in tasks
    }
    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in graph}
    stack: List[str] = []

    def dfs(n: str) -> Optional[List[str]]:
        color[n] = GREY
        stack.append(n)
        for m in graph.get(n, ()):
            if color.get(m, BLACK) == GREY:
                return stack[stack.index(m):] + [m]
            if color.get(m, BLACK) == WHITE:
                cyc = dfs(m)
                if cyc:
                    return cyc
        stack.pop()
        color[n] = BLACK
        return None

    for n in sorted(graph):
        if color[n] == WHITE:
            cyc = dfs(n)
            if cyc:
                return cyc
    return None


def _check_cycles(compiled, name: str) -> List[Finding]:
    tasks = list(compiled.all_tasks())
    if compiled.sink is not None:
        tasks.append(compiled.sink)
    cyc = _find_cycle(tasks)
    if cyc:
        return [_f(
            "GRF002", name,
            "undeclared feedback cycle " + " -> ".join(cyc) + ": only the "
            "QF->VA/CR query-push control edge may close a loop, and it is "
            "a state push, never an event edge",
        )]
    return []


# --------------------------------------------------------------------- #
# GRF003: fusion gates                                                   #
# --------------------------------------------------------------------- #
def _check_fusion(compiled, name: str) -> List[Finding]:
    out: List[Finding] = []
    sim = compiled.sim
    drops = compiled.deployment.drops_enabled
    transit_static = getattr(sim, "transit_is_static", False)
    xi_static = getattr(sim, "xi_is_static", True)
    fuse_ok = transit_static and xi_static
    for t in compiled.all_tasks():
        if getattr(t, "fuse_streaming", False) and (drops or not fuse_ok):
            why = (
                "drops are enabled" if drops
                else "xi is dynamic" if not xi_static
                else "transit is dynamic"
            )
            out.append(_f(
                "GRF003", name,
                f"{t.name} has fuse_streaming=True but {why}: fused "
                "execute+transmit is only sound with drops off and static "
                "transit/xi (xi_is_static)",
            ))
    if getattr(compiled, "fuse_fc", False):
        from ..core.dataflow import fc_is_active

        if compiled.app.fc is not fc_is_active:
            out.append(_f(
                "GRF003", name,
                "fuse_fc=True with a stateful FC logic: only the stateless "
                "fc_is_active gate may be fused into the source",
            ))
        if drops or not fuse_ok:
            out.append(_f(
                "GRF003", name,
                "fuse_fc=True under drops or dynamic transit/xi: the fused "
                "source plane precomputes transits and xi",
            ))
        if compiled.fps <= 0 or 1.0 / compiled.fps <= compiled.fc_xi1:
            out.append(_f(
                "GRF003", name,
                "fuse_fc=True but the frame period does not exceed "
                "xi_fc(1): the fused source would reorder FC completions",
            ))
    return out


# --------------------------------------------------------------------- #
# GRF004: spec sanity                                                    #
# --------------------------------------------------------------------- #
def _check_specs(app, name: str) -> List[Finding]:
    from ..core.compile import MODULES

    out: List[Finding] = []
    for module in getattr(app, "specs", {}):
        if module not in MODULES:
            out.append(_f(
                "GRF004", name,
                f"app.specs names unknown module {module!r}: the module "
                f"universe is {MODULES}",
            ))
    for logic_name in ("fc", "va", "cr"):
        logic = getattr(app, logic_name, None)
        if not callable(logic):
            out.append(_f(
                "GRF004", name,
                f"app.{logic_name} is not callable ({logic!r}): FC/VA/CR "
                "logics are required",
            ))
    qf = getattr(app, "qf", None)
    if qf is not None and not callable(qf):
        out.append(_f("GRF004", name, f"app.qf is not callable ({qf!r})"))
    tl = getattr(app, "tl", None)
    for attr in ("active", "last_seen_camera", "cameras_in_vertices"):
        if not hasattr(tl, attr):
            out.append(_f(
                "GRF004", name,
                f"app.tl lacks the TrackingLogic surface (missing "
                f"{attr!r}): the control plane cannot drive it",
            ))
            break
    return out


# --------------------------------------------------------------------- #
# Public API                                                             #
# --------------------------------------------------------------------- #
def verify_compiled(compiled) -> List[Finding]:
    """All GRF001-GRF004 findings for a :class:`CompiledApp` (empty =
    contract holds)."""
    name = getattr(compiled.app, "name", "?")
    findings = _check_specs(compiled.app, name)
    findings += _check_edges(compiled, name)
    findings += _check_cycles(compiled, name)
    findings += _check_fusion(compiled, name)
    return findings


def check_compiled(compiled) -> None:
    """Raise :class:`GraphContractError` when the compiled graph is
    miswired; the hook behind ``compile_app(..., verify=True)`` and
    ``REPRO_ANALYSIS_VERIFY=1``."""
    findings = verify_compiled(compiled)
    if findings:
        raise GraphContractError(findings)


def verify_megastep(scn, *, post_run: bool = False) -> List[Finding]:
    """GRF005: a scenario that requests ``engine="megastep"`` must map to a
    backend or record why not.

    Pre-run (default): classify via :func:`repro.core.megastep.
    megastep_backend` and reject the unobservable "no backend, no reason"
    state.  ``post_run=True`` additionally checks the recorded outcome
    (``engine_used`` / ``engine_fallback_reason``) after the run.
    """
    name = getattr(getattr(scn, "cfg", None), "engine", "?")
    out: List[Finding] = []
    cfg = getattr(scn, "cfg", None)
    if getattr(cfg, "engine", "interpreted") != "megastep":
        return out
    from ..core.megastep import megastep_backend

    backend, reason = megastep_backend(scn)
    if backend is None and not reason:
        out.append(_f(
            "GRF005", str(name),
            "megastep config maps to no backend and no recorded "
            "engine_fallback_reason: the engine contract requires every "
            "fallback to be observable",
        ))
    if post_run:
        used = getattr(scn, "engine_used", "")
        known = {"interpreted", "megastep-device", "megastep-host", "megastep-des"}
        if used not in known:
            out.append(_f(
                "GRF005", str(name),
                f"engine_used={used!r} after a megastep run: expected one "
                f"of {sorted(known)}",
            ))
        if used == "interpreted" and not getattr(scn, "engine_fallback_reason", ""):
            out.append(_f(
                "GRF005", str(name),
                "megastep was requested, the interpreted pipeline ran, and "
                "no engine_fallback_reason was recorded",
            ))
    return out


def verify_env_enabled() -> bool:
    """True when the ``REPRO_ANALYSIS_VERIFY`` env hook asks the compiler
    to verify every lowered app (tests + CI debugging aid)."""
    return os.environ.get("REPRO_ANALYSIS_VERIFY", "") == "1"
