"""Serving: jit'd prefill/decode engine + Anveshak-scheduled stages."""

from .engine import Generator, bucket_for, make_prefill_step, make_serve_step
from .journal import Journal, RestoreMismatch, diff_snapshots
from .reid import embed_frames, init_reid_tower, match
from .sampling import sample_tokens
from .scheduler import (
    ServedStage,
    StageRequest,
    StageResult,
    calibrate_xi,
    lower_app_stages,
    lower_stage,
)

__all__ = [
    "Generator", "Journal", "RestoreMismatch", "ServedStage", "StageRequest",
    "StageResult", "bucket_for", "calibrate_xi", "diff_snapshots",
    "embed_frames", "init_reid_tower", "lower_app_stages", "lower_stage",
    "make_prefill_step", "make_serve_step", "match", "sample_tokens",
]
