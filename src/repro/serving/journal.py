"""Append-only event journal + periodic state snapshots (fault tolerance).

The serving plane's durability story follows EventFlow's replay contract:
the simulation is **deterministic in (config, dynamism spec, seed)**, so a
crashed driver process does not need to serialize the discrete-event heap —
it needs (1) the inputs (config + query specs + fault schedule, all already
value-typed), (2) an append-only journal of the observable event stream
(sourced / sink / drop records), and (3) periodic **snapshots** of the
serving frontier: global counters, per-task pipeline counters and budgets,
per-query registry state, and the admission queue.  Recovery rebuilds the
scenario from the inputs, replays to the last snapshot's timestamp, and
verifies the reconstructed frontier is **bit-identical** to the snapshot
(`RestoreMismatch` otherwise) before continuing to the horizon — so a run
that crashes at tick T and restores produces per-query summaries
bit-identical to a run that was never interrupted (frozen as goldens in
``tests/test_faults.py``).

Snapshots are flat ``str -> float`` dicts, which makes them a pytree the
training plane's checkpoint round-trip (:mod:`repro.training.checkpoint`)
can persist to npz with its key/shape/dtype validation — missing *and*
unexpected keys both fail loudly on load.  ``jax`` is imported lazily so a
journal in a pure-sim process costs nothing.
"""

from __future__ import annotations

import hashlib
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Journal", "RestoreMismatch", "diff_snapshots"]


class RestoreMismatch(ValueError):
    """Replayed state does not bit-match the snapshot it restores from."""


def diff_snapshots(
    expected: Dict[str, float], got: Dict[str, float]
) -> List[str]:
    """Human-readable list of differing/missing keys (empty == bit-equal).

    Comparison is exact (``!=`` on floats): the replay contract is
    bit-identity, not tolerance.
    """
    out = []
    for k in sorted(expected.keys() | got.keys()):
        if k not in got:
            out.append(f"{k}: missing in replayed state")
        elif k not in expected:
            out.append(f"{k}: unexpected in replayed state")
        elif got[k] != expected[k]:
            out.append(f"{k}: snapshot {expected[k]!r} != replayed {got[k]!r}")
    return out


class Journal:
    """Append-only record stream + snapshot ring for one serving run.

    Records are ``(kind, t, a, b)`` tuples with ``kind`` one of ``source``
    (a = frames sourced this tick), ``sink`` (a = query mask, b = positive
    flag) or ``drop`` (a = drop point, b = query mask) — the full observable
    event stream of a run, appended by the scenario's accounting hooks.
    ``snapshot_period_s`` sets the cadence at which the owning scenario
    appends a frontier snapshot (0 disables periodic snapshots; the journal
    still records the event stream).
    """

    _KINDS = ("source", "sink", "drop")

    def __init__(self, snapshot_period_s: float = 30.0) -> None:
        if snapshot_period_s < 0:
            raise ValueError(f"snapshot_period_s must be >= 0, got {snapshot_period_s}")
        self.snapshot_period_s = float(snapshot_period_s)
        self.records: List[Tuple[str, float, float, float]] = []
        self.snapshots: List[Dict[str, float]] = []

    # -- event stream --------------------------------------------------- #
    def append(self, kind: str, t: float, a: float = 0.0, b: float = 0.0) -> None:
        self.records.append((kind, float(t), float(a), float(b)))

    def counts(self) -> Dict[str, int]:
        """Records by kind — the lose/duplicate-free invariant the property
        tests compare between an original run and its replay."""
        out = {k: 0 for k in self._KINDS}
        for kind, _, _, _ in self.records:
            out[kind] = out.get(kind, 0) + 1
        return out

    def last_snapshot(self) -> Dict[str, float]:
        if not self.snapshots:
            raise RestoreMismatch("journal holds no snapshot to restore from")
        return self.snapshots[-1]

    def digest(self) -> str:
        """sha256 over the full record stream + snapshots (CI golden gate)."""
        h = hashlib.sha256()
        for rec in self.records:
            h.update(repr(rec).encode())
        for snap in self.snapshots:
            for k in sorted(snap):
                h.update(f"{k}={snap[k]!r};".encode())
        return h.hexdigest()

    # -- persistence (training-plane npz round trip) -------------------- #
    def _tree(self) -> Dict[str, Any]:
        import numpy as np

        kinds = np.array(
            [self._KINDS.index(k) for k, _, _, _ in self.records], dtype=np.int64
        )
        cols = np.array(
            [(t, a, b) for _, t, a, b in self.records], dtype=np.float64
        ).reshape(len(self.records), 3)
        # Snapshot values as 0-d float64 leaves: the checkpoint round trip
        # validates shape/dtype per leaf, which plain Python floats lack.
        snaps = [
            {k: np.float64(v) for k, v in snap.items()} for snap in self.snapshots
        ]
        return {"records": {"kind": kinds, "tab": cols}, "snapshots": snaps}

    def save(self, path: str) -> None:
        """Persist via the training plane's flat-key checkpoint writer."""
        from repro.training.checkpoint import save_checkpoint

        save_checkpoint(
            path,
            self._tree(),
            metadata={
                "snapshot_period_s": self.snapshot_period_s,
                "digest": self.digest(),
            },
        )

    def load(self, path: str) -> "Journal":
        """Restore this journal's contents from ``path`` (round trip of
        :meth:`save`, validated against the *current* structure: the
        checkpoint loader rejects missing and unexpected keys alike)."""
        from repro.training.checkpoint import load_checkpoint

        tree = load_checkpoint(path, like=self._tree())
        kinds = tree["records"]["kind"]
        cols = tree["records"]["tab"]
        self.records = [
            (self._KINDS[int(k)], float(t), float(a), float(b))
            for k, (t, a, b) in zip(kinds, cols)
        ]
        self.snapshots = [
            {k: float(v) for k, v in snap.items()} for snap in tree["snapshots"]
        ]
        return self
