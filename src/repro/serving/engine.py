"""Serving engine: jit'd prefill / decode steps + a simple generator.

``make_serve_step`` builds exactly the function the multi-pod dry-run lowers
for the decode shapes (``decode_32k``, ``long_500k``): ONE new token against
a KV cache of ``seq_len``, returning sampled tokens and updated caches.
Batch padding buckets keep the jit cache small under the Anveshak
scheduler's *dynamic* batch sizes (TPU adaptation, DESIGN.md §2).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import decode, forward, init_cache, init_params, prefill
from .sampling import sample_tokens

__all__ = ["make_serve_step", "make_prefill_step", "Generator", "bucket_for"]


def bucket_for(n: int, buckets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)) -> int:
    """Smallest bucket >= n (jit cache friendliness for dynamic batches)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def make_serve_step(
    cfg: ModelConfig,
    *,
    decode_long: bool = False,
    greedy: bool = True,
    temperature: float = 1.0,
):
    """(params, token, caches, cache_len, rng) -> (next_token, caches)."""

    def serve_step(params, token, caches, cache_len, rng):
        logits, new_caches = decode(
            params, cfg, token, caches, cache_len, decode_long=decode_long
        )
        next_token = sample_tokens(
            logits[:, -1], rng, greedy=greedy, temperature=temperature,
            vocab_size=cfg.vocab_size,
        )
        return next_token[:, None], new_caches

    return serve_step


def make_prefill_step(cfg: ModelConfig, *, decode_long: bool = False):
    def prefill_step(params, batch, caches):
        return prefill(params, cfg, batch, caches, decode_long=decode_long)

    return prefill_step


class Generator:
    """Single-host convenience wrapper: prefill + greedy decode loop."""

    def __init__(self, cfg: ModelConfig, params: Any, *, max_len: int = 512,
                 cache_dtype=jnp.float32) -> None:
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        # Generator-lifetime jits: constructed once per Generator (a
        # process builds O(1) of them), never per dispatch, so the compile
        # caches are bounded without the dispatch LRU.
        # repro: noqa[JAX001] — one-time generator-lifetime jit.
        self._prefill = jax.jit(make_prefill_step(cfg))
        # repro: noqa[JAX001] — one-time generator-lifetime jit.
        self._step = jax.jit(make_serve_step(cfg))

    def generate(
        self,
        prompts: jax.Array,  # (B, S) int32
        max_new_tokens: int = 32,
        *,
        frames: Optional[jax.Array] = None,
        seed: int = 0,
    ) -> jax.Array:
        B, S = prompts.shape
        cfg = self.cfg
        caches = init_cache(
            cfg, B, S + max_new_tokens + cfg.meta_tokens + 1, dtype=self.cache_dtype
        )
        batch: Dict[str, jax.Array] = {"tokens": prompts}
        if cfg.arch_type == "encdec":
            assert frames is not None, "whisper needs encoder frames"
            batch["frames"] = frames
        logits, caches = self._prefill(self.params, batch, caches)
        rng = jax.random.PRNGKey(seed)
        token = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
        out = [token]
        cache_len = jnp.asarray(S + cfg.meta_tokens, jnp.int32)
        for i in range(max_new_tokens - 1):
            rng, sub = jax.random.split(rng)
            token, caches = self._step(self.params, token, caches, cache_len, sub)
            cache_len = cache_len + 1
            out.append(token)
        return jnp.concatenate(out, axis=1)
