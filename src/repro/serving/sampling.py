"""Token sampling: greedy / temperature / top-k, padded-vocab aware."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["sample_tokens"]


def sample_tokens(
    logits: jax.Array,  # (B, V)
    rng: jax.Array,
    *,
    greedy: bool = True,
    temperature: float = 1.0,
    top_k: int = 0,
    vocab_size: Optional[int] = None,
) -> jax.Array:
    V = logits.shape[-1]
    if vocab_size is not None and vocab_size < V:
        logits = jnp.where(jnp.arange(V) >= vocab_size, -jnp.inf, logits)
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / jnp.maximum(temperature, 1e-6)
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
