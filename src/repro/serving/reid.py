"""Re-identification tower: the CR stage's embedding model.

A compact residual MLP mapping frame feature vectors to L2-normalizable
embeddings; matching runs through the ``reid_match`` kernel (Pallas on TPU).
This is the JAX analogue of the paper's OpenReid DNN in CR (App 1) and the
small/large re-id pair of App 4.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.reid_match.ops import reid_match
from repro.models.layers import Params, init_linear, init_norm, linear, rms_norm

__all__ = ["init_reid_tower", "embed_frames", "match"]


def init_reid_tower(
    key: jax.Array, d_in: int = 128, d_hidden: int = 256, d_embed: int = 64, depth: int = 2
) -> Params:
    ks = jax.random.split(key, depth + 2)
    return {
        "proj_in": init_linear(ks[0], d_in, d_hidden),
        "blocks": [
            {
                "norm": init_norm(d_hidden),
                "w1": init_linear(ks[i + 1], d_hidden, d_hidden),
                "w2": init_linear(jax.random.fold_in(ks[i + 1], 1), d_hidden, d_hidden),
            }
            for i in range(depth)
        ],
        "proj_out": init_linear(ks[-1], d_hidden, d_embed),
    }


@jax.jit
def embed_frames(params: Params, frames: jax.Array) -> jax.Array:
    """frames: (N, d_in) -> embeddings (N, d_embed)."""
    x = linear(params["proj_in"], frames)
    for blk in params["blocks"]:
        h = rms_norm(blk["norm"], x)
        h = jax.nn.silu(linear(blk["w1"], h))
        x = x + linear(blk["w2"], h)
    return linear(params["proj_out"], x)


def match(params: Params, frames: jax.Array, queries: jax.Array, threshold: float = 0.5):
    """Full CR stage: embed candidate frames, match against query embeddings."""
    gallery = embed_frames(params, frames)
    return reid_match(gallery, queries, threshold=threshold)
