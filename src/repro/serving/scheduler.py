"""Anveshak-scheduled serving: the paper's runtime knobs in front of jit'd
model steps.

This is where the paper's contribution becomes a first-class feature of the
JAX stack: a :class:`ServedStage` wraps one jit-compiled batched step (VA
embedding, CR re-id, LM decode...) with

* a **completion budget** (:class:`TaskBudget`) updated by accept/reject
  signals,
* the paper's **three drop points** around the device step, and
* the **dynamic deadline batcher** (§4.4) whose ``xi(b)`` cost model is
  *calibrated by timing the compiled step* on the padding buckets —
  replacing the paper's empirical benchmarking table.

Batches are padded to the bucket sizes so XLA recompilation never happens on
the serving path (TPU adaptation of the paper's arbitrary batch sizes).

Stages are the **serving lowering target of the app compiler**: a composed
:class:`~repro.core.dataflow.TrackingApp` + a
:class:`~repro.core.compile.DeploymentSpec` lower onto ServedStages via
:func:`lower_app_stages`, resolving the same per-module specs
(``m_max``, cost model, drops, ``gamma``) that
``repro.core.compile.compile_app`` resolves for the discrete-event plane —
one application spec, two execution planes.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batching import DynamicBatcher, PendingEvent
from repro.core.budget import TaskBudget
from repro.core.dropping import drop_before_exec, drop_before_queuing, drop_before_transmit
from repro.core.events import Event, EventHeader, EventRecord, new_event_id

__all__ = [
    "StageRequest",
    "StageResult",
    "ServedStage",
    "calibrate_xi",
    "lower_stage",
    "lower_app_stages",
]


@dataclass
class StageRequest:
    """One unit of work (e.g. a camera frame's features).

    ``query_id`` is the multi-query tenancy tag (None outside multi-query
    serving): requests carrying one are counted into the stage's per-query
    telemetry row, mirroring the sim plane's ``Event.query_mask``.
    """

    payload: np.ndarray
    source_time: float
    event_id: int = field(default_factory=new_event_id)
    avoid_drop: bool = False
    query_id: Optional[int] = None


@dataclass
class StageResult:
    event_id: int
    output: Any
    latency: float
    batch_size: int
    dropped: bool = False


# Counter keys of a per-query telemetry row (same keys as ServedStage.stats,
# minus nothing — signals are stage-level but the row shape stays uniform).
_ZERO_QUERY_ROW: Dict[str, int] = {
    "arrived": 0,
    "dropped": 0,
    "dropped_dp1": 0,
    "dropped_dp2": 0,
    "dropped_dp3": 0,
    "executed": 0,
    "batches": 0,
    "probes": 0,
    "accepts_rx": 0,
    "rejects_rx": 0,
}


def calibrate_xi(
    step_fn: Callable[[np.ndarray], Any],
    payload_shape: Sequence[int],
    buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
    repeats: int = 3,
) -> Callable[[int], float]:
    """Measure the compiled step on each bucket; return interpolating xi(b).

    Replaces the paper's offline benchmarking: on TPU the compiled cost is
    stable, so a few timed calls per bucket give a reliable batch cost model.
    """
    times: List[Tuple[int, float]] = []
    for b in buckets:
        x = np.zeros((b, *payload_shape), np.float32)
        step_fn(x)  # compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            jax.block_until_ready(step_fn(x))
        times.append((b, (time.perf_counter() - t0) / repeats))
    bs = np.array([b for b, _ in times], np.float64)
    ts = np.array([t for _, t in times], np.float64)

    def xi(b: int) -> float:
        return float(np.interp(b, bs, ts))

    return xi


class ServedStage:
    """One pipeline stage: budgeted, batched, droppable jit'd step."""

    def __init__(
        self,
        name: str,
        step_fn: Callable[[np.ndarray], Any],  # batched device step
        xi: Callable[[int], float],
        *,
        gamma: float = 15.0,
        m_max: int = 32,
        buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
        drops_enabled: bool = True,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.name = name
        self.step_fn = step_fn
        self.xi = xi
        self.gamma = float(gamma)
        self.buckets = tuple(buckets)
        self.drops_enabled = drops_enabled
        self.clock = clock
        self.budget = TaskBudget(name, xi, m_max=m_max)
        self.batcher = DynamicBatcher(xi, m_max=m_max)
        # "dropped" stays the total; the per-drop-point split feeds the same
        # telemetry surface the pipeline's dynamism trace samples (§4.3).
        # Counter keys use the PipelineStats attribute names so telemetry()
        # can be driven by repro.core.pipeline.STAT_FIELDS directly.
        self.stats = {
            "arrived": 0,
            "dropped": 0,
            "dropped_dp1": 0,
            "dropped_dp2": 0,
            "dropped_dp3": 0,
            "executed": 0,
            "batches": 0,
            "probes": 0,  # serving has no probe re-injection (yet)
            "accepts_rx": 0,
            "rejects_rx": 0,
        }
        # Optional upstream stage: every drop here rejects into its budget
        # (the serving analogue of the pipeline's path-based reject signals,
        # §4.5; wired by lower_app_stages as VA <- CR).
        self.upstream: Optional["ServedStage"] = None
        # Multi-query tenancy: per-query counter rows (same keys as
        # ``stats``) and the event-id -> query-id attribution map for
        # requests currently in flight through the batcher.
        self._query_stats: Dict[int, Dict[str, int]] = {}
        self._query_of: Dict[int, int] = {}
        # Query-major fused step: a (Q, D) query-embedding block padded to a
        # power-of-two bucket (see set_queries); when present, the step is
        # invoked as ``step_fn(payloads, query_block, nq)``.
        self._query_block: Optional[Any] = None
        self._nq: int = 0

    # -- Anveshak signal hooks (downstream stages call these) ----------- #
    def on_reject(self, event_id: int, epsilon: float, q_bar: float) -> None:
        from repro.core.events import RejectSignal

        self.stats["rejects_rx"] += 1
        self.budget.on_reject(RejectSignal(event_id, epsilon, q_bar))

    def on_accept(self, event_id: int, epsilon: float, xi_bar: float) -> None:
        from repro.core.events import AcceptSignal

        self.stats["accepts_rx"] += 1
        self.budget.on_accept(AcceptSignal(event_id, epsilon, xi_bar))

    def telemetry(self, query_id: Optional[int] = None) -> Dict[str, float]:
        """One telemetry sample, shaped like the discrete-event plane's
        :data:`repro.sim.dynamism.TRACE_FIELDS` row so a serving deployment
        can be traced on a cadence by the same tooling: current budget,
        queue depth, the three drop-point counters and the signal counters.
        Pure snapshot — no allocation on the request path.

        ``query_id`` selects the multi-query dimension: ``None`` returns the
        stage-wide row (historical behavior); a query id returns that
        query's row in the *same shape* — counters restricted to requests
        tagged with the id, queue depth to its pending requests, ``beta``
        the shared stage budget (the device is the shared resource) — so the
        serving and sim planes report identical per-query row shapes."""
        from repro.core.pipeline import STAT_FIELDS

        if query_id is None:
            s = self.stats
            queue = self.batcher.current_size
        else:
            s = self._query_stats.get(query_id, _ZERO_QUERY_ROW)
            q_of = self._query_of
            queue = sum(
                1
                for pe in self.batcher._current
                if q_of.get(pe.event.event_id) == query_id
            )
        row: Dict[str, float] = {
            "beta": self.budget.min_budget(),
            "queue": queue,
        }
        for fld, attr in STAT_FIELDS:
            row[fld] = s[attr]
        return row

    def publish_metrics(self, registry) -> None:
        """Publish stage counters + telemetry rows (stage-wide and per
        query) into an obs-plane metrics registry.  Thin delegation to
        :func:`repro.obs.collect_stage` (lazy import so the serving layer
        never depends on the obs package at module load).  Serving-plane
        numbers depend on wall-clock arrival timing, so everything lands in
        the WALL domain and is excluded from determinism digests."""
        from repro.obs import collect_stage

        collect_stage(registry, self)

    # -- Multi-query tenancy -------------------------------------------- #
    def query_ids(self) -> List[int]:
        """Query ids this stage has seen (sorted)."""
        return sorted(self._query_stats)

    def _qstat(self, query_id: int) -> Dict[str, int]:
        qs = self._query_stats.get(query_id)
        if qs is None:
            qs = self._query_stats[query_id] = dict(_ZERO_QUERY_ROW)
        return qs

    def set_queries(self, embeddings: np.ndarray) -> None:
        """Install a query-major fused step: the ``(Q, D)`` live-query
        embedding block is padded to a power-of-two query bucket (same
        bucketing rule as ``repro.kernels.dispatch``, so XLA compiles one
        executable per bucket even as queries come and go) and kept
        device-resident; ``step_fn`` is then invoked as
        ``step_fn(payloads, query_block, nq)`` with ``nq`` the number of
        real queries (pad rows to be masked by the step).  Pass an empty
        block to fall back to the single-query ``step_fn(payloads)``."""
        import jax.numpy as jnp

        from repro.kernels.dispatch import bucket

        emb = np.asarray(embeddings, dtype=np.float32)
        if emb.size == 0:
            self._query_block = None
            self._nq = 0
            return
        if emb.ndim != 2:
            raise ValueError(f"embeddings must be (Q, D), got {emb.shape}")
        Q, D = emb.shape
        qb = bucket(Q)
        pad = np.zeros((qb, D), dtype=np.float32)
        pad[:Q] = emb
        self._query_block = jnp.asarray(pad)
        self._nq = Q

    def _reject_upstream(self, event_id: int, epsilon: float, q_bar: float) -> None:
        if self.upstream is not None:
            self.upstream.on_reject(event_id, max(epsilon, 0.0), q_bar)

    # -- Request path ---------------------------------------------------- #
    def submit(self, req: StageRequest) -> Optional[List[StageResult]]:
        """Drop point 1 + dynamic batching; returns results if a batch ran."""
        now = self.clock()
        self.stats["arrived"] += 1
        qs = self._qstat(req.query_id) if req.query_id is not None else None
        if qs is not None:
            qs["arrived"] += 1
        beta = self.budget.min_budget() if self.drops_enabled else math.inf
        if self.drops_enabled and drop_before_queuing(
            req.source_time, now, self.xi(1), beta, avoid_drop=req.avoid_drop
        ):
            self.stats["dropped"] += 1
            self.stats["dropped_dp1"] += 1
            if qs is not None:
                qs["dropped"] += 1
                qs["dropped_dp1"] += 1
            u = now - req.source_time
            self._reject_upstream(req.event_id, u + self.xi(1) - beta, 0.0)
            return [StageResult(req.event_id, None, u, 0, dropped=True)]
        if qs is not None:
            self._query_of[req.event_id] = req.query_id
        ev = Event(
            header=EventHeader(
                event_id=req.event_id,
                source_arrival=req.source_time,
                avoid_drop=req.avoid_drop,
            ),
            key=req.event_id,
            value=req.payload,
        )
        pe = PendingEvent(event=ev, arrival=now, deadline=req.source_time + beta)
        if math.isinf(beta):  # bootstrap: streaming (paper §4.5)
            return self._execute([pe])
        batch = self.batcher.offer(pe, now)
        if batch:
            return self._execute(batch)
        return None

    def flush(self) -> Optional[List[StageResult]]:
        """Submit the open batch if its auto-submit deadline passed."""
        batch = self.batcher.flush_if_due(self.clock())
        if batch:
            return self._execute(batch)
        return None

    def next_due_time(self) -> float:
        return self.batcher.next_due_time()

    # -- Execution: drop points 2/3 around the device step --------------- #
    def _execute(self, batch: List[PendingEvent]) -> List[StageResult]:
        now = self.clock()
        beta = self.budget.min_budget() if self.drops_enabled else math.inf
        b = len(batch)
        tuples = [
            (pe.event.header.source_arrival, pe.arrival, now - pe.arrival, pe.event)
            for pe in batch
        ]
        if self.drops_enabled:
            retained, dropped = drop_before_exec(tuples, self.xi(b), beta)
        else:
            retained, dropped = [t[3] for t in tuples], []
        results: List[StageResult] = []
        q_of = self._query_of
        for ev in dropped:
            self.stats["dropped"] += 1
            self.stats["dropped_dp2"] += 1
            qid = q_of.pop(ev.event_id, None)
            if qid is not None:
                qs = self._qstat(qid)
                qs["dropped"] += 1
                qs["dropped_dp2"] += 1
            u_total = now - ev.header.source_arrival
            self._reject_upstream(ev.event_id, u_total + self.xi(b) - beta, ev.header.q_bar)
            results.append(StageResult(ev.event_id, None, u_total, 0, dropped=True))
        if not retained:
            return results
        pe_by_id = {pe.event.event_id: pe for pe in batch}
        m = len(retained)
        # Pad to the bucket so XLA reuses the compiled executable.
        bucket = next((x for x in self.buckets if m <= x), self.buckets[-1])
        payloads = np.stack([ev.value for ev in retained])
        if bucket > m:
            pad = np.zeros((bucket - m, *payloads.shape[1:]), payloads.dtype)
            payloads = np.concatenate([payloads, pad])
        if self._query_block is None:
            out = jax.device_get(self.step_fn(payloads))
        else:
            # Query-major fused step: every live query rides one device call
            # (the block is bucket-padded and device-resident; see
            # set_queries), the serving analogue of the sim plane's
            # cross-query reid_match_multi dispatch.
            out = jax.device_get(self.step_fn(payloads, self._query_block, self._nq))
        end = self.clock()
        exec_dur = end - now
        self.stats["executed"] += m
        self.stats["batches"] += 1
        batch_queries = set()
        executed_q: Dict[int, int] = {}
        for ev in retained:
            qid = q_of.pop(ev.event_id, None)
            if qid is not None:
                executed_q[ev.event_id] = qid
                qs = self._qstat(qid)
                qs["executed"] += 1
                if qid not in batch_queries:
                    batch_queries.add(qid)
                    qs["batches"] += 1
        for ev in retained:
            pe = pe_by_id[ev.event_id]
            u = pe.arrival - ev.header.source_arrival
            q = now - pe.arrival
            pi = q + exec_dur
            self.budget.record(
                ev.event_id, EventRecord(departure=u + pi, queuing=q, batch_size=m, xi=exec_dur)
            )
            idx = retained.index(ev)
            row = jax.tree.map(lambda a: a[idx], out)
            if self.drops_enabled and drop_before_transmit(
                0.0, u, pi, beta, avoid_drop=ev.header.avoid_drop
            ):
                self.stats["dropped"] += 1
                self.stats["dropped_dp3"] += 1
                qid = executed_q.get(ev.event_id)
                if qid is not None:
                    qst = self._qstat(qid)
                    qst["dropped"] += 1
                    qst["dropped_dp3"] += 1
                self._reject_upstream(ev.event_id, u + pi - beta, ev.header.q_bar)
                results.append(StageResult(ev.event_id, None, u + pi, m, dropped=True))
            else:
                results.append(StageResult(ev.event_id, row, u + pi, m))
        return results


# --------------------------------------------------------------------- #
# App-compiler lowering: TrackingApp + DeploymentSpec -> ServedStages    #
# --------------------------------------------------------------------- #
def lower_stage(
    module: str,
    app,
    deployment,
    step_fn: Callable[[np.ndarray], Any],
    *,
    payload_shape: Optional[Sequence[int]] = None,
    buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
    clock: Callable[[], float] = time.monotonic,
) -> ServedStage:
    """Lower one module (``"VA"`` or ``"CR"``) of ``app`` onto a
    :class:`ServedStage` wrapping ``step_fn``.

    The stage's knobs come from the same spec resolution the discrete-event
    compiler uses (``repro.core.compile.resolve_module``): the app's
    per-module :class:`~repro.core.dataflow.ModuleSpec` overrides merged
    over the :class:`~repro.core.compile.DeploymentSpec` defaults.  The
    cost model priority is spec ``xi`` -> measured :func:`calibrate_xi`
    (requires ``payload_shape``) — calibration replaces the paper's offline
    benchmarking table.  Serving batches through the dynamic deadline
    batcher only; a spec pinning ``static``/``nob`` batching is rejected
    rather than silently ignored.
    """
    from repro.core.compile import _zero_xi, resolve_module

    spec = resolve_module(app, deployment, module)
    if spec.batching != "dynamic":
        raise ValueError(
            f"serving lowers only dynamic batching; {module} spec pins "
            f"{spec.batching!r}"
        )
    xi = spec.xi
    if xi is _zero_xi:
        # Neither the app nor the deployment pinned a cost model (an
        # *explicit* zero xi is honored as "free"): measure the compiled
        # step itself.
        if payload_shape is None:
            raise ValueError(
                f"{module} spec carries no xi cost model; pass payload_shape "
                "so lower_stage can calibrate one from the compiled step"
            )
        xi = calibrate_xi(step_fn, payload_shape, buckets=buckets)
    return ServedStage(
        f"{app.name}/{module}",
        step_fn,
        xi,
        gamma=app.gamma,
        m_max=spec.m_max,
        buckets=buckets,
        drops_enabled=deployment.drops_enabled,
        clock=clock,
    )


def lower_app_stages(
    app,
    deployment,
    step_fns: Dict[str, Callable[[np.ndarray], Any]],
    *,
    payload_shapes: Optional[Dict[str, Sequence[int]]] = None,
    buckets: Sequence[int] = (1, 2, 4, 8, 16, 32),
    clock: Callable[[], float] = time.monotonic,
) -> Dict[str, "ServedStage"]:
    """Lower an app's compute modules onto serving stages.

    ``step_fns`` maps module names (``"VA"``/``"CR"``) to jit-compiled
    batched steps; the returned dict maps the same names to configured
    :class:`ServedStage` instances.  Downstream accept/reject signals are
    chained VA <- CR automatically (a CR-side drop rejects into the VA
    budget, mirroring the pipeline's path-based signal delivery).
    """
    payload_shapes = payload_shapes or {}
    stages = {
        module: lower_stage(
            module,
            app,
            deployment,
            fn,
            payload_shape=payload_shapes.get(module),
            buckets=buckets,
            clock=clock,
        )
        for module, fn in step_fns.items()
    }
    va, cr = stages.get("VA"), stages.get("CR")
    if va is not None and cr is not None:
        cr.upstream = va  # CR-side drops reject into the VA budget
    return stages
