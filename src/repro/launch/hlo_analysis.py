"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` visits a ``while`` body ONCE — with
scan-over-layers that undercounts FLOPs/bytes/collectives by ~n_layers.
This module re-derives the roofline inputs from ``compiled.as_text()``:

1. split the module into computations and build per-computation symbol
   tables (op name -> result shape),
2. build the call graph (``while`` / ``call`` / ``fusion`` / conditional),
3. read each while's trip count from its ``backend_config``
   ``known_trip_count`` (fallback: the s32 constant in its condition),
4. accumulate with multipliers = product of enclosing trip counts:
   * **flops**: ``dot`` = 2 * prod(result) * prod(lhs contracting dims),
     ``convolution`` = 2 * prod(result) * prod(kernel non-output dims);
     fusion bodies are recursed for flops,
   * **bytes**: result + operand bytes of top-level macro ops (fusion
     call-sites count their operands/results — the post-fusion HBM-traffic
     approximation; plumbing ops like tuple/gte/bitcast are free),
   * **collective bytes** by kind (output-shard-size convention).

Validated against XLA's cost_analysis on unrolled modules in
``tests/test_roofline.py``.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCosts", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^(?:\([^=]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([\w\-]+)\(")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[="{\\]+n[="{\\]*"?(\d+)')
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"(?:branch_computations|true_computation|false_computation)=\{?%?([\w.\-,% ]+)\}?")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_REF_RE = re.compile(r"%([\w.\-]+)")

# plumbing ops: no HBM traffic of their own
_FREE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "after-all", "partition-id", "replica-id", "domain", "opt-barrier",
}


def _dims(dim_str: str) -> List[int]:
    return [int(d) for d in dim_str.split(",") if d]


def _numel(dim_str: str) -> int:
    n = 1
    for d in _dims(dim_str):
        n *= d
    return n


def _shape_bytes(dtype: str, dim_str: str) -> int:
    return _numel(dim_str) * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class _Op:
    name: str
    opname: str
    rest: str  # text after '='
    result_bytes: float
    result_shapes: List[Tuple[str, str]]  # (dtype, dims)
    is_root: bool = False
    param_index: int = -1


@dataclass
class _Comp:
    name: str
    ops: List[_Op] = field(default_factory=list)
    symbols: Dict[str, float] = field(default_factory=dict)  # name -> bytes
    raw_lines: List[str] = field(default_factory=list)


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    # Bytes moved by pure dtype-convert / layout-copy ops (and fusions of
    # them).  On CPU, XLA upcasts bf16 dot operands to f32 — whole-cache
    # converts that do NOT exist on TPU (native bf16 MXU).  The TPU-native
    # memory estimate is ``bytes - cast_bytes`` (EXPERIMENTS.md §Roofline).
    cast_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    while_trip_counts: Dict[str, int] = field(default_factory=dict)

    @property
    def bytes_tpu_native(self) -> float:
        return max(self.bytes - self.cast_bytes, 0.0)

    def merge_scaled(self, other: "HloCosts", k: float) -> None:
        self.flops += other.flops * k
        self.bytes += other.bytes * k
        self.cast_bytes += other.cast_bytes * k
        self.collective_bytes += other.collective_bytes * k
        for kind, v in other.collective_by_kind.items():
            self.collective_by_kind[kind] = self.collective_by_kind.get(kind, 0.0) + v * k
        for kind, v in other.collective_counts.items():
            self.collective_counts[kind] = self.collective_counts.get(kind, 0.0) + v * k
        for name, t in other.while_trip_counts.items():
            self.while_trip_counts[name] = t


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry: Optional[str] = None
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and ("->" in stripped) and ("%" in stripped or stripped.startswith("ENTRY")):
                m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", stripped)
                if m:
                    cur = _Comp(m.group(2))
                    if m.group(1):
                        entry = cur.name
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        cur.raw_lines.append(line)
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rest = dm.groups()
        is_root = line.lstrip().startswith("ROOT ")
        shapes = []
        # result shapes: everything before the op name token
        om = _OPNAME_RE.match(rest)
        opname = om.group(1) if om else ""
        head = rest.split(opname + "(", 1)[0] if opname else rest
        for sm in _SHAPE_RE.finditer(head):
            shapes.append((sm.group(1), sm.group(2)))
        rbytes = sum(_shape_bytes(d, s) for d, s in shapes)
        pidx = -1
        if opname == "parameter":
            pm = re.search(r"parameter\((\d+)\)", rest)
            if pm:
                pidx = int(pm.group(1))
        cur.ops.append(_Op(name=name, opname=opname, rest=rest, result_bytes=rbytes,
                           result_shapes=shapes, is_root=is_root, param_index=pidx))
        cur.symbols[name] = rbytes
    return comps, entry


def _operand_bytes(op: _Op, comp: _Comp) -> float:
    """Sum bytes of named operand refs inside the op's argument list."""
    if not op.opname:
        return 0.0
    try:
        args = op.rest.split(op.opname + "(", 1)[1]
    except IndexError:
        return 0.0
    # cut at the matching close paren (approximately: first '),' or trailing ')')
    depth, end = 1, len(args)
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    total = 0.0
    for m in _OPERAND_REF_RE.finditer(args[:end]):
        total += comp.symbols.get(m.group(1), 0.0)
    return total


def _dot_flops(op: _Op, comp: _Comp, lhs_shapes: Dict[str, List[int]]) -> float:
    cm = _LHS_CONTRACT_RE.search(op.rest)
    if cm is None or not op.result_shapes:
        return 0.0
    res_elems = _numel(op.result_shapes[0][1])
    args = op.rest.split(op.opname + "(", 1)[1]
    first = _OPERAND_REF_RE.search(args)
    contract = 1
    if first and first.group(1) in lhs_shapes:
        dims = lhs_shapes[first.group(1)]
        for idx in _dims(cm.group(1)):
            if idx < len(dims):
                contract *= dims[idx]
    return 2.0 * res_elems * contract


def _conv_flops(op: _Op, rhs_shapes: Dict[str, List[int]]) -> float:
    if not op.result_shapes:
        return 0.0
    res = _numel(op.result_shapes[0][1])
    args = op.rest.split(op.opname + "(", 1)[1]
    refs = _OPERAND_REF_RE.findall(args.split(")")[0])
    if len(refs) < 2 or refs[1] not in rhs_shapes:
        return 2.0 * res  # minimal fallback
    rhs = rhs_shapes[refs[1]]
    # kernel contributes all dims except the output-feature dim; HLO text
    # doesn't mark which is which, so divide by the largest dim matching the
    # result feature count heuristically — or simply all dims / last.
    prod = 1
    for d in rhs:
        prod *= d
    return 2.0 * res * prod / max(rhs[-1], 1)


def _op_args(op: "_Op") -> str:
    try:
        return op.rest.split(op.opname + "(", 1)[1]
    except IndexError:
        return ""


def _arg_refs(op: "_Op") -> List[str]:
    """Operand refs of the op's argument list, in order."""
    args = _op_args(op)
    depth, end = 1, len(args)
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_REF_RE.findall(args[:end])


def _param_read_bytes(body: "_Comp") -> Dict[int, float]:
    """Actual bytes each fusion parameter contributes when read.

    A parameter consumed ONLY by dynamic-slice ops is read at slice size;
    a parameter that is the updated buffer of a ROOT dynamic-update-slice
    is read in place (0 extra; the write is charged via the result side).
    """
    reads: Dict[int, float] = {}
    params = {op.name: op for op in body.ops if op.opname == "parameter"}
    consumers: Dict[str, List["_Op"]] = {name: [] for name in params}
    for op in body.ops:
        if op.opname == "parameter":
            continue
        for ref in _arg_refs(op):
            if ref in consumers:
                consumers[ref].append(op)
    root = next((op for op in body.ops if op.is_root), None)
    for name, pop in params.items():
        cons = consumers.get(name, [])
        if cons and all(c.opname == "dynamic-slice" for c in cons):
            reads[pop.param_index] = sum(c.result_bytes for c in cons)
        elif (
            root is not None
            and root.opname == "dynamic-update-slice"
            and _arg_refs(root)[:1] == [name]
        ):
            reads[pop.param_index] = 0.0  # in-place updated buffer
        else:
            reads[pop.param_index] = pop.result_bytes
    return reads


def _fusion_bytes(op: "_Op", comp: "_Comp", body: Optional["_Comp"]) -> float:
    """HBM traffic of a fusion call-site with in-place DS/DUS refinement."""
    refs = _arg_refs(op)
    if body is None:
        total = op.result_bytes
        for r in refs:
            total += comp.symbols.get(r, 0.0)
        return total
    reads = _param_read_bytes(body)
    total = 0.0
    for i, r in enumerate(refs):
        total += min(reads.get(i, float("inf")), comp.symbols.get(r, 0.0))
    root = next((o for o in body.ops if o.is_root), None)
    if root is not None and root.opname == "dynamic-update-slice":
        # write only the updated region (2nd operand of the DUS)
        dus_refs = _arg_refs(root)
        upd = body.symbols.get(dus_refs[1], 0.0) if len(dus_refs) > 1 else 0.0
        total += upd
    else:
        total += op.result_bytes
    return total


def _trip_count_from_line(line: str, comps: Dict[str, _Comp], cond_name: str) -> int:
    tm = _TRIP_RE.search(line)
    if tm:
        return int(tm.group(1))
    cond = comps.get(cond_name)
    if cond is not None:
        consts = [
            int(m.group(1)) for l in cond.raw_lines for m in _CONST_RE.finditer(l)
        ]
        if consts:
            return max(consts)
    return 1


def _analyze_comp(
    comp: _Comp,
    comps: Dict[str, _Comp],
    cache: Dict[str, HloCosts],
    stack: Tuple[str, ...] = (),
) -> HloCosts:
    if comp.name in cache:
        return cache[comp.name]
    if comp.name in stack:
        return HloCosts()
    # shape table (dims) for dot/conv operand lookup
    dim_table: Dict[str, List[int]] = {}
    for op in comp.ops:
        if op.result_shapes:
            dim_table[op.name] = _dims(op.result_shapes[0][1])
    out = HloCosts()
    for op in comp.ops:
        wm = _WHILE_RE.search(op.rest)
        if wm:
            cond_name, body_name = wm.groups()
            trips = _trip_count_from_line(op.rest, comps, cond_name)
            out.while_trip_counts[body_name] = trips
            if body_name in comps:
                body = _analyze_comp(comps[body_name], comps, cache, stack + (comp.name,))
                out.merge_scaled(body, trips)
            continue
        if op.opname == "conditional":
            for ref in _OPERAND_REF_RE.findall(op.rest):
                if ref in comps:
                    out.merge_scaled(
                        _analyze_comp(comps[ref], comps, cache, stack + (comp.name,)), 1.0
                    )
            continue
        if op.opname == "fusion":
            cm = _CALLS_RE.search(op.rest)
            body_comp = comps.get(cm.group(1)) if cm else None
            fb = _fusion_bytes(op, comp, body_comp)
            if body_comp is not None:
                body = _analyze_comp(body_comp, comps, cache, stack + (comp.name,))
                out.flops += body.flops  # dots fused into loops still count
                out.collective_bytes += body.collective_bytes
                for k, v in body.collective_by_kind.items():
                    out.collective_by_kind[k] = out.collective_by_kind.get(k, 0.0) + v
                # Fusions made only of converts/copies/plumbing are dtype/
                # layout churn (CPU bf16 upcast artifact).
                if all(
                    o.opname in _FREE_OPS or o.opname in ("convert", "copy")
                    for o in body_comp.ops
                ):
                    out.cast_bytes += fb
            out.bytes += fb
            continue
        if op.opname == "call":
            cm = _TOAPPLY_RE.search(op.rest)
            if cm and cm.group(1) in comps:
                out.merge_scaled(
                    _analyze_comp(comps[cm.group(1)], comps, cache, stack + (comp.name,)), 1.0
                )
            continue
        if op.opname in _COLLECTIVES:
            b = op.result_bytes
            out.collective_bytes += b
            out.collective_by_kind[op.opname] = out.collective_by_kind.get(op.opname, 0.0) + b
            out.collective_counts[op.opname] = out.collective_counts.get(op.opname, 0.0) + 1
            out.bytes += op.result_bytes + _operand_bytes(op, comp)
            continue
        if op.opname == "dot":
            out.flops += _dot_flops(op, comp, dim_table)
            out.bytes += op.result_bytes + _operand_bytes(op, comp)
            continue
        if op.opname == "convolution":
            out.flops += _conv_flops(op, dim_table)
            out.bytes += op.result_bytes + _operand_bytes(op, comp)
            continue
        if op.opname == "dynamic-slice":
            out.bytes += 2.0 * op.result_bytes  # read slice + write result
            continue
        if op.opname == "dynamic-update-slice":
            refs = _arg_refs(op)
            upd = comp.symbols.get(refs[1], 0.0) if len(refs) > 1 else 0.0
            out.bytes += 2.0 * upd  # in-place: read update + write region
            continue
        if op.opname in _FREE_OPS or not op.opname:
            continue
        b = op.result_bytes + _operand_bytes(op, comp)
        out.bytes += b
        if op.opname in ("convert", "copy"):
            out.cast_bytes += b
    cache[comp.name] = out
    return out


def analyze_hlo(text: str) -> HloCosts:
    comps, entry = _parse_computations(text)
    if not comps:
        return HloCosts()
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda n: len(comps[n].ops))
    cache: Dict[str, HloCosts] = {}
    return _analyze_comp(comps[entry], comps, cache)
