"""Roofline-term extraction from a compiled dry-run artifact.

Three terms, per (arch x shape x mesh), all in seconds (TPU v5e constants):

    compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16 / chip)
    memory     = HLO_bytes / HBM_bw                (819 GB/s / chip)
    collective = collective_bytes / link_bw        (~50 GB/s/link ICI)

All three inputs come from :mod:`repro.launch.hlo_analysis` over the
optimized per-device HLO (``compiled.as_text()``), because XLA's own
``cost_analysis()`` counts ``while`` bodies once — a ~n_layers undercount
with scan-over-layers.  The analyzer multiplies through loop trip counts,
models in-place dynamic-update-slice (KV-cache writes) and sums collective
output-shard sizes per kind.  ``xla_cost`` is recorded alongside for
reference.
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional, Tuple

from .hlo_analysis import HloCosts, analyze_hlo

__all__ = ["HW", "RooflineTerms", "collective_bytes", "roofline_terms", "format_row"]


@dataclass(frozen=True)
class HW:
    """TPU v5e per-chip constants (assignment-specified)."""

    peak_flops: float = 197e12  # bf16
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s/link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.:  %all-gather.3 = bf16[16,512,128]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" + "|".join(_COLLECTIVES) + r")\("
)
# tuple-result collectives:  = (bf16[..], bf16[..]) all-to-all(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+(" + "|".join(_COLLECTIVES) + r")\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Sum output-shard bytes of collective ops in optimized HLO."""
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            per_kind[kind] += _shape_bytes(dtype, dims)
            count[kind] += 1
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            for dm in _SHAPE_RE.finditer(shapes):
                per_kind[kind] += _shape_bytes(dm.group(1), dm.group(2))
            count[kind] += 1
    total = sum(per_kind.values())
    per_kind = {k: v for k, v in per_kind.items() if v}
    per_kind["_counts"] = {k: v for k, v in count.items() if v}  # type: ignore
    return total, per_kind


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops: float  # per-chip HLO flops
    hbm_bytes: float  # per-chip bytes accessed
    coll_bytes: float  # per-chip collective bytes (output-size convention)
    compute_s: float
    memory_s: float
    # Memory term excluding pure dtype-convert/copy traffic — the CPU HLO
    # upcasts bf16 dot operands to f32, which TPU does not (DESIGN.md).
    memory_tpu_native_s: float
    collective_s: float
    dominant: str
    model_flops: float  # 6*N*D (active params) — global
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs * chips)
    peak_memory_bytes: float = 0.0
    per_kind: Dict[str, float] = field(default_factory=dict)

    def to_dict(self) -> Dict:
        return asdict(self)


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh: str,
    chips: int,
    hlo_text: str,
    model_flops: float,
    peak_memory_bytes: float = 0.0,
    hw: HW = HW(),
    costs: Optional[HloCosts] = None,
) -> RooflineTerms:
    h = costs if costs is not None else analyze_hlo(hlo_text)
    flops, hbm, coll = h.flops, h.bytes, h.collective_bytes
    compute_s = flops / hw.peak_flops
    memory_s = hbm / hw.hbm_bw
    memory_native_s = getattr(h, "bytes_tpu_native", hbm) / hw.hbm_bw
    collective_s = coll / hw.ici_bw
    dominant = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", collective_s)),
        key=lambda kv: kv[1],
    )[0]
    useful = model_flops / max(flops * chips, 1.0)
    per_kind = {k: float(v) for k, v in h.collective_by_kind.items()}
    per_kind.update({f"n_{k}": float(v) for k, v in h.collective_counts.items()})
    return RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh,
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(coll),
        compute_s=compute_s,
        memory_s=memory_s,
        memory_tpu_native_s=memory_native_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=useful,
        peak_memory_bytes=peak_memory_bytes,
        per_kind=per_kind,
    )


def format_row(t: RooflineTerms) -> str:
    return (
        f"{t.arch:22s} {t.shape:12s} {t.mesh:10s} "
        f"comp={t.compute_s*1e3:9.3f}ms mem={t.memory_s*1e3:9.3f}ms "
        f"coll={t.collective_s*1e3:9.3f}ms dom={t.dominant:10s} "
        f"useful={t.useful_ratio:6.3f} peak_dev_mem={t.peak_memory_bytes/2**30:7.2f}GiB"
    )
