import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, with no real device allocation (ShapeDtypeStruct inputs).

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single --out experiments/dryrun

Per combination this prints/records ``compiled.memory_analysis()`` (fits?),
``compiled.cost_analysis()`` (FLOPs / bytes for §Roofline) and the collective
byte summary parsed from the optimized HLO.

NOTE: the XLA_FLAGS line above must run before any other import initializes
jax — do not move it.  (No ``from __future__`` import here for the same
reason: the docstring sits after the env var on purpose.)
"""

import argparse
import dataclasses
import functools
import json
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import INPUT_SHAPES, get_config
from repro.core.clock import monotonic
from repro.config.base import InputShape, ModelConfig
from repro.configs import ASSIGNED_ARCHS
from repro.distributed.partitioning import (
    MeshRules,
    cache_specs,
    default_rules,
    mesh_rules,
    param_specs,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HW, format_row, roofline_terms
from repro.models import init_cache, init_params, input_specs
from repro.models.model_zoo import cache_len_for
from repro.serving.engine import make_prefill_step, make_serve_step
from repro.training.optimizer import init_adamw
from repro.training.train_loop import TrainConfig, make_train_step

__all__ = ["run_case", "main"]


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _batch_specs(cfg: ModelConfig, shape: InputShape, rules: MeshRules) -> Dict[str, P]:
    out: Dict[str, P] = {}
    for name, sds in input_specs(cfg, shape).items():
        logical = ["batch"] + [None] * (len(sds.shape) - 1)
        out[name] = rules.resolve(logical, sds.shape)
    return out


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D forward-only."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def run_case(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mesh=None,
    save_hlo: Optional[str] = None,
    variant: str = "baseline",
) -> Dict[str, Any]:
    """``variant`` selects the sharding/implementation scheme:

    * ``baseline``  — the paper-faithful first lowering (FSDP+TP everywhere).
    * ``opt``       — the beyond-paper optimized scheme (EXPERIMENTS.md §Perf):
        - decode: weight-stationary serving layout (no FSDP param gathers);
        - MoE with E %% model == 0: expert-parallel weight placement.
    """
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    decode_long = shape_name == "long_500k"
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    rules = default_rules(mesh)
    if decode_long:
        # batch=1: shard the KV sequence instead (context parallel).
        rules.rules["kv_seq"] = "data"
    if variant == "opt":
        if shape.kind == "decode":
            # Serving mesh view (same 256/512 chips): factor the model axis
            # into ("kv", "tp") so the KV cache can LIVE kv-head-sharded —
            # eliminating the end-of-step whole-cache all-gather (H3) — and
            # keep weights stationary (no FSDP gathers).
            kv_size = 8 if cfg.n_kv_heads % 8 == 0 else (
                4 if cfg.n_kv_heads and cfg.n_kv_heads % 4 == 0 else 1
            )
            tp_size = 16 // kv_size
            if multi_pod:
                mesh = jax.make_mesh((2, 16, kv_size, tp_size), ("pod", "data", "kv", "tp"))
            else:
                mesh = jax.make_mesh((16, kv_size, tp_size), ("data", "kv", "tp"))
            mesh_name = "x".join(str(x) for x in mesh.devices.shape) + "(kv)"
            model_axes = ("kv", "tp") if tp_size > 1 else ("kv",)
            rules = MeshRules(
                mesh=mesh,
                rules={
                    "batch": ("pod", "data") if multi_pod else ("data",),
                    "seq": None,
                    "model": model_axes,
                    "fsdp": None,  # weight-stationary serving
                    "expert": None,
                    "vocab": model_axes,
                    "kv_seq": "data" if decode_long else None,
                    "kv_heads": "kv" if kv_size > 1 else None,
                    "kv_latent": model_axes,  # MLA: shard the latent dim
                },
            )
        model_axis = rules.rules.get("model")
        if cfg.moe.enabled and model_axis is not None and (
            cfg.moe.num_experts % rules.axis_size(model_axis) == 0
        ):
            rules.rules["expert"] = model_axis
        if shape.kind != "decode" and cfg.n_heads and cfg.n_heads % 16 != 0:
            # Heads don't divide the model axis: row-parallel attention/SSD
            # blocks instead of replicated per-chip intermediates (H1).
            rules.rules["q_seq"] = rules.rules.get("model")
    t0 = monotonic()

    with mesh, mesh_rules(rules):
        max_dec_len = max(shape.seq_len + 8, 4096)  # whisper learned positions
        params_struct = jax.eval_shape(
            lambda k: init_params(k, cfg, dtype=jnp.bfloat16, max_dec_len=max_dec_len),
            jax.random.PRNGKey(0),
        )
        p_shard = _ns(mesh, param_specs(params_struct, rules))
        b_specs = input_specs(cfg, shape)
        b_shard = _ns(mesh, _batch_specs(cfg, shape, rules))

        if shape.kind == "train":
            tcfg = TrainConfig(remat=True)
            step = make_train_step(cfg, tcfg)
            opt_struct = jax.eval_shape(init_adamw, params_struct)
            o_shard = type(opt_struct)(
                step=NamedSharding(mesh, P()), mu=p_shard, nu=p_shard
            )
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
            )
            lowered = jitted.lower(params_struct, opt_struct, b_specs)
        elif shape.kind == "prefill":
            cap = shape.seq_len + cfg.meta_tokens
            cache_struct = jax.eval_shape(
                functools.partial(
                    init_cache, cfg, shape.global_batch, cap, dtype=jnp.bfloat16
                )
            )
            c_shard = _ns(mesh, cache_specs(cache_struct, rules))
            step = make_prefill_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, b_shard, c_shard),
                out_shardings=(None, c_shard),
            )
            lowered = jitted.lower(params_struct, b_specs, cache_struct)
        else:  # decode
            cap = cache_len_for(cfg, shape)
            cache_struct = jax.eval_shape(
                functools.partial(
                    init_cache,
                    cfg,
                    shape.global_batch,
                    cap,
                    dtype=jnp.bfloat16,
                    decode_long=decode_long,
                )
            )
            c_shard = _ns(
                mesh, cache_specs(cache_struct, rules, context_parallel=decode_long)
            )
            step = make_serve_step(cfg, decode_long=decode_long, greedy=True)
            len_struct = jax.ShapeDtypeStruct((), jnp.int32)
            rng_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
            repl = NamedSharding(mesh, P())
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, b_shard["token"], c_shard, repl, repl),
                out_shardings=(b_shard["token"], c_shard),
            )
            lowered = jitted.lower(
                params_struct, b_specs["token"], cache_struct, len_struct, rng_struct
            )
        t_lower = monotonic() - t0
        t0 = monotonic()
        compiled = lowered.compile()
        t_compile = monotonic() - t0

    # ---- analyses ------------------------------------------------------ #
    mem = compiled.memory_analysis()
    mem_info: Dict[str, float] = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        try:
            mem_info[attr] = float(getattr(mem, attr))
        except (AttributeError, TypeError, ValueError):
            pass  # older jaxlibs omit some memory-analysis fields
    peak = (
        mem_info.get("argument_size_in_bytes", 0.0)
        - mem_info.get("alias_size_in_bytes", 0.0)
        + mem_info.get("output_size_in_bytes", 0.0)
        + mem_info.get("temp_size_in_bytes", 0.0)
    )
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    terms = roofline_terms(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        chips=chips,
        hlo_text=hlo,
        model_flops=model_flops(cfg, shape),
        peak_memory_bytes=peak,
    )
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_info,
        "peak_device_bytes": peak,
        "xla_cost_analysis": {
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed", "optimal_seconds")
        },
        "roofline": terms.to_dict(),
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument(
        "--mesh", default="single", choices=["single", "multi", "both"],
        help="single=16x16 (256 chips), multi=2x16x16 (512)"
    )
    ap.add_argument("--all", action="store_true", help="all archs x shapes")
    ap.add_argument("--out", default="experiments/dryrun", help="output dir for JSON records")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=["baseline", "opt"])
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                if args.variant != "baseline":
                    tag += f"__{args.variant}"
                out_path = os.path.join(args.out, tag + ".json")
                if os.path.exists(out_path):
                    print(f"[skip] {tag} (exists)")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = run_case(
                        arch,
                        shape,
                        multi_pod=mp,
                        variant=args.variant,
                        save_hlo=os.path.join(args.out, tag + ".hlo")
                        if args.save_hlo
                        else None,
                    )
                except Exception as e:  # a failure here is a bug in our system
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
                    continue
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=2)
                r = rec["roofline"]
                print(
                    f"[ok] {tag}: lower={rec['lower_s']}s compile={rec['compile_s']}s "
                    f"flops/chip={r['flops']:.3e} bytes/chip={r['hbm_bytes']:.3e} "
                    f"coll/chip={r['coll_bytes']:.3e} dom={r['dominant']} "
                    f"peak_dev_mem={rec['peak_device_bytes']/2**30:.2f}GiB",
                    flush=True,
                )
    if failures:
        print("\nFAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        raise SystemExit(1)
    print("\nAll dry-run cases compiled.")


if __name__ == "__main__":
    main()
