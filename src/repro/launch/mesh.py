"""Production mesh definition (kept as functions — importing this module
never touches jax device state)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e target: 16x16 = 256 chips per pod; 2 pods multi-pod.

    Axes: ``data`` (batch / FSDP) x ``model`` (tensor parallel), plus a
    leading ``pod`` axis in the multi-pod configuration (DCN-connected).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small CPU meshes, e.g. (2, 4))."""
    return jax.make_mesh(tuple(shape), tuple(axes))
