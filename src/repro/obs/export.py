"""Exporters: Prometheus text exposition and OTLP-shaped JSONL.

The exposition is deterministic by construction — metric families in
sorted-name order, series in sorted label-tuple order, float formatting
via the shortest round-tripping decimal — so the SIM-domain exposition
of a deterministic run is a bit-stable artifact that can be digest-gated
(see ``MetricsRegistry.digest`` and tests/test_obs.py's golden).
"""

from __future__ import annotations

import json
from typing import Iterable, List, Optional, Tuple

from repro.obs.metrics import SIM, MetricsRegistry, _fmt

__all__ = [
    "prometheus_exposition",
    "exposition_digest",
    "metrics_jsonl",
    "spans_jsonl",
    "write_text",
]


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _labels_text(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(str(v))}"' for k, v in labels)
    return "{" + inner + "}"


def prometheus_exposition(registry: MetricsRegistry, include_wall: bool = True) -> str:
    """Prometheus text exposition format 0.0.4 of the registry."""
    lines: List[str] = []
    domain = None if include_wall else SIM
    for m in registry.collect(domain=domain):
        lines.append(f"# HELP {m.name} {m.help}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        for sample_name, labels, value in m.samples():
            lines.append(f"{sample_name}{_labels_text(labels)} {_fmt(value)}")
    return "\n".join(lines) + ("\n" if lines else "")


def exposition_digest(registry: MetricsRegistry) -> str:
    """Digest of the SIM-domain exposition (wall-clock rows excluded)."""
    return registry.digest()


def metrics_jsonl(registry: MetricsRegistry, include_wall: bool = True) -> str:
    """OTLP-shaped JSONL: one metric family per line, ``sort_keys`` so the
    SIM subset is as bit-stable as the Prometheus exposition."""
    lines = []
    domain = None if include_wall else SIM
    for m in registry.collect(domain=domain):
        data_points = [
            {
                "attributes": {k: v for k, v in labels},
                "name": sample_name,
                "value": value,
            }
            for sample_name, labels, value in m.samples()
        ]
        row = {
            "name": m.name,
            "description": m.help,
            "type": m.kind,
            "domain": m.domain,
            "data_points": data_points,
        }
        lines.append(json.dumps(row, sort_keys=True))
    return "\n".join(lines) + ("\n" if lines else "")


def spans_jsonl(spans: Iterable) -> str:
    """OTLP-shaped span export: one span per line, hops as child-span
    entries and drop/retry annotations as span events."""
    lines = []
    for s in spans:
        row = s.to_row() if hasattr(s, "to_row") else dict(s)
        lines.append(json.dumps(row, sort_keys=True))
    return "\n".join(lines) + ("\n" if lines else "")


def write_text(path: str, text: str) -> str:
    """Write an export artifact; returns ``path`` for chaining."""
    with open(path, "w") as f:
        f.write(text)
    return path
