"""Health / readiness probes for the serving plane.

Kubernetes-style split: **readiness** means the component can take
traffic right now (stage calibrated, journal attached); **health** means
it is not degrading (runaway drop fraction, stale snapshots, a kernel
backend that died).  Probes are pure functions over the components'
existing counters — no background threads, no wall-clock reads — so
they are as deterministic as the state they inspect.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

__all__ = ["Probe", "probe_stage", "probe_journal", "probe_backend",
           "readyz", "healthz"]

#: (name, ok, detail) — the unit every aggregate reduces over.
Probe = Tuple[str, bool, str]


def probe_stage(stage, max_drop_fraction: float = 0.5) -> Probe:
    """A ServedStage is unhealthy when it sheds more than
    ``max_drop_fraction`` of its arrivals — a budget collapse the
    §4.5.2 probe machinery should have recovered from."""
    stats = getattr(stage, "stats", None)
    if not stats:
        return ("stage", False, "no stats surface")
    arrived = float(stats.get("arrived", 0))
    dropped = float(stats.get("dropped", 0))
    if arrived == 0:
        return ("stage", True, "idle")
    frac = dropped / arrived
    ok = frac <= max_drop_fraction
    return ("stage", ok, f"drop_fraction={frac:.3f}")


def probe_journal(journal, t_now: Optional[float] = None,
                  max_staleness_periods: float = 2.0) -> Probe:
    """A journal is unhealthy when its last snapshot is more than
    ``max_staleness_periods`` snapshot periods behind ``t_now`` — a
    restore would replay an unbounded tail."""
    if journal is None:
        return ("journal", False, "no journal attached")
    snapshots = getattr(journal, "snapshots", None) or []
    if not snapshots:
        # Before the first period elapses that is expected, not a failure.
        period = float(getattr(journal, "snapshot_period_s", 0.0) or 0.0)
        ok = t_now is None or period <= 0 or t_now < max_staleness_periods * period
        return ("journal", ok, "no snapshot yet")
    snap = snapshots[-1]
    if t_now is None:
        return ("journal", True, f"snapshot@t={snap['time']}")
    period = float(getattr(journal, "snapshot_period_s", 0.0) or 0.0)
    lag = t_now - float(snap["time"])
    ok = period <= 0 or lag <= max_staleness_periods * period
    return ("journal", ok, f"snapshot_lag_s={lag}")


def probe_backend() -> Probe:
    """The kernel plane is unhealthy once a device call has failed and
    forced the host-reference fallback (``dispatch.last_device_error``)."""
    try:
        from repro.kernels.megastep import ops
    except Exception as e:  # pragma: no cover - import cycle guard
        return ("backend", False, f"kernel plane unavailable: {e!r}")
    err = ops.last_device_error()
    if not err:
        return ("backend", True, "device path clean")
    return ("backend", False, f"device fallback active: {err}")


def _aggregate(probes: List[Probe]) -> Dict[str, object]:
    return {
        "ok": all(ok for _, ok, _ in probes),
        "components": {name: {"ok": ok, "detail": detail}
                       for name, ok, detail in probes},
    }


def readyz(stage=None, journal=None) -> Dict[str, object]:
    """Readiness: every *attached* component can take traffic.  Absent
    components are simply not probed (a stage without a journal is still
    ready — durability is an opt-in)."""
    probes: List[Probe] = []
    if stage is not None:
        xi = getattr(stage, "xi", None)
        probes.append(("stage", xi is not None, "xi calibrated" if xi else "no xi"))
    if journal is not None:
        probes.append(("journal", True, f"records={len(getattr(journal, 'records', ()))}"))
    if not probes:
        probes.append(("none", True, "nothing attached"))
    return _aggregate(probes)


def healthz(stage=None, journal=None, t_now: Optional[float] = None,
            include_backend: bool = True) -> Dict[str, object]:
    """Liveness/health over the attached components + the kernel plane."""
    probes: List[Probe] = []
    if stage is not None:
        probes.append(probe_stage(stage))
    if journal is not None:
        probes.append(probe_journal(journal, t_now=t_now))
    if include_backend:
        probes.append(probe_backend())
    return _aggregate(probes)
