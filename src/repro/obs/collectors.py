"""Collectors: route every existing ad-hoc signal into the registry.

One collector per signal surface, each registering its metrics (name +
mandatory help, OBS001-checked) and filling them from the component's
already-maintained counters — collectors never add work to any hot path;
they run once, after (or on a cadence outside) the run.

Domain assignment is the determinism contract (see ``obs.metrics``):

* event/sim-state-derived values (pipeline counters, per-query ledgers,
  journal records, latency histograms, dynamism-trace samples, tracer
  spans) register as ``SIM`` and participate in exposition digests;
* engine/shard attribution, jit caches, kernel-plane profiling and
  wall-clock serving-stage counters register as ``WALL`` — they vary
  with backend, mesh width or host timing and are excluded from digests.
"""

from __future__ import annotations

from typing import Iterable, Optional

from repro.obs.metrics import SIM, WALL, MetricsRegistry

__all__ = [
    "collect_scenario",
    "collect_query_result",
    "collect_journal",
    "collect_stage",
    "collect_dispatch",
    "collect_engine",
]

#: PipelineStats attributes aggregated per module (FC/VA/CR/UV).
_TASK_KINDS = ("arrived", "executed", "batches", "probes",
               "accepts_rx", "rejects_rx")
_DROP_KINDS = (("dp1", "dropped_dp1"), ("dp2", "dropped_dp2"),
               ("dp3", "dropped_dp3"), ("dp_fault", "dropped_fault"))


def collect_scenario(registry: MetricsRegistry, scn, res) -> MetricsRegistry:
    """Single-pipeline run: global counters, per-module task stats, the
    end-to-end latency histogram, fault-plane counters and the final
    dynamism-trace sample.  All SIM-domain."""
    registry.counter(
        "repro_source_events_total",
        "Frames sourced by the active camera set over the run.",
    ).inc(res.source_events)
    sink = registry.counter(
        "repro_sink_events_total",
        "Events that completed the full path to the UV sink, by deadline "
        "outcome (on_time: u <= gamma).",
        labels=("outcome",),
    )
    sink.inc(res.on_time, outcome="on_time")
    sink.inc(res.delayed, outcome="delayed")
    lat = registry.histogram(
        "repro_sink_latency_seconds",
        "End-to-end event latency at the sink (u = sink arrival - source "
        "arrival), seconds.",
    )
    for _, u in res.latencies:
        lat.observe(u)
    pos = registry.counter(
        "repro_positives_total",
        "Ground-truth positive frames by outcome.",
        labels=("outcome",),
    )
    pos.inc(res.positives_generated, outcome="generated")
    pos.inc(res.positives_completed, outcome="completed")
    pos.inc(res.positives_dropped, outcome="dropped")
    registry.counter(
        "repro_reid_matched_total",
        "Sink detections matched by the re-id tower.",
    ).inc(res.reid_matched)
    registry.counter(
        "repro_query_pushes_total",
        "QF feedback-edge query updates pushed to VA/CR state.",
    ).inc(res.query_pushes)
    dropped = registry.counter(
        "repro_events_dropped_total",
        "Events dropped before the sink, attributed to the dropping task.",
        labels=("task",),
    )
    for task, n in sorted(res.drops_by_task.items()):
        dropped.inc(n, task=task)
    active = registry.gauge(
        "repro_active_cameras",
        "Active camera set size (spotlight scoping), final and peak.",
        labels=("stat",),
    )
    timeline = res.active_timeline
    active.set(timeline[-1][1] if timeline else 0, stat="final")
    active.set(res.peak_active, stat="peak")

    # Per-module pipeline counters (aggregated: a per-task family would be
    # one series per lazily-built FC).
    compiled = getattr(scn, "compiled", None)
    if compiled is not None:
        mod_events = registry.counter(
            "repro_module_events_total",
            "Pipeline task counters aggregated per dataflow module "
            "(FC/VA/CR/UV).",
            labels=("module", "kind"),
        )
        mod_drops = registry.counter(
            "repro_module_dropped_total",
            "Pipeline drops per module and drop point (dp1-dp3, dp_fault).",
            labels=("module", "cause"),
        )
        tasks = list(compiled.all_tasks()) + [compiled.sink]
        agg: dict = {}
        for t in tasks:
            row = agg.setdefault(t.module or t.name, {})
            for kind in _TASK_KINDS:
                row[kind] = row.get(kind, 0) + getattr(t.stats, kind)
            for cause, attr in _DROP_KINDS:
                row[cause] = row.get(cause, 0) + getattr(t.stats, attr)
        for module in sorted(agg):
            row = agg[module]
            for kind in _TASK_KINDS:
                if row[kind]:
                    mod_events.inc(row[kind], module=module, kind=kind)
            for cause, _ in _DROP_KINDS:
                if row[cause]:
                    mod_drops.inc(row[cause], module=module, cause=cause)

    # Fault plane (PR 6): retry/blocked/fault-drop books.
    faults = getattr(getattr(scn, "sim", None), "faults", None)
    if faults is not None:
        registry.counter(
            "repro_fault_sends_blocked_total",
            "Inter-task sends blocked by a crash window or partition.",
        ).inc(faults.sends_blocked)
        registry.counter(
            "repro_fault_retries_total",
            "Fault-plane transmit retries (capped exponential backoff).",
        ).inc(faults.retries)
        registry.counter(
            "repro_fault_drops_total",
            "Events lost to faults (DP_FAULT): crashed host or retries "
            "exhausted.",
        ).inc(faults.fault_drops)

    # Dynamism trace: the final sampled row per task/aggregate column.
    trace = getattr(res, "trace", None)
    if trace is not None and getattr(trace, "times", None):
        dyn = registry.gauge(
            "repro_dyn_sample",
            "Final dynamism-trace sample per task column and trace field "
            "(beta, queue, drop/signal counters).",
            labels=("task", "field"),
        )
        for task in sorted(trace.series):
            for fld, col in sorted(trace.series[task].items()):
                if col:
                    dyn.set(col[-1], task=task, field=fld)

    tracer = getattr(scn, "tracer", None)
    if tracer is not None:
        tracer.publish_metrics(registry)
    return registry


def collect_journal(registry: MetricsRegistry, journal) -> MetricsRegistry:
    """Journal record stream + snapshot books (SIM: the record stream is
    part of the exact-recovery contract, identical under restore-replay)."""
    if journal is None:
        return registry
    recs = registry.counter(
        "repro_journal_records_total",
        "Journal WAL records by kind (source/sink/drop).",
        labels=("kind",),
    )
    for kind, n in sorted(journal.counts().items()):
        if n:
            recs.inc(n, kind=kind)
    registry.counter(
        "repro_journal_snapshots_total",
        "Frontier snapshots appended by the journal tick.",
    ).inc(len(journal.snapshots))
    return registry


def collect_engine(registry: MetricsRegistry, scn) -> MetricsRegistry:
    """Engine/shard attribution for a MultiQueryScenario run.  WALL-domain
    by definition: the chosen backend, shard count and transfer walls vary
    with the host/mesh, never with the simulated system's state."""
    info = registry.gauge(
        "repro_engine_info",
        "Engine actually used for the run (value 1; fallback reason as a "
        "label, empty when none).",
        labels=("engine", "fallback_reason"),
        domain=WALL,
    )
    info.set(
        1,
        engine=getattr(scn, "engine_used", "interpreted"),
        fallback_reason=getattr(scn, "engine_fallback_reason", ""),
    )
    registry.gauge(
        "repro_engine_xfer_seconds",
        "Device->host transfer wall of the mega-step run (0 off-device).",
        domain=WALL,
    ).set(getattr(scn, "engine_xfer_s", 0.0))
    registry.gauge(
        "repro_engine_shards_used",
        "Camera-mesh shards the fused scan actually ran on.",
        domain=WALL,
    ).set(getattr(scn, "shards_used", 1))
    registry.gauge(
        "repro_engine_collective_bytes_per_tick",
        "Estimated all-reduce payload per simulated tick on the sharded "
        "engine (0 unsharded).",
        domain=WALL,
    ).set(getattr(scn, "collective_bytes_per_tick", 0.0))
    registry.gauge(
        "repro_engine_shard_fallback_info",
        "Why the sharded scan did not run (value 1; empty reason = it ran).",
        labels=("reason",),
        domain=WALL,
    ).set(1, reason=getattr(scn, "shard_fallback_reason", "no-mesh"))
    chunk_s = getattr(scn, "megastep_chunk_s", None)
    if chunk_s is not None:
        registry.gauge(
            "repro_megastep_chunk_seconds",
            "Total host wall of the mega-step scan chunks (device dispatch "
            "+ compute + summary pull).",
            domain=WALL,
        ).set(chunk_s)
        registry.gauge(
            "repro_megastep_chunks",
            "Number of K-tick scan chunks the mega-step run dispatched.",
            domain=WALL,
        ).set(getattr(scn, "megastep_chunks", 0))
    # The kernel plane is part of the engine story: dispatch counters,
    # per-bucket compile counts and jit-cache occupancy ride along.
    collect_dispatch(registry)
    return registry


def collect_query_result(registry: MetricsRegistry, scn, res) -> MetricsRegistry:
    """Multi-query run: the global scenario collectors plus per-query
    ledgers, admission books, the journal, and engine attribution."""
    collect_scenario(registry, scn, res.result)
    qev = registry.counter(
        "repro_query_events_total",
        "Per-query event ledger (sourced/completed/dropped and the orphan "
        "classes reconciling late events after cancel/expiry).",
        labels=("query", "kind"),
    )
    qdrop = registry.counter(
        "repro_query_dropped_total",
        "Per-query drops by drop point (dp1-dp3, dp_fault).",
        labels=("query", "cause"),
    )
    qpos = registry.counter(
        "repro_query_positives_total",
        "Per-query ground-truth positives by outcome.",
        labels=("query", "outcome"),
    )
    qbeta = registry.gauge(
        "repro_query_beta_seconds",
        "Per-query completion budget (beta) at end of run.",
        labels=("query",),
    )
    qstate = registry.gauge(
        "repro_query_state_info",
        "Per-query lifecycle state at end of run (value 1).",
        labels=("query", "state"),
    )
    qflight = registry.gauge(
        "repro_query_in_flight",
        "Per-query events still in flight at the horizon.",
        labels=("query",),
    )
    for qid, st in sorted(res.registry.states.items()):
        q = str(qid)
        for kind in ("sourced", "completed", "dropped", "on_time", "delayed",
                     "orphan_completed", "orphan_dropped"):
            v = getattr(st, kind)
            if v:
                qev.inc(v, query=q, kind=kind)
        for i, cause in ((1, "dp1"), (2, "dp2"), (3, "dp3"), (4, "dp_fault")):
            if st.dp[i]:
                qdrop.inc(st.dp[i], query=q, cause=cause)
        if st.positives_generated:
            qpos.inc(st.positives_generated, query=q, outcome="generated")
        if st.positives_completed:
            qpos.inc(st.positives_completed, query=q, outcome="completed")
        qbeta.set(st.beta(), query=q)
        qstate.set(1, query=q, state=st.state)
        qflight.set(st.in_flight, query=q)
    adm = res.admission
    if adm is not None:
        dec = registry.counter(
            "repro_admission_decisions_total",
            "Admission-controller decisions by verdict.",
            labels=("decision",),
        )
        for k, v in sorted(adm.decisions.items()):
            if v:
                dec.inc(v, decision=k)
        registry.gauge(
            "repro_admission_queue_len",
            "Admission queue length at end of run.",
        ).set(len(adm.queue))
    collect_journal(registry, getattr(scn, "journal", None))
    collect_engine(registry, scn)
    return registry


def collect_stage(registry: MetricsRegistry, stage,
                  query_ids: Optional[Iterable[int]] = None) -> MetricsRegistry:
    """ServedStage counters + per-query telemetry rows.  WALL-domain: the
    serving plane runs on the host clock (``core.clock.monotonic`` /
    ``time.monotonic``), so its counters are not replay-deterministic."""
    sev = registry.counter(
        "repro_stage_events_total",
        "Serving-stage counters (TRACE_FIELDS-shaped row) per stage.",
        labels=("stage", "kind"),
        domain=WALL,
    )
    sgauge = registry.gauge(
        "repro_stage_row",
        "Serving-stage budget/queue sample per stage (beta seconds, queue "
        "depth).",
        labels=("stage", "field"),
        domain=WALL,
    )
    row = stage.telemetry()
    for fld, v in sorted(row.items()):
        if fld in ("beta", "queue"):
            sgauge.set(v, stage=stage.name, field=fld)
        elif v:
            sev.inc(v, stage=stage.name, kind=fld)
    qids = sorted(query_ids) if query_ids is not None else stage.query_ids()
    if qids:
        qev = registry.counter(
            "repro_stage_query_events_total",
            "Serving-stage per-query telemetry counters (same row shape as "
            "the stage-wide sample).",
            labels=("stage", "query", "kind"),
            domain=WALL,
        )
        qgauge = registry.gauge(
            "repro_stage_query_row",
            "Serving-stage per-query budget/queue sample.",
            labels=("stage", "query", "field"),
            domain=WALL,
        )
        for qid in qids:
            qrow = stage.telemetry(query_id=qid)
            for fld, v in sorted(qrow.items()):
                if fld in ("beta", "queue"):
                    qgauge.set(v, stage=stage.name, query=str(qid), field=fld)
                elif v:
                    qev.inc(v, stage=stage.name, query=str(qid), kind=fld)
    return registry


def collect_dispatch(registry: MetricsRegistry) -> MetricsRegistry:
    """Kernel-plane profile: call/compile counters, jit cache occupancy and
    accumulated dispatch wall (WALL: host timing + backend-dependent)."""
    from repro.kernels import dispatch

    stats = dispatch.stats()
    calls = registry.counter(
        "repro_kernel_calls_total",
        "Padded-kernel dispatches by entry point.",
        labels=("kernel",),
        domain=WALL,
    )
    for kind in ("reid_calls", "reid_multi_calls", "ball_calls"):
        if stats.get(kind):
            calls.inc(stats[kind], kernel=kind.rsplit("_calls", 1)[0])
    cache = registry.counter(
        "repro_kernel_device_cache_events_total",
        "Device-resident gallery cache hits/misses.",
        labels=("event",),
        domain=WALL,
    )
    if stats.get("device_cache_hits"):
        cache.inc(stats["device_cache_hits"], event="hit")
    if stats.get("device_cache_misses"):
        cache.inc(stats["device_cache_misses"], event="miss")
    profile = dispatch.profile()
    compiles = registry.counter(
        "repro_kernel_compiles_total",
        "Distinct padded bucket shapes compiled, per kernel entry point "
        "(each new shape is one XLA compile).",
        labels=("kernel",),
        domain=WALL,
    )
    for kernel, n in sorted(profile["compiles"].items()):
        if n:
            compiles.inc(n, kernel=kernel)
    wall = registry.counter(
        "repro_kernel_dispatch_seconds_total",
        "Accumulated host wall inside kernel dispatch entry points "
        "(core.clock.monotonic).",
        labels=("kernel",),
        domain=WALL,
    )
    for kernel, s in sorted(profile["dispatch_wall_s"].items()):
        if s:
            wall.inc(s, kernel=kernel)
    sizes = registry.gauge(
        "repro_jit_cache_entries",
        "Entries currently held by each bounded jit cache.",
        labels=("cache",),
        domain=WALL,
    )
    for name, n in sorted(dispatch.jit_cache_sizes().items()):
        sizes.set(n, cache=name)
    return registry
