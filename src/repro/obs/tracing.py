"""Event-flow span tracing for the compiled pipeline.

A sampled event gets one :class:`Span` covering its full causal path
(source FC → VA → CR → UV sink), with per-hop transit attribution
(IPC: same host, MAN: an edge host on either end, LAN: node-to-node),
fault-plane retry annotations, and drop causality (dp1/dp2/dp3 and
DP_FAULT) recorded as span events.

The tracer is duck-typed from ``core/pipeline.py``'s point of view: tasks
hold ``self.tracer = None`` and pay a single attribute test per arrival —
the hot path is unchanged when tracing is off, and never imports this
module.  Sampling is id-strided (every ``stride``-th event relative to
the first id the tracer sees), so the span set for a deterministic run
is itself deterministic: event ids are assigned in event order, and the
lazily-captured base id makes spans independent of how many events other
in-process runs consumed from the process-global id counter.

Known limitation: fully fused FC hops (``CompiledApp.fuse_fc``) bypass
the FC Task objects entirely, so those spans begin at the VA hop.
Installing a tracer via ``CompiledApp.install_tracer`` also disables the
bulk same-destination delivery fast path so every arrival is observed.
"""

from __future__ import annotations

from typing import Dict, List, Optional

__all__ = ["Span", "EventTracer", "transit_class"]


def transit_class(src_host: str, dst_host: str) -> str:
    """Transit attribution, mirroring the simulator's latency classes:
    same host → IPC; an edge host on either end → MAN; else LAN."""
    if src_host == dst_host:
        return "ipc"
    if src_host.startswith("edge") or dst_host.startswith("edge"):
        return "man"
    return "lan"


class Span:
    """One sampled event's causal trace."""

    __slots__ = ("event_id", "is_probe", "hops", "events", "status", "latency")

    def __init__(self, event_id: int, is_probe: bool) -> None:
        self.event_id = event_id
        self.is_probe = is_probe
        #: [{"task", "module", "host", "t", "transit"}, ...] in hop order.
        self.hops: List[Dict[str, object]] = []
        #: [{"kind": "drop"|"retry", ...}, ...] in sim-time order.
        self.events: List[Dict[str, object]] = []
        self.status = "in_flight"
        self.latency: Optional[float] = None

    def to_row(self) -> Dict[str, object]:
        """Plain-dict row for JSONL export (OTLP-shaped, see export.py)."""
        return {
            "event_id": self.event_id,
            "is_probe": self.is_probe,
            "status": self.status,
            "latency_s": self.latency,
            "hops": list(self.hops),
            "events": list(self.events),
        }


class EventTracer:
    """Collects :class:`Span`s from the pipeline's tracer hooks.

    ``stride`` samples every N-th event id; ``max_spans`` bounds memory —
    once the finished list is full no new spans start (counted in
    ``spans_overflowed``, never silently)."""

    def __init__(self, stride: int = 16, max_spans: int = 1024) -> None:
        if stride < 1:
            raise ValueError("stride must be >= 1")
        self.stride = int(stride)
        self.max_spans = int(max_spans)
        self._base_id: Optional[int] = None
        self._active: Dict[int, Span] = {}
        self.finished: List[Span] = []
        self.spans_started = 0
        self.spans_overflowed = 0
        self.retries_seen = 0
        self.drops_seen = 0

    # ------------------------------------------------------------------ #
    def _sampled(self, event_id: int) -> bool:
        if self._base_id is None:
            self._base_id = event_id
        return (event_id - self._base_id) % self.stride == 0

    def _finish(self, span: Span, status: str) -> None:
        span.status = status
        self._active.pop(span.event_id, None)
        self.finished.append(span)

    # ------------------------------------------------------------------ #
    # Hooks (called from core/pipeline.py via the duck-typed contract)    #
    # ------------------------------------------------------------------ #
    def on_arrival(self, task, header, t: float) -> None:
        eid = header.event_id
        span = self._active.get(eid)
        if span is None:
            if not self._sampled(eid):
                return
            if len(self.finished) + len(self._active) >= self.max_spans:
                self.spans_overflowed += 1
                return
            span = Span(eid, bool(header.is_probe))
            self._active[eid] = span
            self.spans_started += 1
        host = task.node
        prev = span.hops[-1] if span.hops else None
        transit = transit_class(str(prev["host"]), host) if prev else "source"
        span.hops.append(
            {
                "task": task.name,
                "module": task.module or task.name,
                "host": host,
                "t": t,
                "transit": transit,
            }
        )

    def on_drop(self, task, header, t: float, point: int, epsilon: float) -> None:
        span = self._active.get(header.event_id)
        if span is None:
            return
        self.drops_seen += 1
        span.events.append(
            {
                "kind": "drop",
                "task": task.name,
                "t": t,
                "point": int(point),
                "epsilon": float(epsilon),
            }
        )
        self._finish(span, "dropped")

    def on_retry(self, task, header, t: float, attempt: int) -> None:
        span = self._active.get(header.event_id)
        if span is None:
            return
        self.retries_seen += 1
        span.events.append(
            {"kind": "retry", "task": task.name, "t": t, "attempt": int(attempt)}
        )

    def on_sink(self, task, header, t: float, latency: float) -> None:
        span = self._active.get(header.event_id)
        if span is None:
            return
        span.latency = float(latency)
        self._finish(span, "completed")

    # ------------------------------------------------------------------ #
    def all_spans(self) -> List[Span]:
        """Finished spans plus still-open ones, in start order."""
        return self.finished + list(self._active.values())

    def to_rows(self) -> List[Dict[str, object]]:
        """Span rows with event ids made *relative* to the tracer's base:
        absolute ids come from a process-global counter, so two otherwise
        bit-identical in-process runs would disagree on them.  Relative
        rows are deterministic per (config, seed) — exportable and
        comparable like the SIM metrics."""
        base = self._base_id or 0
        rows = []
        for s in self.all_spans():
            row = s.to_row()
            row["event_id"] = int(row["event_id"]) - base
            rows.append(row)
        return rows

    def publish_metrics(self, registry) -> None:
        """Register + set the tracer's own SIM-domain signal counters."""
        spans = registry.counter(
            "repro_trace_spans_total",
            "Spans recorded by the event tracer, by terminal status.",
            labels=("status",),
        )
        for status in ("completed", "dropped", "in_flight"):
            n = sum(1 for s in self.all_spans() if s.status == status)
            if n:
                spans.inc(n, status=status)
        hops = registry.counter(
            "repro_trace_hops_total",
            "Span hops by transit class (ipc/lan/man/source).",
            labels=("transit",),
        )
        for s in self.all_spans():
            for h in s.hops:
                hops.inc(transit=h["transit"])
        retries = registry.counter(
            "repro_trace_retries_total",
            "Fault-plane retry annotations recorded on sampled spans.",
        )
        if self.retries_seen:
            retries.inc(self.retries_seen)
        overflowed = registry.counter(
            "repro_trace_spans_overflowed_total",
            "Sampled events not traced because max_spans was reached.",
        )
        if self.spans_overflowed:
            overflowed.inc(self.spans_overflowed)
