"""Unified observability plane: metrics registry, span tracing, exporters.

Layering: ``repro.obs`` may import any other repro package (it observes
them); nothing on a hot path imports ``repro.obs`` — the pipeline's
tracer hooks are duck-typed and default to ``None``.

    from repro.obs import MetricsRegistry, EventTracer, collect_query_result
    reg = MetricsRegistry()
    scn = MultiQueryScenario(cfg, specs)
    res = scn.run()
    collect_query_result(reg, scn, res)
    print(reg.exposition())          # Prometheus text format
    print(reg.digest())              # sha256 of the SIM-domain exposition
"""

from repro.obs.collectors import (
    collect_dispatch,
    collect_engine,
    collect_journal,
    collect_query_result,
    collect_scenario,
    collect_stage,
)
from repro.obs.export import (
    exposition_digest,
    metrics_jsonl,
    prometheus_exposition,
    spans_jsonl,
    write_text,
)
from repro.obs.health import healthz, probe_backend, probe_journal, probe_stage, readyz
from repro.obs.metrics import (
    REGISTRY,
    SIM,
    WALL,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.tracing import EventTracer, Span, transit_class

__all__ = [
    "REGISTRY",
    "SIM",
    "WALL",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "EventTracer",
    "Span",
    "transit_class",
    "collect_scenario",
    "collect_query_result",
    "collect_journal",
    "collect_stage",
    "collect_dispatch",
    "collect_engine",
    "prometheus_exposition",
    "exposition_digest",
    "metrics_jsonl",
    "spans_jsonl",
    "write_text",
    "healthz",
    "readyz",
    "probe_stage",
    "probe_journal",
    "probe_backend",
]
