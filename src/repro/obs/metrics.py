"""Typed metrics registry with a hard sim-time / wall-clock split.

Every signal the platform emits — pipeline counters, per-query ledgers,
journal records, engine/shard attribution, kernel-plane profiling — is
registered here as a Counter, Gauge or Histogram with a mandatory help
string and a declared label set.  The registry enforces the one invariant
the rest of the repo's determinism gates depend on:

* ``SIM``-domain metrics are derived **purely from event/sim state**.
  Their values (and the Prometheus exposition built from them) must be
  bit-identical across an uninterrupted run, a journal restore-replay,
  and every camera-mesh width.  Digests cover the SIM domain only.
* ``WALL``-domain metrics may read host time — exclusively through
  ``repro.core.clock.monotonic()`` (DET002-clean) — or other
  machine-varying state (engine choice, shard count, jit cache sizes).
  They are exported alongside the SIM metrics but never digested.

Metric names must match ``repro_[a-z][a-z0-9_]*`` (analyzer rule OBS001
statically checks every registration site carries a literal, conforming
name and a non-empty help string).
"""

from __future__ import annotations

import hashlib
import math
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "SIM",
    "WALL",
    "Counter",
    "Gauge",
    "Histogram",
    "Metric",
    "MetricsRegistry",
    "DEFAULT_BUCKETS",
]

#: Determinism domains (see module docstring).
SIM = "sim"
WALL = "wall"

_NAME_RE = re.compile(r"^repro_[a-z][a-z0-9_]*$")
_LABEL_RE = re.compile(r"^[a-z_][a-z0-9_]*$")

#: Latency-shaped default buckets (seconds): spans the IPC floor (~50 us)
#: through the multi-second delayed-frame tail.
DEFAULT_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def _fmt(v: float) -> str:
    """Prometheus sample-value formatting, bit-stable for digesting.

    ``repr`` of a Python float is the shortest round-tripping decimal —
    deterministic across runs and platforms for identical bit patterns.
    Integral values render without the trailing ``.0`` (matching common
    exposition style and keeping counter lines clean)."""
    if v != v:  # NaN
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class Metric:
    """Base: a named family of label-addressed series."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labels: Sequence[str], domain: str):
        if not _NAME_RE.match(name):
            raise ValueError(
                f"metric name {name!r} must match {_NAME_RE.pattern}"
            )
        if not help or not str(help).strip():
            raise ValueError(f"metric {name!r} requires non-empty help text")
        if domain not in (SIM, WALL):
            raise ValueError(f"metric {name!r}: unknown domain {domain!r}")
        for lab in labels:
            if not _LABEL_RE.match(lab):
                raise ValueError(f"metric {name!r}: bad label name {lab!r}")
        self.name = name
        self.help = str(help).strip()
        self.label_names: Tuple[str, ...] = tuple(labels)
        self.domain = domain
        # label-value tuple -> scalar (Counter/Gauge) or histogram state.
        self._series: Dict[Tuple[str, ...], float] = {}

    # ------------------------------------------------------------------ #
    def _key(self, labels: Dict[str, object]) -> Tuple[str, ...]:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} expects labels {self.label_names}, "
                f"got {tuple(sorted(labels))}"
            )
        return tuple(str(labels[k]) for k in self.label_names)

    def signature(self) -> Tuple[str, str, Tuple[str, ...], str]:
        return (self.kind, self.help, self.label_names, self.domain)

    def samples(self) -> List[Tuple[str, Tuple[Tuple[str, str], ...], float]]:
        """(suffix-qualified name, ((label, value), ...), value) rows in
        deterministic (sorted label-tuple) order."""
        out = []
        for key in sorted(self._series):
            out.append((self.name, tuple(zip(self.label_names, key)), self._series[key]))
        return out

    def clear(self) -> None:
        self._series.clear()


class Counter(Metric):
    """Monotone cumulative count."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels: object) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name!r}: negative increment {amount}")
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels: object) -> float:
        return self._series.get(self._key(labels), 0.0)


class Gauge(Metric):
    """Point-in-time value (set wins; inc/dec supported)."""

    kind = "gauge"

    def set(self, value: float, **labels: object) -> None:
        self._series[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels: object) -> None:
        key = self._key(labels)
        self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels: object) -> float:
        return self._series.get(self._key(labels), 0.0)


class Histogram(Metric):
    """Cumulative-bucket histogram (Prometheus semantics)."""

    kind = "histogram"

    def __init__(self, name, help, labels, domain, buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, labels, domain)
        bks = tuple(float(b) for b in buckets)
        if list(bks) != sorted(bks) or len(set(bks)) != len(bks):
            raise ValueError(f"histogram {name!r}: buckets must be sorted unique")
        self.buckets = bks
        # label tuple -> [per-bucket counts..., +Inf count]; sum/count kept
        # in parallel dicts so `samples` can emit the full exposition.
        self._counts: Dict[Tuple[str, ...], List[int]] = {}
        self._sums: Dict[Tuple[str, ...], float] = {}

    def observe(self, value: float, **labels: object) -> None:
        key = self._key(labels)
        counts = self._counts.get(key)
        if counts is None:
            counts = self._counts[key] = [0] * (len(self.buckets) + 1)
            self._sums[key] = 0.0
        # First bucket whose upper bound admits the value (+Inf fallback).
        idx = len(self.buckets)
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                idx = i
                break
        counts[idx] += 1
        self._sums[key] += float(value)

    def count(self, **labels: object) -> int:
        counts = self._counts.get(self._key(labels))
        return sum(counts) if counts else 0

    def samples(self):
        out = []
        for key in sorted(self._counts):
            counts = self._counts[key]
            base = tuple(zip(self.label_names, key))
            cum = 0
            for ub, c in zip(self.buckets, counts):
                cum += c
                out.append((f"{self.name}_bucket", base + (("le", _fmt(ub)),), float(cum)))
            cum += counts[-1]
            out.append((f"{self.name}_bucket", base + (("le", "+Inf"),), float(cum)))
            out.append((f"{self.name}_sum", base, self._sums[key]))
            out.append((f"{self.name}_count", base, float(cum)))
        return out

    def clear(self) -> None:
        self._counts.clear()
        self._sums.clear()


class MetricsRegistry:
    """Registration + collection surface.

    Re-registering a name with an identical signature returns the
    existing metric (collectors can run repeatedly against one registry);
    a signature mismatch is a hard error — two meanings for one name is
    exactly the ambiguity the registry exists to prevent."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    # ------------------------------------------------------------------ #
    def _register(self, cls, name, help, labels, domain, **kw) -> Metric:
        existing = self._metrics.get(name)
        if existing is not None:
            fresh = cls(name, help, labels, domain, **kw)
            if existing.signature() != fresh.signature():
                raise ValueError(
                    f"metric {name!r} re-registered with a different "
                    f"signature: {existing.signature()} != {fresh.signature()}"
                )
            return existing
        m = cls(name, help, labels, domain, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str, labels: Sequence[str] = (),
                domain: str = SIM) -> Counter:
        return self._register(Counter, name, help, labels, domain)

    def gauge(self, name: str, help: str, labels: Sequence[str] = (),
              domain: str = SIM) -> Gauge:
        return self._register(Gauge, name, help, labels, domain)

    def histogram(self, name: str, help: str, labels: Sequence[str] = (),
                  domain: str = SIM,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labels, domain,
                              buckets=buckets)

    # ------------------------------------------------------------------ #
    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def collect(self, domain: Optional[str] = None) -> Iterable[Metric]:
        """Metrics in sorted-name order, optionally filtered by domain."""
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if domain is None or m.domain == domain:
                yield m

    def clear_values(self) -> None:
        """Reset every series, keeping registrations (help text, labels)."""
        for m in self._metrics.values():
            m.clear()

    # ------------------------------------------------------------------ #
    # Exposition + digest (delegates to repro.obs.export for the format)  #
    # ------------------------------------------------------------------ #
    def exposition(self, include_wall: bool = True) -> str:
        from repro.obs.export import prometheus_exposition

        return prometheus_exposition(self, include_wall=include_wall)

    def digest(self) -> str:
        """sha256 over the SIM-domain exposition only: the bit-identity
        contract explicitly excludes wall-clock/engine-attribution rows."""
        text = self.exposition(include_wall=False)
        return hashlib.sha256(text.encode()).hexdigest()


#: Process-default registry for callers that don't thread their own.
#: Determinism tests construct private registries instead — cumulative
#: counters on a shared default would double across in-process runs.
REGISTRY = MetricsRegistry()
