"""Model substrate: the ten assigned architectures (+ unified zoo API)."""

from .model_zoo import (
    decode,
    forward,
    init_cache,
    init_params,
    input_specs,
    prefill,
    reduced_config,
)

__all__ = [
    "decode", "forward", "init_cache", "init_params", "input_specs",
    "prefill", "reduced_config",
]
