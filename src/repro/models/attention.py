"""Grouped-query attention with the assigned archs' variants:

* GQA with arbitrary (n_heads, n_kv_heads)        [all dense archs]
* qk_norm (per-head RMSNorm on q and k)           [qwen3]
* QKV bias                                        [qwen2, whisper]
* sliding-window attention                        [hymba; long_500k variant]
* M-RoPE                                          [qwen2-vl]
* cross-attention over precomputed encoder KV     [whisper decoder]
* KV-cache prefill + single-token decode

Compute goes through the kernel wrappers (Pallas on TPU, jnp oracle on CPU).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from .layers import (
    Params,
    apply_mrope,
    apply_rope,
    init_linear,
    init_norm,
    linear,
    rms_norm,
)

__all__ = [
    "init_attention",
    "attention_forward",
    "attention_decode",
    "init_cross_attention",
    "cross_attention_forward",
    "init_kv_cache",
]


def init_attention(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, hd = cfg.d_model, cfg.head_dim_
    k0, k1, k2, k3 = jax.random.split(key, 4)
    p: Params = {
        "wq": init_linear(k0, d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(k1, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(k2, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(k3, cfg.n_heads * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(hd, dtype)
        p["k_norm"] = init_norm(hd, dtype)
    return p


def _project_qkv(
    params: Params, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = linear(params["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = linear(params["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = linear(params["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = rms_norm(params["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _rope(q, k, positions, cfg: ModelConfig):
    if cfg.mrope_sections:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    elif not cfg.learned_pos_emb:  # whisper uses learned absolute positions
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attention_forward(
    params: Params,
    x: jax.Array,  # (B, S, d)
    cfg: ModelConfig,
    positions: jax.Array,  # (B, S) or (3, B, S) for M-RoPE
    *,
    window: int = 0,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence attention (train / prefill)."""
    q, k, v = _project_qkv(params, x, cfg)
    q, k = _rope(q, k, positions, cfg)
    out = flash_attention(q, k, v, causal=causal, window=window)
    B, S = x.shape[:2]
    return linear(params["wo"], out.reshape(B, S, -1))


def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> Dict[str, jax.Array]:
    """Head-major KV cache: (B, H_kv, T, D).

    Decode's cache dot contracts D with batch dims (B, H) — head-major makes
    those the leading axes, so the cache streams through the step with ZERO
    transpose copies (§Perf H3: the (B, T, H, D) layout cost ~2x cache bytes
    in transpose materialization per step, per layer)."""
    hd = cfg.head_dim_
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, max_len, hd), dtype),
        "v": jnp.zeros((batch, cfg.n_kv_heads, max_len, hd), dtype),
    }


def _write_prefill(cache_arr: jax.Array, new: jax.Array) -> jax.Array:
    """Write prefill K/V into the head-major cache (may be a ring buffer).

    ``new`` is (B, S, H, D) from the projection; the cache is (B, H, T, D).
    Full cache (capacity >= S): contiguous write at slot 0.  Sliding-window
    ring (capacity < S): keep the last ``capacity`` tokens, laid out so that
    token position ``p`` lands in slot ``p % capacity`` (static gather —
    shapes are compile-time constants)."""
    import numpy as np

    S = new.shape[1]
    cap = cache_arr.shape[2]
    new_hm = jnp.swapaxes(new, 1, 2)  # (B, H, S, D), once per prefill
    if S <= cap:
        return jax.lax.dynamic_update_slice(
            cache_arr, new_hm.astype(cache_arr.dtype), (0,) * cache_arr.ndim
        )
    pos = np.arange(S - cap, S)
    order = np.argsort(pos % cap)  # slot j receives position pos[order[j]]
    tail = new_hm[:, :, pos[order]]
    return tail.astype(cache_arr.dtype)


def attention_prefill(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    cache: Dict[str, jax.Array],
    *,
    window: int = 0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill: full forward + write K/V (ring-aware for SWA layers)."""
    q, k, v = _project_qkv(params, x, cfg)
    q, k = _rope(q, k, positions, cfg)
    out = flash_attention(q, k, v, causal=True, window=window)
    B, S = x.shape[:2]
    new_cache = {
        "k": _write_prefill(cache["k"], k),
        "v": _write_prefill(cache["v"], v),
    }
    return linear(params["wo"], out.reshape(B, S, -1)), new_cache


def attention_decode(
    params: Params,
    x: jax.Array,  # (B, 1, d) — one new token
    cfg: ModelConfig,
    cache: Dict[str, jax.Array],
    cache_len: jax.Array,  # scalar int32: number of valid slots
    *,
    window: int = 0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode: append to cache, attend over valid prefix."""
    B = x.shape[0]
    T = cache["k"].shape[2]  # capacity; == window for SWA ring buffers
    positions = jnp.broadcast_to(cache_len[None, None], (B, 1)).astype(jnp.int32)
    if cfg.mrope_sections:
        positions = jnp.stack([positions] * len(cfg.mrope_sections), axis=0)
    q, k, v = _project_qkv(params, x, cfg)
    q, k = _rope(q, k, positions, cfg)
    # Ring write: position p lives in slot p % capacity (== p when the
    # cache is full-length).  Attention is permutation-invariant given the
    # validity mask, and RoPE was applied at write time, so ring order is
    # safe (see DESIGN.md §5).
    slot = jax.lax.rem(cache_len, jnp.int32(T))
    zero = jnp.zeros((), jnp.int32)
    # Head-major write: the (B, 1, H, D) projection becomes (B, H, 1, D).
    new_cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], jnp.swapaxes(k, 1, 2).astype(cache["k"].dtype),
            (zero, zero, slot, zero),
        ),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], jnp.swapaxes(v, 1, 2).astype(cache["v"].dtype),
            (zero, zero, slot, zero),
        ),
    }
    lengths = jnp.full((B,), jnp.minimum(cache_len + 1, T), jnp.int32)
    # The ring itself enforces the window once capacity == window.
    eff_window = 0 if (window and T <= window) else window
    # Pass the cache at its stored dtype: decode_attention reads it exactly
    # once and accumulates in f32 (no whole-cache convert — §Perf H3).
    out = decode_attention(
        q[:, 0],
        new_cache["k"],
        new_cache["v"],
        lengths,
        window=eff_window,
    )
    return linear(params["wo"], out.reshape(B, 1, -1)), new_cache


# --------------------------------------------------------------------- #
# Cross-attention (whisper decoder)                                      #
# --------------------------------------------------------------------- #
def init_cross_attention(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    return init_attention(key, cfg, dtype)


def cross_attention_forward(
    params: Params,
    x: jax.Array,  # (B, S, d) decoder states
    cross_kv: Tuple[jax.Array, jax.Array],  # precomputed (B, T, Hkv, D) pairs
    cfg: ModelConfig,
) -> jax.Array:
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = linear(params["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k, v = cross_kv
    out = flash_attention(q, k.astype(q.dtype), v.astype(q.dtype), causal=False)
    return linear(params["wo"], out.reshape(B, S, -1))


def cross_attention_kv(
    params: Params, encoder_out: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """Precompute the encoder-side K/V once per request (whisper serving)."""
    B, T, _ = encoder_out.shape
    hd = cfg.head_dim_
    k = linear(params["wk"], encoder_out).reshape(B, T, cfg.n_kv_heads, hd)
    v = linear(params["wv"], encoder_out).reshape(B, T, cfg.n_kv_heads, hd)
    return k, v
