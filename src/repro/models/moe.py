"""Mixture-of-Experts with sort-based (capacity-dropping) dispatch.

Covers both assigned MoE archs:

* deepseek-v2-lite: 64 routed experts top-6 + 2 shared experts (MLA attn)
* qwen2-moe-a2.7b:  60 routed experts top-4 + 4 shared experts

Dispatch avoids the (tokens, E, C) one-hot einsum (OOM at our shapes):
token->expert assignments are sorted by expert id, each expert takes up to
``C = ceil(k * T * capacity_factor / E)`` tokens (overflow dropped — the
standard capacity-based GSPMD-friendly formulation), expert FFNs run as one
batched einsum over the expert dimension, and results scatter back weighted
by the (optionally renormalized) router probabilities.

FLOPs scale as ``k * cf * T * d * f`` — the *active*-parameter roofline —
not ``E * T * d * f``.  Aux losses: Switch-style load-balance + router
z-loss.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, MoEConfig
from .layers import Params, init_linear, init_mlp, linear, mlp

__all__ = ["init_moe", "moe_apply"]


def init_moe(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    mc = cfg.moe
    d = cfg.d_model
    k_router, k_gate, k_up, k_down, k_shared = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p: Params = {
        "router": init_linear(k_router, d, mc.num_experts, dtype=jnp.float32),
        # Stacked expert FFNs: (E, d, f) / (E, f, d).
        "experts": {
            "w_gate": (jax.random.normal(k_gate, (mc.num_experts, d, mc.d_ff_expert)) * scale).astype(dtype),
            "w_up": (jax.random.normal(k_up, (mc.num_experts, d, mc.d_ff_expert)) * scale).astype(dtype),
            "w_down": (
                jax.random.normal(k_down, (mc.num_experts, mc.d_ff_expert, d))
                * (1.0 / math.sqrt(mc.d_ff_expert))
            ).astype(dtype),
        },
    }
    if mc.num_shared_experts > 0:
        p["shared"] = init_mlp(k_shared, d, mc.d_ff_shared, act="silu", dtype=dtype)
    return p


def moe_apply(
    params: Params, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns ``(y, load_balance_loss, router_z_loss)``.  x: (B, S, d)."""
    mc = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = mc.num_experts, mc.top_k
    xt = x.reshape(T, d)

    logits = linear(params["router"], xt.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    if mc.normalize_top_k:
        top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # ---- aux losses (computed on the full router distribution) -------- #
    # Switch load-balance: E * sum_e f_e * P_e.
    ones = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], top_e
    ].set(1.0)
    f_e = jnp.mean(ones, axis=0) / k  # fraction of routed slots per expert
    p_e = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(f_e * p_e)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- sort-based dispatch ------------------------------------------ #
    C = int(math.ceil(k * T * mc.capacity_factor / E))
    C = max(C, 1)
    expert_ids = top_e.reshape(-1)  # (T*k,)
    token_ids = jnp.repeat(jnp.arange(T), k)
    gates = top_p.reshape(-1)

    order = jnp.argsort(expert_ids)  # stable
    sorted_eids = expert_ids[order]
    sorted_tokens = token_ids[order]
    sorted_gates = gates[order]

    counts = jnp.bincount(expert_ids, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * k) - starts[sorted_eids]
    keep = rank < C
    slot = jnp.where(keep, sorted_eids * C + rank, E * C)  # E*C = trash slot

    # Scatter tokens into the (E*C + 1, d) buffer (last row = dropped).
    xk = xt.astype(jnp.float32)[sorted_tokens]  # (T*k, d)
    buf = jnp.zeros((E * C + 1, d), jnp.float32).at[slot].set(xk)
    buf = buf[: E * C].reshape(E, C, d)

    # Batched expert SwiGLU.
    w = params["experts"]
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w["w_gate"].astype(jnp.float32)))
    u = jnp.einsum("ecd,edf->ecf", buf, w["w_up"].astype(jnp.float32))
    yb = jnp.einsum("ecf,efd->ecd", g * u, w["w_down"].astype(jnp.float32))

    # Gather back and combine weighted by the gates.
    yb = jnp.concatenate([yb.reshape(E * C, d), jnp.zeros((1, d), jnp.float32)])
    y_sorted = yb[slot] * sorted_gates[:, None]  # dropped slots contribute 0
    y = jax.ops.segment_sum(y_sorted, sorted_tokens, num_segments=T)

    if "shared" in params:
        y = y + mlp(params["shared"], xt.astype(jnp.float32))

    return y.reshape(B, S, d).astype(x.dtype), lb_loss, z_loss
