"""Decoder-only language model with scan-over-layers.

Consecutive layers with identical :class:`LayerSpec` are stacked into a
*group* whose parameters (and caches) carry a leading layer axis; each group
runs under one ``jax.lax.scan``.  This keeps the lowered HLO size (and
compile time) independent of depth — essential for dry-running an 80-layer
72B model on 512 emulated devices.

Heterogeneous stacks (deepseek's leading dense layer, hymba's global-attn
layers) simply produce several groups.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.distributed.partitioning import constrain
from .blocks import (
    LayerSpec,
    block_decode,
    block_forward,
    block_prefill,
    init_block,
    init_block_cache,
)
from .layers import Params, init_norm, mrope_position_ids, rms_norm

__all__ = [
    "layer_specs",
    "group_specs",
    "init_lm",
    "init_lm_cache",
    "lm_forward",
    "lm_prefill",
    "lm_decode",
]


# --------------------------------------------------------------------- #
# Layer layout                                                           #
# --------------------------------------------------------------------- #
def layer_specs(cfg: ModelConfig, *, decode_long: bool = False) -> Tuple[LayerSpec, ...]:
    """Per-layer specs for an architecture.  ``decode_long`` swaps full
    attention for the sliding-window variant (the long_500k policy,
    DESIGN.md §4)."""
    specs: List[LayerSpec] = []
    for i in range(cfg.n_layers):
        window = cfg.sliding_window
        if cfg.global_attn_layers and i in cfg.global_attn_layers:
            window = 0
        if decode_long and window == 0 and cfg.arch_type not in ("ssm",):
            window = 8192  # forced SWA for long decode (DESIGN.md §4)
        if cfg.arch_type == "ssm":
            specs.append(LayerSpec(mixer="ssm", ffn="none", window=0))
        elif cfg.arch_type == "hybrid":
            specs.append(LayerSpec(mixer="hybrid", ffn="dense", window=window))
        elif cfg.arch_type == "moe":
            mixer = "mla" if cfg.kv_lora_rank else "attn"
            ffn = "dense" if i < cfg.first_k_dense_layers else "moe"
            specs.append(LayerSpec(mixer=mixer, ffn=ffn, window=window))
        else:  # dense | vlm
            specs.append(LayerSpec(mixer="attn", ffn="dense", window=window))
    return tuple(specs)


def group_specs(specs: Sequence[LayerSpec]) -> Tuple[Tuple[LayerSpec, int], ...]:
    """Run-length encode consecutive identical specs into scan groups."""
    groups: List[Tuple[LayerSpec, int]] = []
    for s in specs:
        if groups and groups[-1][0] == s:
            groups[-1] = (s, groups[-1][1] + 1)
        else:
            groups.append((s, 1))
    return tuple(groups)


# --------------------------------------------------------------------- #
# Init                                                                   #
# --------------------------------------------------------------------- #
def init_lm(
    key: jax.Array,
    cfg: ModelConfig,
    *,
    dtype=jnp.float32,
    decode_long: bool = False,
) -> Params:
    specs = layer_specs(cfg, decode_long=decode_long)
    groups = group_specs(specs)
    k_embed, k_head, k_meta, *k_groups = jax.random.split(key, 3 + len(groups))
    V, d = cfg.padded_vocab, cfg.d_model
    params: Params = {
        "embedding": (jax.random.normal(k_embed, (V, d)) * 0.02).astype(dtype),
        "final_norm": init_norm(d, dtype),
        "groups": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k_head, (d, V)) * 0.02).astype(dtype)
    if cfg.meta_tokens:
        params["meta_tokens"] = (
            jax.random.normal(k_meta, (cfg.meta_tokens, d)) * 0.02
        ).astype(dtype)
    for (spec, count), kg in zip(groups, k_groups):
        stacked = jax.vmap(lambda k: init_block(k, cfg, spec, dtype))(
            jax.random.split(kg, count)
        )
        params["groups"].append(stacked)
    return params


def init_lm_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    *,
    dtype=jnp.bfloat16,
    decode_long: bool = False,
) -> List[Dict[str, Any]]:
    specs = layer_specs(cfg, decode_long=decode_long)
    groups = group_specs(specs)
    caches: List[Dict[str, Any]] = []
    for spec, count in groups:
        # Sliding-window layers only need a window-sized cache.
        layer_len = min(max_len, spec.window) if spec.window else max_len
        one = init_block_cache(cfg, spec, batch, layer_len, dtype)
        caches.append(jax.tree.map(lambda a: jnp.stack([a] * count), one))
    return caches


# --------------------------------------------------------------------- #
# Forward paths                                                          #
# --------------------------------------------------------------------- #
def _positions(cfg: ModelConfig, B: int, S: int) -> jax.Array:
    if cfg.mrope_sections:
        return mrope_position_ids(B, S, cfg.mrope_sections)
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))


def _embed(params: Params, cfg: ModelConfig, tokens=None, inputs_embeds=None):
    if inputs_embeds is not None:
        x = inputs_embeds
    else:
        x = jnp.take(params["embedding"], tokens, axis=0)
    return x


def _logits(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"].astype(x.dtype))
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e9, logits)
    return logits


def lm_forward(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,  # (B, S) int32
    *,
    inputs_embeds: Optional[jax.Array] = None,  # (B, S, d) frontend stub
    positions: Optional[jax.Array] = None,
    remat: bool = False,
    decode_long: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full forward; returns ``(logits, aux)`` with router aux losses."""
    x = _embed(params, cfg, tokens, inputs_embeds)
    B, S = x.shape[:2]
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(
            params["meta_tokens"].astype(x.dtype), (B, cfg.meta_tokens, x.shape[-1])
        )
        x = jnp.concatenate([meta, x], axis=1)
        S = S + cfg.meta_tokens
    if positions is None:
        positions = _positions(cfg, B, S)
    x = constrain(x, ("batch", "seq", None))

    specs = layer_specs(cfg, decode_long=decode_long)
    groups = group_specs(specs)
    lb = jnp.zeros((), jnp.float32)
    zl = jnp.zeros((), jnp.float32)
    for (spec, count), stacked in zip(groups, params["groups"]):
        def body(carry, layer_params, _spec=spec):
            h, l, z = carry
            h = constrain(h, ("batch", "seq", None))
            y, dl, dz = block_forward(layer_params, h, cfg, _spec, positions)
            return (y, l + dl, z + dz), None

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        (x, lb, zl), _ = jax.lax.scan(body, (x, lb, zl), stacked)

    if cfg.meta_tokens:
        x = x[:, cfg.meta_tokens :]
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x)
    return logits, {"lb_loss": lb, "z_loss": zl}


def lm_prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    caches: Optional[List[Dict[str, Any]]] = None,
    *,
    inputs_embeds: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    decode_long: bool = False,
) -> Tuple[jax.Array, List[Dict[str, Any]]]:
    """Prefill the caches; returns ``(last_token_logits, new_caches)``.

    Meta tokens (hymba) are prepended here exactly as in ``lm_forward``; the
    cache capacity must therefore cover ``S + cfg.meta_tokens`` slots and the
    engine's ``cache_len`` starts at that value."""
    x = _embed(params, cfg, tokens, inputs_embeds)
    B, S = x.shape[:2]
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(
            params["meta_tokens"].astype(x.dtype), (B, cfg.meta_tokens, x.shape[-1])
        )
        x = jnp.concatenate([meta, x], axis=1)
        S = S + cfg.meta_tokens
    if positions is None:
        positions = _positions(cfg, B, S)
    x = constrain(x, ("batch", "seq", None))
    specs = layer_specs(cfg, decode_long=decode_long)
    groups = group_specs(specs)
    new_caches: List[Dict[str, Any]] = []
    for (spec, count), stacked, cache in zip(groups, params["groups"], caches):
        def body(h, xs, _spec=spec):
            layer_params, layer_cache = xs
            h = constrain(h, ("batch", "seq", None))
            y, new_cache = block_prefill(layer_params, h, cfg, _spec, positions, layer_cache)
            return y, new_cache

        x, updated = jax.lax.scan(body, x, (stacked, cache))
        new_caches.append(updated)
    x = rms_norm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = _logits(params, cfg, x)
    return logits, new_caches


def lm_decode(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,  # (B, 1) int32
    caches: List[Dict[str, Any]],
    cache_len: jax.Array,  # scalar int32
    *,
    decode_long: bool = False,
) -> Tuple[jax.Array, List[Dict[str, Any]]]:
    """One decode step; returns ``(logits, new_caches)``."""
    x = _embed(params, cfg, token)
    x = constrain(x, ("batch", None, None))
    specs = layer_specs(cfg, decode_long=decode_long)
    groups = group_specs(specs)
    new_caches: List[Dict[str, Any]] = []
    for (spec, count), stacked, cache in zip(groups, params["groups"], caches):
        def body(h, xs, _spec=spec):
            layer_params, layer_cache = xs
            y, new_cache = block_decode(layer_params, h, cfg, _spec, layer_cache, cache_len)
            return y, new_cache

        x, updated = jax.lax.scan(body, x, (stacked, cache))
        new_caches.append(updated)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x)
    return logits, new_caches
