"""Shared neural-net layers (functional style: ``init_*`` -> params pytree,
``apply`` functions are pure).

Parameter dictionaries use *conventional key names* (``wq``, ``wk``, ``wv``,
``wo``, ``w_gate``, ``w_up``, ``w_down``, ``embedding`` ...) which the
partitioner (:mod:`repro.distributed.partitioning`) matches path-based rules
against — the model code stays sharding-agnostic.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Params",
    "rms_norm",
    "layer_norm",
    "init_linear",
    "linear",
    "init_norm",
    "init_mlp",
    "mlp",
    "rope_frequencies",
    "apply_rope",
    "mrope_position_ids",
    "apply_mrope",
]

Params = Dict[str, Any]


# --------------------------------------------------------------------- #
# Norms                                                                  #
# --------------------------------------------------------------------- #
def init_norm(d: int, dtype=jnp.float32, with_bias: bool = False) -> Params:
    p: Params = {"scale": jnp.ones((d,), dtype)}
    if with_bias:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def rms_norm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layer_norm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


# --------------------------------------------------------------------- #
# Linear / embedding                                                     #
# --------------------------------------------------------------------- #
def init_linear(
    key: jax.Array,
    d_in: int,
    d_out: int,
    *,
    bias: bool = False,
    dtype=jnp.float32,
    scale: Optional[float] = None,
) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p: Params = {"kernel": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def linear(params: Params, x: jax.Array) -> jax.Array:
    y = x @ params["kernel"].astype(x.dtype)
    if "bias" in params:
        y = y + params["bias"].astype(x.dtype)
    return y


# --------------------------------------------------------------------- #
# MLP (SwiGLU / GELU)                                                    #
# --------------------------------------------------------------------- #
def init_mlp(
    key: jax.Array, d_model: int, d_ff: int, *, act: str = "silu", dtype=jnp.float32
) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "silu":  # SwiGLU: gate + up + down
        return {
            "w_gate": init_linear(k1, d_model, d_ff, dtype=dtype),
            "w_up": init_linear(k2, d_model, d_ff, dtype=dtype),
            "w_down": init_linear(k3, d_ff, d_model, dtype=dtype),
        }
    return {  # classic 2-matrix MLP (whisper)
        "w_up": init_linear(k1, d_model, d_ff, bias=True, dtype=dtype),
        "w_down": init_linear(k2, d_ff, d_model, bias=True, dtype=dtype),
    }


def mlp(params: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    if "w_gate" in params:
        g = jax.nn.silu(linear(params["w_gate"], x))
        u = linear(params["w_up"], x)
        return linear(params["w_down"], g * u)
    h = jax.nn.gelu(linear(params["w_up"], x))
    return linear(params["w_down"], h)


# --------------------------------------------------------------------- #
# RoPE / M-RoPE                                                          #
# --------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """Inverse frequencies for rotary embeddings: (head_dim // 2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    # Pairing convention: split halves (llama/qwen style).
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    theta: float = 10000.0,
) -> jax.Array:
    """Rotary embedding.  ``x``: (B, S, H, D); ``positions``: (B, S) int."""
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)  # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, D/2)
    cos = jnp.cos(ang)[..., None, :]  # (B, S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def mrope_position_ids(
    batch: int, seq: int, sections: Sequence[int]
) -> jax.Array:
    """Text-only M-RoPE positions (3, B, S): all three sections advance with
    the sequence index (qwen2-vl's behaviour for pure-text spans; the vision
    frontend stub supplies real (t,h,w) grids for patch spans)."""
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
    return jnp.stack([pos] * len(sections), axis=0)


def apply_mrope(
    x: jax.Array,
    positions_3d: jax.Array,
    sections: Sequence[int],
    theta: float = 1_000_000.0,
) -> jax.Array:
    """Multimodal RoPE (qwen2-vl, arXiv:2409.12191 §2.1).

    The head-dim frequency bands are partitioned into ``sections`` (t, h, w);
    each band rotates by its own coordinate stream.  ``positions_3d`` is
    (3, B, S).  With all three streams equal this reduces to 1-D RoPE.
    """
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)  # (D/2,)
    # Section s owns a contiguous slice of the frequency bands.
    sec = jnp.asarray(sections)
    band_section = jnp.repeat(jnp.arange(len(sections)), sec, total_repeat_length=d // 2)
    pos = positions_3d.astype(jnp.float32)  # (3, B, S)
    pos_per_band = jnp.take(pos, band_section, axis=0)  # (D/2, ...) -> wrong axis
    # take along axis 0 gives (D/2, B, S); rearrange to (B, S, D/2)
    pos_per_band = jnp.moveaxis(pos_per_band, 0, -1)  # (B, S, D/2)
    ang = pos_per_band * inv  # (B, S, D/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)
