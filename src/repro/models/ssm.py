"""Mamba2 block (SSD, arXiv:2405.21060) — attention-free token mixer.

Layout follows the reference implementation: a fused input projection to
``(z, x, B, C, dt)``, a short depthwise causal conv over ``(x, B, C)``, the
SSD scan (Pallas kernel on TPU / jnp oracle on CPU), a per-head skip ``D``,
a gated RMSNorm and the output projection.

Decode carries two states per layer: the conv window ``(B, d_conv-1, cdim)``
and the SSM state ``(B, H, P, N)`` — constant-size, independent of context
length (why ``long_500k`` is natively sub-quadratic for this family).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.kernels.ssd_scan.ops import ssd_decode_step, ssd_scan
from .layers import Params, init_linear, init_norm, linear, rms_norm

__all__ = ["init_mamba", "mamba_forward", "mamba_prefill", "mamba_decode", "init_mamba_cache"]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    sc = cfg.ssm
    di = sc.d_inner(cfg.d_model)
    nh = sc.n_heads(cfg.d_model)
    cdim = di + 2 * sc.n_groups * sc.d_state
    return di, nh, sc.head_dim, sc.d_state, cdim


def init_mamba(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    sc = cfg.ssm
    d = cfg.d_model
    di, nh, hp, n, cdim = _dims(cfg)
    k_in, k_conv, k_dt, k_a, k_out = jax.random.split(key, 5)
    # dt bias: softplus^-1 of log-uniform [dt_min, dt_max] (ref init).
    u = jax.random.uniform(k_dt, (nh,))
    dt = jnp.exp(u * (math.log(sc.dt_max) - math.log(sc.dt_min)) + math.log(sc.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    lo, hi = sc.a_init_range
    a = jax.random.uniform(k_a, (nh,), minval=lo, maxval=hi)
    return {
        "in_proj": init_linear(k_in, d, 2 * di + 2 * sc.n_groups * n + nh, dtype=dtype),
        "conv_w": (jax.random.normal(k_conv, (cdim, sc.d_conv)) / math.sqrt(sc.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((cdim,), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(a).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_norm": init_norm(di, dtype),
        "out_proj": init_linear(k_out, di, d, dtype=dtype),
    }


def _split_in(proj: jax.Array, cfg: ModelConfig):
    di, nh, hp, n, cdim = _dims(cfg)
    g = cfg.ssm.n_groups
    z = proj[..., :di]
    xBC = proj[..., di : di + cdim]
    dt = proj[..., di + cdim :]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  xBC: (B, L, C); w: (C, K)."""
    B, L, C = xBC.shape
    K = w.shape[1]
    lhs = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    rhs = jnp.transpose(w)[:, None, :]  # (K, 1, C)  — WIO layout
    out = jax.lax.conv_general_dilated(
        lhs.astype(jnp.float32),
        rhs.astype(jnp.float32),
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C,
    )
    return (out + b.astype(jnp.float32)).astype(xBC.dtype)


def _ssd_inputs(params: Params, x: jax.Array, cfg: ModelConfig):
    """Project + conv; returns (z, xh, dt, Bm, Cm) with xh: (B,L,H,P)."""
    di, nh, hp, n, cdim = _dims(cfg)
    g = cfg.ssm.n_groups
    B, L, _ = x.shape
    proj = linear(params["in_proj"], x)
    z, xBC, dt = _split_in(proj, cfg)
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"], params["conv_b"]))
    xs = xBC[..., :di].reshape(B, L, nh, hp)
    Bm = xBC[..., di : di + g * n].reshape(B, L, g, n)
    Cm = xBC[..., di + g * n :].reshape(B, L, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    return z, xs, dt, Bm, Cm, xBC


def mamba_forward(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    di, nh, hp, n, cdim = _dims(cfg)
    B, L, _ = x.shape
    z, xs, dt, Bm, Cm, _ = _ssd_inputs(params, x, cfg)
    A = -jnp.exp(params["A_log"])
    y, _ = ssd_scan(xs, dt, A, Bm, Cm, chunk=cfg.ssm.chunk_size)
    y = y + xs * params["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, L, di)
    y = rms_norm(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return linear(params["out_proj"], y)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    di, nh, hp, n, cdim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, cdim), dtype),
        "ssm": jnp.zeros((batch, nh, hp, n), jnp.float32),
    }


def mamba_prefill(
    params: Params, x: jax.Array, cfg: ModelConfig, cache: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    di, nh, hp, n, cdim = _dims(cfg)
    B, L, _ = x.shape
    z, xs, dt, Bm, Cm, xBC = _ssd_inputs(params, x, cfg)
    A = -jnp.exp(params["A_log"])
    y, final_state = ssd_scan(xs, dt, A, Bm, Cm, chunk=cfg.ssm.chunk_size)
    y = y + xs * params["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, L, di)
    y = rms_norm(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    # Conv state needs the *pre-conv* activations of the last K-1 steps.
    proj = linear(params["in_proj"], x)
    _, xBC_raw, _ = _split_in(proj, cfg)
    K = cfg.ssm.d_conv
    tail = xBC_raw[:, -(K - 1) :, :]
    new_cache = {
        "conv": tail.astype(cache["conv"].dtype),
        "ssm": final_state.astype(cache["ssm"].dtype),
    }
    return linear(params["out_proj"], y), new_cache


def mamba_decode(
    params: Params, x: jax.Array, cfg: ModelConfig, cache: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token step.  x: (B, 1, d)."""
    di, nh, hp, n, cdim = _dims(cfg)
    g = cfg.ssm.n_groups
    B = x.shape[0]
    proj = linear(params["in_proj"], x[:, 0])  # (B, ·)
    z = proj[..., :di]
    xBC_t = proj[..., di : di + cdim]
    dt_t = proj[..., di + cdim :]
    # Conv over the rolled window [cache..., new].
    window = jnp.concatenate([cache["conv"].astype(xBC_t.dtype), xBC_t[:, None, :]], axis=1)
    conv_out = jnp.einsum(
        "bkc,ck->bc", window.astype(jnp.float32), params["conv_w"].astype(jnp.float32)
    ) + params["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(conv_out).astype(x.dtype)
    xs = xBC[..., :di].reshape(B, nh, hp)
    B_t = xBC[..., di : di + g * n].reshape(B, g, n)
    C_t = xBC[..., di + g * n :].reshape(B, g, n)
    dt_t = jax.nn.softplus(dt_t.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, new_state = ssd_decode_step(cache["ssm"], xs, dt_t, A, B_t, C_t)
    y = y + xs * params["D"][None, :, None].astype(xs.dtype)
    y = y.reshape(B, di)
    y = rms_norm(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    new_cache = {
        "conv": window[:, 1:].astype(cache["conv"].dtype),
        "ssm": new_state.astype(cache["ssm"].dtype),
    }
    return linear(params["out_proj"], y)[:, None, :], new_cache
