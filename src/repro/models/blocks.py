"""Decoder-block variants and their cache/prefill/decode wiring.

A layer is described by a :class:`LayerSpec` — ``(mixer, ffn, window)``:

* mixer: ``attn`` | ``mla`` | ``ssm`` | ``hybrid`` (parallel attn+mamba, hymba)
* ffn:   ``dense`` | ``moe`` | ``none`` (mamba2 blocks have no FFN; d_ff=0)
* window: sliding-window size for the attention path (0 = full)

Blocks are pre-norm residual: ``x + mixer(norm1(x))`` then
``x + ffn(norm2(x))``.  Hybrid runs attention and Mamba on the same normed
input and averages the branch outputs (Hymba, arXiv:2411.13676 §2.1 —
per-branch output norms folded into the branches here).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from .attention import (
    attention_decode,
    attention_forward,
    attention_prefill,
    init_attention,
    init_kv_cache,
)
from .layers import Params, init_mlp, init_norm, mlp, rms_norm
from .mla import init_mla, init_mla_cache, mla_decode, mla_forward, mla_prefill
from .moe import init_moe, moe_apply
from .ssm import (
    init_mamba,
    init_mamba_cache,
    mamba_decode,
    mamba_forward,
    mamba_prefill,
)

__all__ = [
    "LayerSpec",
    "init_block",
    "init_block_cache",
    "block_forward",
    "block_prefill",
    "block_decode",
]


@dataclass(frozen=True)
class LayerSpec:
    mixer: str  # attn | mla | ssm | hybrid
    ffn: str  # dense | moe | none
    window: int = 0


def init_block(key: jax.Array, cfg: ModelConfig, spec: LayerSpec, dtype=jnp.float32) -> Params:
    k_mix, k_mamba, k_ffn = jax.random.split(key, 3)
    p: Params = {"norm1": init_norm(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        p["attn"] = init_attention(k_mix, cfg, dtype)
    elif spec.mixer == "mla":
        p["mla"] = init_mla(k_mix, cfg, dtype)
    elif spec.mixer == "ssm":
        p["mamba"] = init_mamba(k_mix, cfg, dtype)
    elif spec.mixer == "hybrid":
        p["attn"] = init_attention(k_mix, cfg, dtype)
        p["mamba"] = init_mamba(k_mamba, cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == "dense":
        p["norm2"] = init_norm(cfg.d_model, dtype)
        p["ffn"] = init_mlp(k_ffn, cfg.d_model, cfg.d_ff, act=cfg.act, dtype=dtype)
    elif spec.ffn == "moe":
        p["norm2"] = init_norm(cfg.d_model, dtype)
        p["moe"] = init_moe(k_ffn, cfg, dtype)
    return p


def init_block_cache(
    cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int, dtype=jnp.bfloat16
) -> Dict[str, Any]:
    cache: Dict[str, Any] = {}
    if spec.mixer in ("attn", "hybrid"):
        cache.update(init_kv_cache(cfg, batch, max_len, dtype))
    if spec.mixer == "mla":
        cache.update(init_mla_cache(cfg, batch, max_len, dtype))
    if spec.mixer in ("ssm", "hybrid"):
        cache.update(init_mamba_cache(cfg, batch, dtype))
    return cache


def _mix_forward(params, x, cfg, spec, positions):
    if spec.mixer == "attn":
        return attention_forward(params["attn"], x, cfg, positions, window=spec.window)
    if spec.mixer == "mla":
        return mla_forward(params["mla"], x, cfg, positions, window=spec.window)
    if spec.mixer == "ssm":
        return mamba_forward(params["mamba"], x, cfg)
    # hybrid: parallel attention + mamba heads, averaged.
    a = attention_forward(params["attn"], x, cfg, positions, window=spec.window)
    m = mamba_forward(params["mamba"], x, cfg)
    return 0.5 * (a + m)


def block_forward(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    spec: LayerSpec,
    positions: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns ``(y, lb_loss, z_loss)`` (zeros when the block has no router)."""
    h = rms_norm(params["norm1"], x, cfg.norm_eps)
    x = x + _mix_forward(params, h, cfg, spec, positions)
    lb = jnp.zeros((), jnp.float32)
    zl = jnp.zeros((), jnp.float32)
    if spec.ffn == "dense":
        x = x + mlp(params["ffn"], rms_norm(params["norm2"], x, cfg.norm_eps), cfg.act)
    elif spec.ffn == "moe":
        y, lb, zl = moe_apply(params["moe"], rms_norm(params["norm2"], x, cfg.norm_eps), cfg)
        x = x + y
    return x, lb, zl


def block_prefill(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    spec: LayerSpec,
    positions: jax.Array,
    cache: Dict[str, Any],
) -> Tuple[jax.Array, Dict[str, Any]]:
    h = rms_norm(params["norm1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if spec.mixer == "attn":
        y, kv = attention_prefill(params["attn"], h, cfg, positions, cache, window=spec.window)
        new_cache.update(kv)
    elif spec.mixer == "mla":
        y, kv = mla_prefill(params["mla"], h, cfg, positions, cache, window=spec.window)
        new_cache.update(kv)
    elif spec.mixer == "ssm":
        y, st = mamba_prefill(params["mamba"], h, cfg, cache)
        new_cache.update(st)
    else:  # hybrid
        ya, kv = attention_prefill(params["attn"], h, cfg, positions, cache, window=spec.window)
        ym, st = mamba_prefill(params["mamba"], h, cfg, cache)
        new_cache.update(kv)
        new_cache.update(st)
        y = 0.5 * (ya + ym)
    x = x + y
    if spec.ffn == "dense":
        x = x + mlp(params["ffn"], rms_norm(params["norm2"], x, cfg.norm_eps), cfg.act)
    elif spec.ffn == "moe":
        y, _, _ = moe_apply(params["moe"], rms_norm(params["norm2"], x, cfg.norm_eps), cfg)
        x = x + y
    return x, new_cache


def block_decode(
    params: Params,
    x: jax.Array,  # (B, 1, d)
    cfg: ModelConfig,
    spec: LayerSpec,
    cache: Dict[str, Any],
    cache_len: jax.Array,
) -> Tuple[jax.Array, Dict[str, Any]]:
    h = rms_norm(params["norm1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if spec.mixer == "attn":
        y, kv = attention_decode(params["attn"], h, cfg, cache, cache_len, window=spec.window)
        new_cache.update(kv)
    elif spec.mixer == "mla":
        y, kv = mla_decode(params["mla"], h, cfg, cache, cache_len, window=spec.window)
        new_cache.update(kv)
    elif spec.mixer == "ssm":
        y, st = mamba_decode(params["mamba"], h, cfg, cache)
        new_cache.update(st)
    else:  # hybrid
        ya, kv = attention_decode(params["attn"], h, cfg, cache, cache_len, window=spec.window)
        ym, st = mamba_decode(params["mamba"], h, cfg, cache)
        new_cache.update(kv)
        new_cache.update(st)
        y = 0.5 * (ya + ym)
    x = x + y
    if spec.ffn == "dense":
        x = x + mlp(params["ffn"], rms_norm(params["norm2"], x, cfg.norm_eps), cfg.act)
    elif spec.ffn == "moe":
        y, _, _ = moe_apply(params["moe"], rms_norm(params["norm2"], x, cfg.norm_eps), cfg)
        x = x + y
    return x, new_cache
