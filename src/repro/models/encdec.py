"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, 1500, d) — the
output of whisper's two conv layers.  We implement the transformer proper:

* Encoder: bidirectional self-attention + GELU MLP, pre-LayerNorm,
  sinusoidal-equivalent learned positions.
* Decoder: causal self-attention (KV cache) + cross-attention over the
  encoder output (K/V precomputed once per request) + GELU MLP.

Serving: ``encdec_prefill`` runs the encoder, precomputes per-layer cross
K/V, prefills the decoder self-cache; ``encdec_decode`` is the one-token
step used by ``decode_32k`` / ``long_500k`` (with SWA on self-attention).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.distributed.partitioning import constrain
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from .attention import (
    attention_decode,
    attention_forward,
    attention_prefill,
    cross_attention_forward,
    cross_attention_kv,
    init_attention,
    init_kv_cache,
)
from .layers import Params, init_linear, init_mlp, init_norm, layer_norm, linear, mlp

__all__ = [
    "init_encdec",
    "encdec_forward",
    "encdec_prefill",
    "encdec_decode",
    "init_encdec_cache",
]


def _init_enc_block(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": init_norm(cfg.d_model, dtype, with_bias=True),
        "attn": init_attention(k1, cfg, dtype),
        "norm2": init_norm(cfg.d_model, dtype, with_bias=True),
        "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff, act="gelu", dtype=dtype),
    }


def _init_dec_block(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": init_norm(cfg.d_model, dtype, with_bias=True),
        "attn": init_attention(k1, cfg, dtype),
        "norm_x": init_norm(cfg.d_model, dtype, with_bias=True),
        "cross": init_attention(k2, cfg, dtype),
        "norm2": init_norm(cfg.d_model, dtype, with_bias=True),
        "ffn": init_mlp(k3, cfg.d_model, cfg.d_ff, act="gelu", dtype=dtype),
    }


def init_encdec(key: jax.Array, cfg: ModelConfig, *, dtype=jnp.float32, max_dec_len: int = 4096) -> Params:
    ke, kd, kt, kp_e, kp_d = jax.random.split(key, 5)
    V, d = cfg.padded_vocab, cfg.d_model
    enc = jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(
        jax.random.split(ke, cfg.n_encoder_layers)
    )
    dec = jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(
        jax.random.split(kd, cfg.n_layers)
    )
    return {
        "embedding": (jax.random.normal(kt, (V, d)) * 0.02).astype(dtype),
        "enc_pos": (jax.random.normal(kp_e, (cfg.encoder_seq, d)) * 0.01).astype(dtype),
        "dec_pos": (jax.random.normal(kp_d, (max_dec_len, d)) * 0.01).astype(dtype),
        "encoder": enc,
        "decoder": dec,
        "enc_final_norm": init_norm(d, dtype, with_bias=True),
        "final_norm": init_norm(d, dtype, with_bias=True),
    }


def _enc_block(params: Params, x: jax.Array, cfg: ModelConfig, positions) -> jax.Array:
    h = layer_norm(params["norm1"], x, cfg.norm_eps)
    x = x + attention_forward(params["attn"], h, cfg, positions, causal=False)
    h = layer_norm(params["norm2"], x, cfg.norm_eps)
    return x + mlp(params["ffn"], h, "gelu")


def encode(params: Params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, T_enc, d) precomputed conv features (frontend stub)."""
    B, T, d = frames.shape
    x = frames + params["enc_pos"][:T].astype(frames.dtype)
    x = constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(h, layer_params):
        h = constrain(h, ("batch", "seq", None))
        return _enc_block(layer_params, h, cfg, positions), None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layer_norm(params["enc_final_norm"], x, cfg.norm_eps)


def _dec_block_full(params, x, cfg, positions, enc_out):
    h = layer_norm(params["norm1"], x, cfg.norm_eps)
    x = x + attention_forward(params["attn"], h, cfg, positions, causal=True)
    h = layer_norm(params["norm_x"], x, cfg.norm_eps)
    kv = cross_attention_kv(params["cross"], enc_out, cfg)
    x = x + cross_attention_forward(params["cross"], h, kv, cfg)
    h = layer_norm(params["norm2"], x, cfg.norm_eps)
    return x + mlp(params["ffn"], h, "gelu")


def encdec_forward(
    params: Params,
    cfg: ModelConfig,
    frames: jax.Array,  # (B, T_enc, d)
    tokens: jax.Array,  # (B, S)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Training forward: encoder + teacher-forced decoder -> logits."""
    enc_out = encode(params, frames, cfg)
    B, S = tokens.shape
    x = jnp.take(params["embedding"], tokens, axis=0)
    x = x + params["dec_pos"][:S].astype(x.dtype)
    x = constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, layer_params):
        h = constrain(h, ("batch", "seq", None))
        return _dec_block_full(layer_params, h, cfg, positions, enc_out), None

    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = layer_norm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"].astype(x.dtype))
    if cfg.padded_vocab != cfg.vocab_size:
        logits = jnp.where(jnp.arange(cfg.padded_vocab) >= cfg.vocab_size, -1e9, logits)
    aux = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}
    return logits, aux


# --------------------------------------------------------------------- #
# Serving                                                                #
# --------------------------------------------------------------------- #
def init_encdec_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> Dict[str, Any]:
    one = init_kv_cache(cfg, batch, max_len, dtype)
    self_cache = jax.tree.map(lambda a: jnp.stack([a] * cfg.n_layers), one)
    hd = cfg.head_dim_
    cross = {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype),
    }
    return {"self": self_cache, "cross": cross}


def encdec_prefill(
    params: Params,
    cfg: ModelConfig,
    frames: jax.Array,
    tokens: jax.Array,
    cache: Dict[str, Any],
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run encoder, precompute cross K/V, prefill decoder self-cache."""
    enc_out = encode(params, frames, cfg)
    B, S = tokens.shape
    x = jnp.take(params["embedding"], tokens, axis=0)
    x = x + params["dec_pos"][:S].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, xs):
        layer_params, self_cache = xs
        hh = layer_norm(layer_params["norm1"], h, cfg.norm_eps)
        y, new_self = attention_prefill(layer_params["attn"], hh, cfg, positions, self_cache)
        h = h + y
        hh = layer_norm(layer_params["norm_x"], h, cfg.norm_eps)
        kv = cross_attention_kv(layer_params["cross"], enc_out, cfg)
        h = h + cross_attention_forward(layer_params["cross"], hh, kv, cfg)
        hh = layer_norm(layer_params["norm2"], h, cfg.norm_eps)
        h = h + mlp(layer_params["ffn"], hh, "gelu")
        return h, {"self": new_self, "cross": {"k": kv[0].astype(self_cache["k"].dtype),
                                               "v": kv[1].astype(self_cache["v"].dtype)}}

    x, updated = jax.lax.scan(body, x, (params["decoder"], cache["self"]))
    new_cache = {"self": updated["self"], "cross": updated["cross"]}
    x = layer_norm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"].astype(x.dtype))
    return logits, new_cache


def encdec_decode(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,  # (B, 1)
    cache: Dict[str, Any],
    cache_len: jax.Array,
    *,
    window: int = 0,
) -> Tuple[jax.Array, Dict[str, Any]]:
    B = token.shape[0]
    x = jnp.take(params["embedding"], token, axis=0)
    max_pos = params["dec_pos"].shape[0]
    pos_idx = jnp.minimum(cache_len, max_pos - 1)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos_idx, 1, 0).astype(x.dtype)[None]

    def body(h, xs):
        layer_params, self_cache, cross_kv = xs
        hh = layer_norm(layer_params["norm1"], h, cfg.norm_eps)
        y, new_self = attention_decode(
            layer_params["attn"], hh, cfg, self_cache, cache_len, window=window
        )
        h = h + y
        hh = layer_norm(layer_params["norm_x"], h, cfg.norm_eps)
        h = h + cross_attention_forward(
            layer_params["cross"], hh, (cross_kv["k"], cross_kv["v"]), cfg
        )
        hh = layer_norm(layer_params["norm2"], h, cfg.norm_eps)
        h = h + mlp(layer_params["ffn"], hh, "gelu")
        return h, new_self

    x, new_self = jax.lax.scan(body, x, (params["decoder"], cache["self"], cache["cross"]))
    new_cache = {"self": new_self, "cross": cache["cross"]}
    x = layer_norm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"].astype(x.dtype))
    return logits, new_cache
