"""Unified model API over all assigned architectures.

``init_params`` / ``forward`` / ``prefill`` / ``decode`` dispatch between the
decoder-only stack (:mod:`repro.models.transformer`) and the whisper
encoder-decoder (:mod:`repro.models.encdec`).  ``input_specs`` builds
ShapeDtypeStruct stand-ins for every model input of a given benchmark shape
(the dry-run never allocates real tensors), and ``reduced_config`` produces
the CPU smoke-test variant of each family (2 layers, d_model<=512, <=4
experts — per the assignment).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import InputShape, ModelConfig, MoEConfig, SSMConfig
from . import encdec as ed
from . import transformer as tf

__all__ = [
    "reduced_config",
    "init_params",
    "init_cache",
    "forward",
    "prefill",
    "decode",
    "input_specs",
    "cache_len_for",
]


# --------------------------------------------------------------------- #
# Reduced (smoke) variants                                               #
# --------------------------------------------------------------------- #
def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """2 layers, d_model<=512, <=4 experts; same family wiring."""
    kw: Dict[str, Any] = dict(
        n_layers=2,
        d_model=256,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=1000,
        head_dim=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=2 if cfg.n_kv_heads else 0,
        meta_tokens=8 if cfg.meta_tokens else 0,
    )
    if cfg.arch_type in ("ssm", "hybrid"):
        kw["ssm"] = SSMConfig(
            d_state=16, d_conv=4, expand=2, head_dim=32, n_groups=1, chunk_size=32
        )
    if cfg.moe.enabled:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            top_k=2,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            d_ff_expert=128,
            d_ff_shared=128,
            # E/k = 2: cf >= 2 makes dispatch dropless, so decode (tiny T)
            # and full forward (large T) stay numerically comparable.
            capacity_factor=2.5,
        )
        kw["first_k_dense_layers"] = min(cfg.first_k_dense_layers, 1)
        kw["d_ff"] = 512
    if cfg.kv_lora_rank:
        kw.update(kv_lora_rank=64, qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32)
    if cfg.arch_type == "encdec":
        kw.update(n_encoder_layers=2, encoder_seq=64)
    if cfg.global_attn_layers:
        kw["global_attn_layers"] = (0,)
    if cfg.sliding_window:
        kw["sliding_window"] = 32
    if cfg.mrope_sections:
        kw["mrope_sections"] = (8, 12, 12)  # sums to head_dim/2 = 32
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)


# --------------------------------------------------------------------- #
# Unified API                                                            #
# --------------------------------------------------------------------- #
def init_params(key: jax.Array, cfg: ModelConfig, *, dtype=jnp.float32, max_dec_len: int = 4096):
    if cfg.arch_type == "encdec":
        return ed.init_encdec(key, cfg, dtype=dtype, max_dec_len=max_dec_len)
    return tf.init_lm(key, cfg, dtype=dtype)


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, *, dtype=jnp.bfloat16, decode_long: bool = False
):
    if cfg.arch_type == "encdec":
        cap = min(max_len, 8192) if decode_long else max_len
        return ed.init_encdec_cache(cfg, batch, cap, dtype)
    return tf.init_lm_cache(cfg, batch, max_len, dtype=dtype, decode_long=decode_long)


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *, remat: bool = False):
    """Training/eval forward -> (logits, aux)."""
    if cfg.arch_type == "encdec":
        return ed.encdec_forward(params, cfg, batch["frames"], batch["tokens"])
    if cfg.frontend_stub:  # vlm: precomputed patch/frame embeddings
        return tf.lm_forward(
            params, cfg, inputs_embeds=batch["embeds"], remat=remat
        )
    return tf.lm_forward(params, cfg, batch["tokens"], remat=remat)


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array], cache, *, decode_long=False):
    if cfg.arch_type == "encdec":
        return ed.encdec_prefill(params, cfg, batch["frames"], batch["tokens"], cache)
    if cfg.frontend_stub:
        return tf.lm_prefill(
            params, cfg, caches=cache, inputs_embeds=batch["embeds"], decode_long=decode_long
        )
    return tf.lm_prefill(params, cfg, batch["tokens"], cache, decode_long=decode_long)


def decode(params, cfg: ModelConfig, token, cache, cache_len, *, decode_long=False):
    if cfg.arch_type == "encdec":
        window = 8192 if decode_long else 0
        return ed.encdec_decode(params, cfg, token, cache, cache_len, window=window)
    return tf.lm_decode(params, cfg, token, cache, cache_len, decode_long=decode_long)


def cache_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    """Cache capacity for a decode shape (meta tokens included)."""
    return shape.seq_len + cfg.meta_tokens


# --------------------------------------------------------------------- #
# ShapeDtypeStruct inputs for the dry-run                                 #
# --------------------------------------------------------------------- #
def input_specs(
    cfg: ModelConfig, shape: InputShape, *, dtype=jnp.bfloat16
) -> Dict[str, jax.ShapeDtypeStruct]:
    """Stand-ins for every model input of one benchmark shape.

    train: {tokens/embeds/frames, labels}; prefill: model inputs only;
    decode: {token} (cache/params specs are built separately).
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.arch_type == "encdec":
            return {
                "frames": sds((B, cfg.encoder_seq, cfg.d_model), dtype),
                "tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32),
            }
        if cfg.frontend_stub:
            return {
                "embeds": sds((B, S, cfg.d_model), dtype),
                "labels": sds((B, S), jnp.int32),
            }
        return {"tokens": sds((B, S), jnp.int32), "labels": sds((B, S), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.arch_type == "encdec":
            return {
                "frames": sds((B, cfg.encoder_seq, cfg.d_model), dtype),
                "tokens": sds((B, S), jnp.int32),
            }
        if cfg.frontend_stub:
            return {"embeds": sds((B, S, cfg.d_model), dtype)}
        return {"tokens": sds((B, S), jnp.int32)}
    # decode: ONE new token against a cache of seq_len.
    return {"token": sds((B, 1), jnp.int32)}
