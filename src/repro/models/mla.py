"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434 §2.1).

The KV is compressed into a rank-``kv_lora_rank`` latent ``c_kv`` plus a
shared RoPE key ``k_rope``; the cache stores only ``(c_kv, k_rope)`` —
(512 + 64) floats/token for V2-Lite vs 16*2*128 = 4096 for vanilla MHA.

Two decode paths:

* ``absorb=False`` (naive): decompress the whole cache to per-head K/V and
  run standard attention.  Simple, memory-bandwidth heavy.
* ``absorb=True``: fold ``W_UK`` into the query and ``W_UV`` into the output
  projection so attention runs *in the latent space* — the cache is read
  once at latent width.  This is the paper's inference optimization and our
  `long-context` default.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.kernels.flash_attention.ops import flash_attention
from repro.distributed.partitioning import constrain
from .layers import Params, apply_rope, init_linear, init_norm, linear, rms_norm

__all__ = [
    "init_mla",
    "mla_forward",
    "mla_prefill",
    "mla_decode",
    "init_mla_cache",
]


def init_mla(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    r = cfg.kv_lora_rank
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        # V2-Lite: queries are not low-rank (q_lora_rank null).
        "wq": init_linear(ks[0], d, cfg.n_heads * qd, dtype=dtype),
        # Joint KV down-projection: latent + shared rope key.
        "w_dkv": init_linear(ks[1], d, r + cfg.qk_rope_head_dim, dtype=dtype),
        "kv_norm": init_norm(r, dtype),
        "w_uk": init_linear(ks[2], r, cfg.n_heads * cfg.qk_nope_head_dim, dtype=dtype),
        "w_uv": init_linear(ks[3], r, cfg.n_heads * cfg.v_head_dim, dtype=dtype),
        "wo": init_linear(ks[4], cfg.n_heads * cfg.v_head_dim, d, dtype=dtype),
    }


def _compress(params: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """x -> (c_kv normalized, k_rope rotated)."""
    B, S, _ = x.shape
    r = cfg.kv_lora_rank
    dkv = linear(params["w_dkv"], x)
    c_kv = rms_norm(params["kv_norm"], dkv[..., :r], cfg.norm_eps)  # (B,S,r)
    k_rope = dkv[..., r:].reshape(B, S, 1, cfg.qk_rope_head_dim)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def _queries(params: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    B, S, _ = x.shape
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    q = linear(params["wq"], x).reshape(B, S, cfg.n_heads, qd)
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _expand_kv(params: Params, c_kv: jax.Array, cfg: ModelConfig):
    B, S, _ = c_kv.shape
    k_nope = linear(params["w_uk"], c_kv).reshape(B, S, cfg.n_heads, cfg.qk_nope_head_dim)
    v = linear(params["w_uv"], c_kv).reshape(B, S, cfg.n_heads, cfg.v_head_dim)
    return k_nope, v


def mla_forward(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    """Train/prefill forward (decompressed attention)."""
    B, S, _ = x.shape
    q_nope, q_rope = _queries(params, x, cfg, positions)
    c_kv, k_rope = _compress(params, x, cfg, positions)
    k_nope, v = _expand_kv(params, c_kv, cfg)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, cfg.n_heads, cfg.qk_rope_head_dim))],
        axis=-1,
    )
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    out = flash_attention(q, k, v, causal=True, window=window, scale=scale)
    return linear(params["wo"], out.reshape(B, S, -1))


def init_mla_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> Dict[str, jax.Array]:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_prefill(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    cache: Dict[str, jax.Array],
    *,
    window: int = 0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, _ = x.shape
    c_kv, k_rope = _compress(params, x, cfg, positions)
    new_cache = {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0)
        ),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype), (0, 0, 0)
        ),
    }
    y = mla_forward(params, x, cfg, positions, window=window)
    return y, new_cache


def mla_decode(
    params: Params,
    x: jax.Array,  # (B, 1, d)
    cfg: ModelConfig,
    cache: Dict[str, jax.Array],
    cache_len: jax.Array,
    *,
    absorb: bool = True,
    window: int = 0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    r = cfg.kv_lora_rank
    T = cache["c_kv"].shape[1]  # capacity; == window for SWA ring buffers
    positions = jnp.broadcast_to(cache_len[None, None], (B, 1)).astype(jnp.int32)
    q_nope, q_rope = _queries(params, x, cfg, positions)  # (B,1,H,·)
    c_kv, k_rope = _compress(params, x, cfg, positions)
    slot = jax.lax.rem(cache_len, jnp.int32(T))
    zero = jnp.zeros((), jnp.int32)
    new_cache = {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (zero, slot, zero)
        ),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype), (zero, slot, zero)
        ),
    }
    length = jnp.minimum(cache_len + 1, T)
    valid = (jnp.arange(T)[None, :] < length)  # (1, T) -> broadcast (B, T)
    if window > 0 and T > window:
        valid = valid & (jnp.arange(T)[None, :] >= jnp.maximum(length - window, 0))
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    # Latent cache is read at its stored dtype; matmuls accumulate in f32
    # via preferred_element_type (no whole-cache f32 copy — §Perf H2/H3).
    ckv = new_cache["c_kv"]  # (B,T,r)
    krp = new_cache["k_rope"]  # (B,T,rope)
    f32 = jnp.float32

    if absorb:
        # Absorbed: q' = q_nope @ W_UK^T per head -> latent-space logits.
        w_uk = params["w_uk"]["kernel"].reshape(r, cfg.n_heads, cfg.qk_nope_head_dim)
        q_lat = jnp.einsum(
            "bhe,rhe->bhr", q_nope[:, 0], w_uk, preferred_element_type=f32
        )
        # Match the cache's latent sharding so the contraction partial-sums
        # (a small logits all-reduce) instead of all-gathering the cache.
        q_lat = constrain(q_lat, ("batch", None, "kv_latent"))
        logits = jnp.einsum(
            "bhr,btr->bht", q_lat.astype(ckv.dtype), ckv, preferred_element_type=f32
        )
        logits = logits + jnp.einsum(
            "bhe,bte->bht",
            q_rope[:, 0].astype(krp.dtype),
            krp,
            preferred_element_type=f32,
        )
        logits = jnp.where(valid[:, None, :], logits * scale, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum(
            "bht,btr->bhr", probs.astype(ckv.dtype), ckv, preferred_element_type=f32
        )
        w_uv = params["w_uv"]["kernel"].reshape(r, cfg.n_heads, cfg.v_head_dim)
        out = jnp.einsum(
            "bhr,rhv->bhv", o_lat, w_uv.astype(f32), preferred_element_type=f32
        )
    else:
        k_nope, vv = _expand_kv(params, new_cache["c_kv"].astype(x.dtype), cfg)
        k = jnp.concatenate(
            [
                k_nope,
                jnp.broadcast_to(
                    krp[:, :, None, :], (B, T, cfg.n_heads, cfg.qk_rope_head_dim)
                ).astype(x.dtype),
            ],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)[:, 0].astype(jnp.float32)
        logits = jnp.einsum("bhd,bthd->bht", q, k.astype(jnp.float32)) * scale
        logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bht,bthv->bhv", probs, vv.astype(jnp.float32))

    y = linear(params["wo"], out.reshape(B, 1, -1).astype(x.dtype))
    return y, new_cache
