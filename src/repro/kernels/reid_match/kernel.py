"""Pallas TPU kernel for re-identification matching (CR hot loop).

Grid ``(n_gallery_blocks,)``: each step loads a (block_n, D) tile of
candidate embeddings into VMEM, L2-normalizes it, matmuls against the
(Q, D) query tile (kept resident — Q is small: one entity plus QF-fused
variants), and emits per-candidate best score / best query / match flag.

The queries are invariant across gallery tiles, so their L2-normalization
is hoisted out of the grid: ``ops.py`` normalizes once and the kernel
consumes pre-normalized queries (one rsqrt+mul per query total instead of
one per tile).

One MXU pass per tile; the gallery streams through VMEM once, so the
kernel is bandwidth-bound at ~D bytes per candidate — the right regime for
CR, which must score every active camera's detections each frame.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["reid_match_pallas"]


def _kernel(
    g_ref,  # (block_n, D)
    q_ref,  # (Q, D) — pre-normalized by the caller (invariant across tiles)
    score_ref,  # (block_n,)
    best_ref,  # (block_n,)
    match_ref,  # (block_n,)
    *,
    threshold: float,
):
    g = g_ref[...].astype(jnp.float32)
    q = q_ref[...].astype(jnp.float32)
    g = g / jnp.maximum(
        jnp.sqrt(jnp.sum(g * g, axis=1, keepdims=True)), 1e-6
    )
    sim = jax.lax.dot_general(
        g, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (block_n, Q)
    scores = jnp.max(sim, axis=1)
    best = jnp.argmax(sim, axis=1).astype(jnp.int32)
    score_ref[...] = scores
    best_ref[...] = best
    match_ref[...] = scores >= threshold


def reid_match_pallas(
    gallery: jax.Array,  # (N, D)
    queries: jax.Array,  # (Q, D)
    *,
    threshold: float = 0.5,
    block_n: int = 1024,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    N, D = gallery.shape
    Q = queries.shape[0]
    block_n = min(block_n, N)
    pad = (-N) % block_n
    if pad:
        gallery = jnp.pad(gallery, ((0, pad), (0, 0)))
    Np = gallery.shape[0]

    # Hoisted out of the grid: the query tile is identical for every gallery
    # block, so normalize once here instead of once per grid step.
    queries = queries.astype(jnp.float32)
    queries = queries / jnp.maximum(
        jnp.sqrt(jnp.sum(queries * queries, axis=1, keepdims=True)), 1e-6
    )

    kernel = functools.partial(_kernel, threshold=threshold)
    scores, best, is_match = pl.pallas_call(
        kernel,
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((Q, D), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.float32),
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((Np,), jnp.bool_),
        ],
        interpret=interpret,
    )(gallery, queries)
    return scores[:N], best[:N], is_match[:N]
