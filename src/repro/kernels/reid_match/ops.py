"""jit-ready wrapper for the re-id matcher (see flash ops)."""

from __future__ import annotations

import functools
import os
from typing import Tuple

import jax

from .ref import reid_match_ref

__all__ = ["reid_match"]


def _use_pallas() -> bool:
    force = os.environ.get("REPRO_FORCE_PALLAS", "")
    if force == "1":
        return True
    if force == "0":
        return False
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("threshold",))
def reid_match(
    gallery: jax.Array, queries: jax.Array, *, threshold: float = 0.5
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    if _use_pallas():
        from .kernel import reid_match_pallas

        return reid_match_pallas(
            gallery, queries, threshold=threshold,
            interpret=jax.default_backend() != "tpu",
        )
    return reid_match_ref(gallery, queries, threshold=threshold)
