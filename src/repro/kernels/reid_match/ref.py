"""Pure-jnp oracle for the re-identification matcher (the CR hot loop).

Given a gallery of candidate embeddings (detections cropped from frames) and
one or more query embeddings (the entity, possibly fused by QF), compute
L2-normalized cosine similarities and per-candidate best-query scores.  The
Pallas kernel tiles the gallery over VMEM; this is its ground truth.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["reid_match_ref"]


def reid_match_ref(
    gallery: jax.Array,  # (N, D) candidate embeddings
    queries: jax.Array,  # (Q, D) entity query embeddings
    *,
    threshold: float = 0.5,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns ``(scores, best_query, is_match)``:
    scores (N,) best cosine similarity, best_query (N,) argmax query index,
    is_match (N,) bool score >= threshold."""
    g = gallery.astype(jnp.float32)
    q = queries.astype(jnp.float32)
    g = g / jnp.maximum(jnp.linalg.norm(g, axis=-1, keepdims=True), 1e-6)
    q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-6)
    sim = g @ q.T  # (N, Q)
    scores = jnp.max(sim, axis=-1)
    best = jnp.argmax(sim, axis=-1).astype(jnp.int32)
    return scores, best, scores >= threshold
