"""Bucket-batched kernel dispatch (the sweep engine's analytics plane).

``reid_match`` and ``spotlight_ball`` are called with whatever batch size
the simulation happens to produce — a fresh jit specialization per (Q, N)
pair means a sweep of scenarios recompiles the same kernels over and over.
This layer makes kernel launches sweep-friendly:

* **bucketing** — batch dimensions are padded up to power-of-two buckets
  (minimum :data:`BUCKET_MIN`), so an entire sweep compiles each kernel at
  most once per bucket shape.  Padding is masked out: spotlight pad rows
  get radius ``-1`` -> all-``inf`` and the min-plus relaxation is
  row-independent, so spotlight results are **bitwise** equal to the
  unpadded call; re-id pad queries are masked to ``-inf`` similarity, but
  padding the gallery changes the GEMM blocking, so re-id scores agree
  with the unpadded call only up to last-ulp reassociation (still fully
  deterministic for a given shape).
* **device-resident operands** — the dense min-plus adjacency of a road
  network and re-id query blocks are uploaded once and cached by operand
  identity (weakly referenced, so a dropped world frees its buffers).
  Per-call padded scratch operands are donated to the kernel.
* **cache-miss accounting** — :func:`stats` counts calls and distinct
  bucket shapes, and :func:`jit_cache_sizes` exposes the underlying jit
  caches so tests can assert "at most one compile per bucket shape".

Backend selection mirrors the kernel packages: Pallas on TPU (or when
``REPRO_FORCE_PALLAS=1``, interpreted off-TPU), pure-jnp reference
otherwise.
"""

from __future__ import annotations

import functools
import os
import weakref
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.clock import monotonic as _monotonic

__all__ = [
    "BUCKET_MIN",
    "MAX_JIT_SHAPES",
    "bucket",
    "spotlight_ball",
    "reid_match",
    "reid_match_multi",
    "stats",
    "reset_stats",
    "profile",
    "jit_cache_sizes",
    "bound_jit_cache",
]

BUCKET_MIN = 8

# Upper bound on compiled specializations retained per padded kernel.  A
# sweep grid that walks many (bucket, dtype) shapes would otherwise grow
# each kernel's jit cache without bound; jit caches cannot evict single
# entries, so on overflow the kernel's whole cache is dropped and the next
# dispatch recompiles (LRU bookkeeping keeps that rare: only a sweep
# cycling through > MAX_JIT_SHAPES live shapes ever pays it).
MAX_JIT_SHAPES = 32

_STATS = {
    "reid_calls": 0,
    "reid_multi_calls": 0,
    "ball_calls": 0,
    "device_cache_hits": 0,
    "device_cache_misses": 0,
    "bucket_shapes": 0,
}
_SHAPES: set = set()

# Observability profile (repro.obs.collect_dispatch): per-kernel distinct
# bucket-shape compiles and accumulated host wall inside the dispatch entry
# points.  Wall-clock reads go through core.clock.monotonic (DET002-clean)
# and never feed any scheduling decision — attribution only.
_COMPILES: Dict[str, int] = {}
_DISPATCH_WALL: Dict[str, float] = {}


def bucket(n: int, minimum: int = BUCKET_MIN) -> int:
    """Smallest power-of-two >= ``n`` (and >= ``minimum``)."""
    if n < 1:
        raise ValueError(f"bucket size needs n >= 1, got {n}")
    return max(1 << (int(n) - 1).bit_length(), minimum)


def stats() -> Dict[str, int]:
    return dict(_STATS)


def reset_stats() -> None:
    for k in _STATS:
        _STATS[k] = 0
    _SHAPES.clear()
    _COMPILES.clear()
    _DISPATCH_WALL.clear()


def profile() -> Dict[str, Dict[str, float]]:
    """Kernel-plane profile: per-kernel distinct bucket-shape compile
    counts (each new shape is one XLA compile of that kernel) and the
    accumulated host wall spent inside the dispatch entry points."""
    return {
        "compiles": dict(_COMPILES),
        "dispatch_wall_s": dict(_DISPATCH_WALL),
    }


def _note_shape(key: Tuple) -> None:
    if key not in _SHAPES:
        _SHAPES.add(key)
        _STATS["bucket_shapes"] += 1
        name = str(key[0])
        _COMPILES[name] = _COMPILES.get(name, 0) + 1


def _note_wall(name: str, t0: float) -> None:
    _DISPATCH_WALL[name] = _DISPATCH_WALL.get(name, 0.0) + (_monotonic() - t0)


# Per-kernel LRU of live bucket shapes, bounding the jit caches.
_JIT_LRU: Dict[str, "OrderedDict[Tuple, None]"] = {}


def bound_jit_cache(name: str, fn, key: Tuple, cap: Optional[int] = None) -> None:
    """Record that ``fn`` (a jitted kernel) is about to be dispatched with
    bucket-shape ``key``; when more than ``cap`` distinct shapes are live,
    drop the kernel's compile cache so it is rebuilt for the working set.

    Shared by every padded kernel here and by the mega-step engine's
    per-(bucket, K) compile cache, so "jit caches stay bounded" is one
    invariant with one implementation.
    """
    if cap is None:
        cap = MAX_JIT_SHAPES  # read at call time so tests can shrink it
    lru = _JIT_LRU.setdefault(name, OrderedDict())
    if key in lru:
        lru.move_to_end(key)
        return
    lru[key] = None
    if len(lru) > cap:
        try:
            fn.clear_cache()
        except AttributeError:
            pass
        lru.clear()
        lru[key] = None


def _use_pallas() -> bool:
    import jax

    force = os.environ.get("REPRO_FORCE_PALLAS", "")
    if force == "1":
        return True
    if force == "0":
        return False
    return jax.default_backend() == "tpu"


# --------------------------------------------------------------------- #
# Device-resident operand cache (weak, keyed by host-array identity)      #
# --------------------------------------------------------------------- #
# id(array) -> (weakref to the host array, device buffer).  The weakref
# callback evicts the entry when the host array dies, which also guards
# against id() reuse.
_DEVICE_CACHE: Dict[int, Tuple[weakref.ref, object]] = {}


def _device_resident(arr: np.ndarray, transform=None):
    """``jax.device_put(transform(arr))`` memoized on the identity of
    ``arr`` (``transform``, e.g. bucket padding, runs only on a miss)."""
    import jax

    key = id(arr)
    entry = _DEVICE_CACHE.get(key)
    if entry is not None and entry[0]() is arr:
        _STATS["device_cache_hits"] += 1
        return entry[1]
    _STATS["device_cache_misses"] += 1
    dev = jax.device_put(transform(arr) if transform is not None else arr)

    def _evict(_ref, key=key):
        _DEVICE_CACHE.pop(key, None)

    _DEVICE_CACHE[key] = (weakref.ref(arr, _evict), dev)
    return dev


# One dense adjacency per (graph identity, dtype): id(weights) is stable
# because RoadNetwork.csr() caches its arrays.
_DENSE_CACHE: Dict[Tuple[int, str], Tuple[weakref.ref, object]] = {}


def _dense_w(indptr: np.ndarray, indices: np.ndarray, weights: np.ndarray, dtype):
    from .spotlight_ball.ref import dense_adjacency

    key = (id(weights), np.dtype(dtype).str)
    entry = _DENSE_CACHE.get(key)
    if entry is not None and entry[0]() is weights:
        _STATS["device_cache_hits"] += 1
        return entry[1]
    # (the _device_resident call below accounts for the cache miss)
    W_host = dense_adjacency(
        np.asarray(indptr), np.asarray(indices), np.asarray(weights, dtype=dtype)
    )
    dev = _device_resident(W_host)

    def _evict(_ref, key=key):
        _DENSE_CACHE.pop(key, None)

    _DENSE_CACHE[key] = (weakref.ref(weights, _evict), dev)
    return dev


# --------------------------------------------------------------------- #
# Batched spotlight balls                                                #
# --------------------------------------------------------------------- #
def _make_ball_padded():
    import jax
    import jax.numpy as jnp

    from .spotlight_ball.ref import relax_step_ref

    # Donating the per-call scratch operands lets the backend alias their
    # buffers; CPU does not implement donation and would warn on every
    # compile, so only donate where it is real.
    donate = (1, 2) if jax.default_backend() == "tpu" else ()

    @functools.partial(
        jax.jit,
        static_argnames=("use_pallas", "interpret"),
        donate_argnums=donate,
    )
    def ball_padded(W, sources, radii, *, use_pallas: bool, interpret: bool):
        V = W.shape[0]
        Q = sources.shape[0]
        inf = jnp.array(jnp.inf, dtype=W.dtype)
        D0 = jnp.full((Q, V), inf, dtype=W.dtype)
        D0 = D0.at[jnp.arange(Q), sources].set(jnp.zeros((), dtype=W.dtype))

        if use_pallas:
            from .spotlight_ball.kernel import relax_step_pallas

            step = lambda D: relax_step_pallas(D, W, interpret=interpret)
        else:
            step = lambda D: relax_step_ref(D, W)

        def cond(state):
            D, changed, it = state
            return jnp.logical_and(changed, it < V)

        def body(state):
            D, _, it = state
            Dn = step(D)
            return Dn, jnp.any(Dn < D), it + 1

        D, _, _ = jax.lax.while_loop(cond, body, (D0, jnp.bool_(True), jnp.int32(0)))
        return jnp.where(D <= radii[:, None], D, inf)

    return ball_padded


_BALL_PADDED = None


def spotlight_ball(indptr, indices, weights, sources, radii, *, dtype=np.float32):
    """Bucket-padded batched Dijkstra balls over a CSR graph.

    Same contract as ``repro.kernels.spotlight_ball.ops.spotlight_ball``
    (returns (Q, V) distances, ``inf`` outside each radius) but the dense
    adjacency is device-resident per graph, and Q is padded to a
    power-of-two bucket (pad queries get radius ``-1`` and therefore
    all-``inf`` rows, which are sliced off).  Rows are independent under
    min-plus relaxation, so real rows are bitwise identical to an
    unpadded call.
    """
    global _BALL_PADDED
    import jax
    import jax.numpy as jnp

    _STATS["ball_calls"] += 1
    sources = np.asarray(sources, dtype=np.int32)
    Q = sources.shape[0]
    qb = bucket(Q)
    src_pad = np.zeros(qb, dtype=np.int32)
    src_pad[:Q] = sources
    rad_pad = np.full(qb, -1.0, dtype=dtype)
    rad_pad[:Q] = np.asarray(radii, dtype=dtype)

    W = _dense_w(indptr, indices, weights, dtype)
    use_pallas = _use_pallas()
    interpret = jax.default_backend() != "tpu"
    if _BALL_PADDED is None:
        _BALL_PADDED = _make_ball_padded()
    key = ("ball", int(W.shape[0]), qb, np.dtype(dtype).str, use_pallas)
    _note_shape(key)
    bound_jit_cache("ball", _BALL_PADDED, key)
    t0 = _monotonic()
    out = _BALL_PADDED(
        W,
        jnp.asarray(src_pad),
        jnp.asarray(rad_pad),
        use_pallas=use_pallas,
        interpret=interpret,
    )
    _note_wall("ball", t0)
    return out[:Q]


# --------------------------------------------------------------------- #
# Batched re-id matching                                                 #
# --------------------------------------------------------------------- #
def _make_reid_padded():
    import jax
    import jax.numpy as jnp

    donate = (0,) if jax.default_backend() == "tpu" else ()

    # threshold is traced (not static): sweeps vary it per config, and a
    # static arg would recompile per distinct value — violating the
    # one-compile-per-bucket-shape contract without showing up in stats.
    @functools.partial(jax.jit, donate_argnums=donate)
    def reid_padded(gallery, queries, nq, threshold):
        # Same arithmetic as reid_match_ref, with pad queries masked to
        # -inf similarity so they can never win the per-candidate max.
        g = gallery.astype(jnp.float32)
        q = queries.astype(jnp.float32)
        g = g / jnp.maximum(jnp.linalg.norm(g, axis=-1, keepdims=True), 1e-6)
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-6)
        sim = g @ q.T  # (N, Qb)
        valid = jnp.arange(q.shape[0])[None, :] < nq
        sim = jnp.where(valid, sim, -jnp.inf)
        scores = jnp.max(sim, axis=-1)
        best = jnp.argmax(sim, axis=-1).astype(jnp.int32)
        return scores, best, scores >= threshold

    return reid_padded


_REID_PADDED = None


def reid_match(gallery, queries, *, threshold: float = 0.5):
    """Bucket-padded re-id matcher: ``(scores, best_query, is_match)`` for
    the first ``N`` gallery rows, matching the unpadded
    ``repro.kernels.reid_match`` call up to last-ulp GEMM reassociation
    (padding changes the matmul blocking; results are deterministic per
    shape).

    The gallery (per-call candidate embeddings) is padded to a
    power-of-two row bucket and donated; the query block (often a
    long-lived entity embedding) is padded once and kept device-resident
    keyed on its identity.
    """
    global _REID_PADDED
    import jax.numpy as jnp

    _STATS["reid_calls"] += 1
    gallery = np.asarray(gallery, dtype=np.float32)
    if gallery.ndim != 2:
        raise ValueError(f"gallery must be (N, D), got {gallery.shape}")
    N, D = gallery.shape
    nb = bucket(N)
    g_pad = np.zeros((nb, D), dtype=np.float32)
    g_pad[:N] = gallery

    queries_np = np.asarray(queries, dtype=np.float32)
    if queries_np.ndim != 2 or queries_np.shape[1] != D:
        raise ValueError(f"queries must be (Q, {D}), got {queries_np.shape}")
    Q = queries_np.shape[0]
    qb = bucket(Q)

    def _pad_queries(_q):
        q_pad = np.zeros((qb, D), dtype=np.float32)
        q_pad[:Q] = queries_np
        return q_pad

    if isinstance(queries, np.ndarray):
        # Long-lived query blocks (the tracked entity's embedding) stay
        # device-resident, padded once, keyed on the host array identity.
        q_dev = _device_resident(queries, transform=_pad_queries)
    else:
        q_dev = jnp.asarray(_pad_queries(queries_np))

    if _REID_PADDED is None:
        _REID_PADDED = _make_reid_padded()
    key = ("reid", nb, qb, D)
    _note_shape(key)
    bound_jit_cache("reid", _REID_PADDED, key)
    t0 = _monotonic()
    scores, best, matched = _REID_PADDED(
        jnp.asarray(g_pad), q_dev, jnp.int32(Q), jnp.float32(threshold)
    )
    _note_wall("reid", t0)
    return scores[:N], best[:N], matched[:N]


# --------------------------------------------------------------------- #
# Query-major batched re-id (multi-query tenancy plane)                   #
# --------------------------------------------------------------------- #
def _make_reid_multi_padded():
    import jax
    import jax.numpy as jnp

    donate = (0, 2) if jax.default_backend() == "tpu" else ()

    @functools.partial(jax.jit, donate_argnums=donate)
    def reid_multi_padded(gallery, queries, mask, threshold):
        # Per-(candidate, query) cosine similarity with a broadcast
        # multiply-then-reduce over the feature axis: every sim[n, q] is an
        # independent D-length reduction whose arithmetic does not depend on
        # how many other rows/queries share the bucket — which is what makes
        # the fused call bit-exact against per-query serial dispatches.
        g = gallery.astype(jnp.float32)
        q = queries.astype(jnp.float32)
        g = g / jnp.maximum(jnp.linalg.norm(g, axis=-1, keepdims=True), 1e-6)
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-6)
        sim = jnp.sum(g[:, None, :] * q[None, :, :], axis=-1)  # (Nb, Qb)
        sim = jnp.where(mask, sim, -jnp.inf)
        return sim, jnp.logical_and(mask, sim >= threshold)

    return reid_multi_padded


_REID_MULTI_PADDED = None


def reid_match_multi(gallery, queries, *, mask=None, threshold: float = 0.5):
    """Query-major batched re-id: ``(scores, matched)`` of shape ``(N, Q)``
    for an ``(N, D)`` gallery against ``(Q, D)`` query embeddings.

    ``mask`` (optional ``(N, Q)`` bool) is the tenancy filter: pair
    ``(n, q)`` is only evaluated when ``mask[n, q]`` — masked-out pairs get
    ``-inf`` score and ``matched=False``.  Both axes are padded to
    power-of-two buckets (pad pairs masked out), so a whole multi-query
    sweep compiles this kernel at most once per bucket shape.

    Bit-exactness contract: each ``sim[n, q]`` is an independent
    normalize-then-reduce over ``D``, so real entries are **bitwise** equal
    to a per-query serial call (``Q=1``) with the same gallery rows — unlike
    :func:`reid_match`, no GEMM re-blocking is involved.  The fused
    multi-query VA stage relies on this to stay bit-identical to N
    independent single-query runs.
    """
    global _REID_MULTI_PADDED
    import jax.numpy as jnp

    _STATS["reid_multi_calls"] += 1
    gallery = np.asarray(gallery, dtype=np.float32)
    if gallery.ndim != 2:
        raise ValueError(f"gallery must be (N, D), got {gallery.shape}")
    N, D = gallery.shape
    queries_np = np.asarray(queries, dtype=np.float32)
    if queries_np.ndim != 2 or queries_np.shape[1] != D:
        raise ValueError(f"queries must be (Q, {D}), got {queries_np.shape}")
    Q = queries_np.shape[0]
    if mask is None:
        mask_np = np.ones((N, Q), dtype=bool)
    else:
        mask_np = np.asarray(mask, dtype=bool)
        if mask_np.shape != (N, Q):
            raise ValueError(f"mask must be ({N}, {Q}), got {mask_np.shape}")
    nb, qb = bucket(N), bucket(Q)
    g_pad = np.zeros((nb, D), dtype=np.float32)
    g_pad[:N] = gallery
    m_pad = np.zeros((nb, qb), dtype=bool)
    m_pad[:N, :Q] = mask_np

    def _pad_queries(_q):
        q_pad = np.zeros((qb, D), dtype=np.float32)
        q_pad[:Q] = queries_np
        return q_pad

    if isinstance(queries, np.ndarray):
        # The live-query block is long-lived (the query registry caches one
        # array per live set): pad once, keep device-resident by identity —
        # same contract as the single-query reid_match query block.
        q_dev = _device_resident(queries, transform=_pad_queries)
    else:
        q_dev = jnp.asarray(_pad_queries(queries_np))

    if _REID_MULTI_PADDED is None:
        _REID_MULTI_PADDED = _make_reid_multi_padded()
    key = ("reid_multi", nb, qb, D)
    _note_shape(key)
    bound_jit_cache("reid_multi", _REID_MULTI_PADDED, key)
    t0 = _monotonic()
    scores, matched = _REID_MULTI_PADDED(
        jnp.asarray(g_pad), q_dev, jnp.asarray(m_pad),
        jnp.float32(threshold),
    )
    _note_wall("reid_multi", t0)
    return scores[:N, :Q], matched[:N, :Q]


def jit_cache_sizes() -> Dict[str, int]:
    """Number of distinct compilations held by each padded kernel (0 when
    the kernel has not been dispatched yet)."""
    try:  # the mega-step scan shares the bounded-jit-cache contract
        from .megastep import ops as _mega_ops

        mega_fn = _mega_ops._CHUNK_FN
    except ImportError:  # jax/megastep stack absent: report cache size 0
        mega_fn = None
    sizes = {}
    for name, fn in (
        ("ball", _BALL_PADDED),
        ("reid", _REID_PADDED),
        ("reid_multi", _REID_MULTI_PADDED),
        ("megastep", mega_fn),
    ):
        if fn is None:
            sizes[name] = 0
            continue
        try:
            sizes[name] = fn._cache_size()
        except AttributeError:  # older jax: fall back to tracked shapes
            sizes[name] = sum(1 for s in _SHAPES if s[0] == name)
    return sizes
