"""jit-ready wrapper for the Mamba2 SSD chunked scan (see flash ops)."""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax

from .ref import ssd_decode_step_ref, ssd_ref

__all__ = ["ssd_scan", "ssd_decode_step"]


def _use_pallas() -> bool:
    force = os.environ.get("REPRO_FORCE_PALLAS", "")
    if force == "1":
        return True
    if force == "0":
        return False
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    *,
    chunk: int = 256,
    initial_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    if _use_pallas():
        from .kernel import ssd_scan_pallas

        return ssd_scan_pallas(
            x, dt, A, Bm, Cm, chunk=chunk, initial_state=initial_state,
            interpret=jax.default_backend() != "tpu",
        )
    return ssd_ref(x, dt, A, Bm, Cm, chunk=chunk, initial_state=initial_state)


ssd_decode_step = jax.jit(ssd_decode_step_ref)
