"""Pure-jnp oracle for the Mamba2 SSD chunked scan (arXiv:2405.21060, §6).

State-space duality: within a chunk the recurrence is computed as masked
attention (quadratic in the chunk length); across chunks a linear recurrence
carries the (H, P, N) state.  This is the `ssd_minimal_discrete` reference
algorithm, adapted to grouped B/C (ngroups) and an optional initial state so
decode-vs-scan equivalence is testable.

Shapes
------
x  : (B, L, H, P)   — per-head inputs (already multiplied by nothing; the
                      discretization ``x * dt`` happens inside)
dt : (B, L, H)      — softplus-activated step sizes
A  : (H,)           — negative decay rates (A = -exp(A_log))
Bm : (B, L, G, N)   — input projections (groups broadcast to heads)
Cm : (B, L, G, N)   — output projections
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import constrain, current_rules

__all__ = ["ssd_ref", "ssd_decode_step_ref"]


def _segsum(x: jax.Array) -> jax.Array:
    """Stable 'segment sum': out[..., i, j] = sum_{k=j+1..i} x[..., k]
    for j < i (and 0 on the diagonal, -inf above)."""
    q = x.shape[-1]
    csum = jnp.cumsum(x, axis=-1)
    diff = csum[..., :, None] - csum[..., None, :]  # sum_{j+1..i}
    idx = jnp.arange(q)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_ref(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    *,
    chunk: int = 256,
    initial_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns ``(y, final_state)`` with y: (B, L, H, P) and
    final_state: (B, H, P, N)."""
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert h % g == 0
    if l % chunk != 0:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L = x.shape[1]
    nc, q = L // chunk, chunk
    hpg = h // g

    f32 = jnp.float32
    x_ = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(b, nc, q, h, p)
    a_dt = (A.astype(f32) * dt.astype(f32)).reshape(b, nc, q, h)  # (b,c,q,h)
    # Broadcast grouped B/C to heads.
    Bh = jnp.repeat(Bm.astype(f32), hpg, axis=2).reshape(b, nc, q, h, n)
    Ch = jnp.repeat(Cm.astype(f32), hpg, axis=2).reshape(b, nc, q, h, n)

    # 1) Intra-chunk (quadratic, "attention-like") term.  The (b,c,h,q,s)
    # intermediates are the SSD working set; for head counts that do not
    # divide the model axis they would replicate per chip, so we shard the
    # q rows instead when the launcher enables "q_seq" (§Perf H1).  The
    # Pallas kernel holds these tiles in VMEM and never spills them.
    a_dt_t = jnp.moveaxis(a_dt, -1, -2)  # (b,c,h,q)
    decay_mat = jnp.exp(_segsum(a_dt_t))  # (b,c,h,q,s) lower-tri
    scores = jnp.einsum("bcqhn,bcshn->bchqs", Ch, Bh)
    rules = current_rules()
    if rules is not None and rules.rules.get("q_seq"):
        # Only when the launcher activates row-parallel blocks: forcing a
        # constraint otherwise fights XLA's own (better) choice.
        decay_mat = constrain(decay_mat, ("batch", None, None, "q_seq", None))
        scores = constrain(scores, ("batch", None, None, "q_seq", None))
    y_diag = jnp.einsum("bchqs,bchqs,bcshp->bcqhp", scores, decay_mat, x_)

    # 2) Per-chunk final states.
    a_cum = jnp.cumsum(a_dt, axis=2)  # (b,c,q,h)
    total = a_cum[:, :, -1:, :]  # (b,c,1,h)
    decay_to_end = jnp.exp(total - a_cum)  # (b,c,q,h)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bh, decay_to_end, x_)

    # 3) Inter-chunk linear recurrence over chunk states.
    chunk_decay = jnp.exp(total[:, :, 0, :])  # (b,c,h)
    s0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), f32)
    )

    def step(carry, inp):
        dec, st = inp  # (b,h), (b,h,p,n)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* this chunk

    final, prev_states = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,c,h,p,n)

    # 4) Inter-chunk contribution to outputs.
    state_decay = jnp.exp(a_cum)  # decay from chunk start to each position
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, L, h, p)[:, :l]
    return y.astype(x.dtype), final


def ssd_decode_step_ref(
    state: jax.Array,  # (B, H, P, N)
    x_t: jax.Array,  # (B, H, P)
    dt_t: jax.Array,  # (B, H)
    A: jax.Array,  # (H,)
    B_t: jax.Array,  # (B, G, N)
    C_t: jax.Array,  # (B, G, N)
) -> Tuple[jax.Array, jax.Array]:
    """One recurrent step; returns ``(y_t, new_state)``."""
    b, h, p, n = state.shape
    g = B_t.shape[1]
    hpg = h // g
    f32 = jnp.float32
    dA = jnp.exp(A.astype(f32) * dt_t.astype(f32))  # (B, H)
    Bh = jnp.repeat(B_t.astype(f32), hpg, axis=1)  # (B, H, N)
    Ch = jnp.repeat(C_t.astype(f32), hpg, axis=1)
    xbar = x_t.astype(f32) * dt_t.astype(f32)[..., None]  # (B, H, P)
    new_state = state.astype(f32) * dA[..., None, None] + xbar[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x_t.dtype), new_state
