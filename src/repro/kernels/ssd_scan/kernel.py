"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid ``(B, n_chunks)`` with the chunk dimension innermost/sequential: the
running (H, P, N) recurrent state lives in a VMEM scratch that is
initialized at chunk 0 and carried across chunks of the same sequence —
exactly the TPU-native shape of the state-space *duality*: within a chunk
the quadratic masked-attention form feeds the MXU; across chunks the
linear recurrence is a cheap VMEM update.

Per-chunk VMEM working set (defaults: Q=128, H=64, P=64, N=128):
  x tile (Q, H*P) bf16 = 1 MiB, B/C tiles (Q, G*N), decay matrices (H, Q, Q)
  f32, state scratch (H, P, N) f32 = 2 MiB — comfortably under ~16 MiB.

Validated against ``ref.ssd_ref`` (incl. carried initial state) in
interpret mode by ``tests/test_kernels_ssd.py``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_pallas"]


def _kernel(
    x_ref,  # (1, Q, H, P)
    dt_ref,  # (1, Q, H)
    a_ref,  # (1, H)
    b_ref,  # (1, Q, G, N)
    c_ref,  # (1, Q, G, N)
    y_ref,  # (1, Q, H, P) out
    fs_ref,  # (1, H, P, N) out (final state)
    state_scr,  # (H, P, N) f32
    *,
    chunk: int,
    n_chunks: int,
    hpg: int,
    has_init: bool,
    init_ref=None,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        if has_init:
            state_scr[...] = init_ref[0].astype(jnp.float32)
        else:
            state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)  # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)  # (Q, H)
    A = a_ref[0].astype(jnp.float32)  # (H,)
    Bm = b_ref[0].astype(jnp.float32)  # (Q, G, N)
    Cm = c_ref[0].astype(jnp.float32)

    xbar = x * dt[..., None]  # (Q, H, P)
    a_dt = dt * A[None, :]  # (Q, H)
    a_cum = jnp.cumsum(a_dt, axis=0)  # (Q, H)

    # Broadcast groups to heads.
    Bh = jnp.repeat(Bm, hpg, axis=1)  # (Q, H, N)
    Ch = jnp.repeat(Cm, hpg, axis=1)

    # Intra-chunk quadratic term: decay(i<-j) = exp(a_cum_i - a_cum_j), i>=j.
    diff = a_cum[:, None, :] - a_cum[None, :, :]  # (Q, Q, H)
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril = row >= col
    decay = jnp.where(tril[..., None], jnp.exp(diff), 0.0)  # (Q, Q, H)
    scores = jnp.einsum("ihn,jhn->ijh", Ch, Bh)  # (Q, Q, H)
    y_diag = jnp.einsum("ijh,jhp->ihp", scores * decay, xbar)

    # Inter-chunk: contribution of the carried state.
    state = state_scr[...]  # (H, P, N)
    state_decay = jnp.exp(a_cum)  # (Q, H)
    y_off = jnp.einsum("ihn,hpn->ihp", Ch, state) * state_decay[..., None]
    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    # State update: S' = exp(sum a_dt) * S + sum_j decay_to_end_j * x_j B_j^T
    total = a_cum[-1]  # (H,)
    decay_to_end = jnp.exp(total[None, :] - a_cum)  # (Q, H)
    new_state = state * jnp.exp(total)[:, None, None] + jnp.einsum(
        "jhp,jhn,jh->hpn", xbar, Bh, decay_to_end
    )
    state_scr[...] = new_state

    @pl.when(ci == n_chunks - 1)
    def _final():
        fs_ref[0] = new_state.astype(fs_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, L, G, N)
    Cm: jax.Array,  # (B, L, G, N)
    *,
    chunk: int = 128,
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N)
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    hpg = h // g
    orig_l = l
    if l % chunk:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = x.shape[1]
    nc = l // chunk
    has_init = initial_state is not None

    in_specs = [
        pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
        pl.BlockSpec((1, chunk, h), lambda bi, ci: (bi, ci, 0)),
        pl.BlockSpec((1, h), lambda bi, ci: (0, 0)),
        pl.BlockSpec((1, chunk, g, n), lambda bi, ci: (bi, ci, 0, 0)),
        pl.BlockSpec((1, chunk, g, n), lambda bi, ci: (bi, ci, 0, 0)),
    ]
    args = [x, dt, A[None], Bm, Cm]
    if has_init:
        in_specs.append(pl.BlockSpec((1, h, p, n), lambda bi, ci: (bi, 0, 0, 0)))
        args.append(initial_state)

    def kernel(*refs):
        if has_init:
            x_r, dt_r, a_r, b_r, c_r, init_r, y_r, fs_r, scr = refs
        else:
            x_r, dt_r, a_r, b_r, c_r, y_r, fs_r, scr = refs
            init_r = None
        _kernel(
            x_r, dt_r, a_r, b_r, c_r, y_r, fs_r, scr,
            chunk=chunk, n_chunks=nc, hpg=hpg, has_init=has_init, init_ref=init_r,
        )

    y, fs = pl.pallas_call(
        kernel,
        grid=(b, nc),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda bi, ci: (bi, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(*args)
    return y[:, :orig_l], fs
