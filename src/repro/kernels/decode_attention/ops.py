"""jit-ready wrapper for single-token decode attention (see flash ops)."""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax

from .ref import decode_attention_ref

__all__ = ["decode_attention"]


def _use_pallas() -> bool:
    force = os.environ.get("REPRO_FORCE_PALLAS", "")
    if force == "1":
        return True
    if force == "0":
        return False
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "scale"))
def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    length: jax.Array,
    *,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """One-token attention: q (B, Hq, D) vs head-major cache (B, Hkv, T, D)."""
    if _use_pallas():
        from .kernel import decode_attention_pallas

        return decode_attention_pallas(
            q, k_cache, v_cache, length, window=window, scale=scale,
            interpret=jax.default_backend() != "tpu",
        )
    return decode_attention_ref(q, k_cache, v_cache, length, window=window, scale=scale)
