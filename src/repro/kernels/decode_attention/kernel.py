"""Pallas TPU decode attention: one query token vs a long head-major cache.

Grid ``(B, nk)``: for each sequence, stream the (B, Hkv, T, D) cache in
``block_k``-token tiles (sequential) while all query heads ride along in a
single VMEM tile — decode is memory-bound on the cache read, and head-major
storage means each tile is contiguous per head (zero transpose copies,
§Perf H3):

* q tile   (Hq, D)           VMEM (one token, all heads)
* k/v tile (Hkv, block_k, D) VMEM
* acc      (Hq, D) f32 scratch; m/l (Hq, 1) f32 scratch

``lengths`` (B,) arrives via scalar prefetch and bounds the valid slots;
tiles wholly past the length (or outside the sliding window) are skipped.
Validated against ``ref.decode_attention_ref`` in interpret mode.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_pallas"]

_NEG_INF = -1e30


def _kernel(
    len_ref,  # scalar prefetch: (B,) int32
    q_ref,  # (1, Hq, D)
    k_ref,  # (1, Hkv, block_k, D)
    v_ref,  # (1, Hkv, block_k, D)
    o_ref,  # (1, Hq, D)
    m_scr,  # (Hq, 1) f32
    l_scr,  # (Hq, 1) f32
    acc_scr,  # (Hq, D) f32
    *,
    window: int,
    scale: float,
    block_k: int,
    groups: int,
    num_k_blocks: int,
):
    b = pl.program_id(0)
    kj = pl.program_id(1)
    length = len_ref[b]

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    first_k = kj * block_k
    low = jnp.maximum(length - window, 0) if window > 0 else 0
    relevant = jnp.logical_and(first_k < length, first_k + block_k > low)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (Hq, D)
        k = k_ref[0].astype(jnp.float32)  # (Hkv, block_k, D) head-major
        v = v_ref[0].astype(jnp.float32)
        Hq, D = q.shape
        Hkv = k.shape[0]
        pos = first_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        valid = pos < length
        if window > 0:
            valid = jnp.logical_and(valid, pos >= low)
        # (Hq, block_k): per-head dot with the grouped KV head — head-major
        # tiles feed the MXU directly, no swaps.
        qh = q.reshape(Hkv, groups, D)
        s = jax.lax.dot_general(
            qh, k, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
        ) * scale  # (Hkv, groups, block_k)
        s = s.reshape(Hq, block_k)
        s = jnp.where(valid, s, _NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        ph = p.reshape(Hkv, groups, block_k)
        o = jax.lax.dot_general(
            ph, v, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
        )  # (Hkv, groups, D)
        acc_scr[...] = acc_scr[...] * corr + o.reshape(Hq, D)
        m_scr[...] = m_new

    @pl.when(kj == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,  # (B, Hq, D)
    k_cache: jax.Array,  # (B, Hkv, T, D) head-major
    v_cache: jax.Array,
    length: jax.Array,  # (B,) int32
    *,
    window: int = 0,
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, D = q.shape
    _, Hkv, T, _ = k_cache.shape
    groups = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_k = min(block_k, T)
    pad_k = (-T) % block_k
    if pad_k:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Tp = k_cache.shape[2]
    nk = Tp // block_k

    kernel = functools.partial(
        _kernel,
        window=window,
        scale=scale,
        block_k=block_k,
        groups=groups,
        num_k_blocks=nk,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, j, *_: (b, 0, 0)),
            pl.BlockSpec((1, Hkv, block_k, D), lambda b, j, *_: (b, 0, j, 0)),
            pl.BlockSpec((1, Hkv, block_k, D), lambda b, j, *_: (b, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, j, *_: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        interpret=interpret,
    )(length.astype(jnp.int32), q, k_cache, v_cache)
    return out
