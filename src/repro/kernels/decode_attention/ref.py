"""Pure-jnp oracle for single-token decode attention against a KV cache.

The query is one new token per sequence; the cache holds ``T`` slots of which
``length`` are valid.  Sliding-window decode restricts attention to the last
``window`` valid positions.  Ground truth for the Pallas decode kernel.

Decode is cache-bandwidth-bound, so this reference is written to read the
cache EXACTLY ONCE at its stored dtype: GQA is expressed by grouping the
query heads (``(B, Hkv, g, D)``) instead of ``jnp.repeat``-ing the cache
(8x materialization for 64/8 GQA!), and matmuls accumulate in f32 via
``preferred_element_type`` instead of casting the cache to f32 (2x bytes +
an extra HBM round trip).  §Perf H3 measured this at ~8x HBM traffic and a
160 GiB/step all-gather before the rewrite.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["decode_attention_ref"]


def decode_attention_ref(
    q: jax.Array,  # (B, Hq, D) — one token per sequence
    k_cache: jax.Array,  # (B, Hkv, T, D) — head-major (§Perf H3)
    v_cache: jax.Array,  # (B, Hkv, T, D)
    length: jax.Array,  # (B,) int32 — number of valid cache slots
    *,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Hq, D = q.shape
    _, Hkv, T, _ = k_cache.shape
    groups = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    # Grouped queries: (B, Hkv, g, D) — the cache is never expanded, and the
    # head-major layout makes (B, Hkv) the dot's leading batch dims: the
    # cache streams through with no transpose copies.
    qg = q.reshape(B, Hkv, groups, D).astype(k_cache.dtype)
    logits = jnp.einsum(
        "bkgd,bktd->bkgt", qg, k_cache, preferred_element_type=jnp.float32
    )
    logits = logits * scale

    pos = jnp.arange(T)[None, :]  # (1, T)
    valid = pos < length[:, None]
    if window > 0:
        valid = valid & (pos >= jnp.maximum(length[:, None] - window, 0))
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)

    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.any(valid, axis=-1)[:, None, None, None], probs, 0.0)
    out = jnp.einsum(
        "bkgt,bktd->bkgd",
        probs.astype(v_cache.dtype),
        v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, Hq, D).astype(q.dtype)
