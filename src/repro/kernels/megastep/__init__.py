"""Fused mega-step tick engine (kernel/ops/ref triple).

The mega-step collapses the interpreted per-tick hot loop — source frames,
VA pass-through, CR verdicts, sink latency rows and the TL spotlight — into
one engine invocation per run instead of one scheduler event per pipeline
hop.  Three implementations share the exact event semantics of the
discrete-event pipeline (`repro.core.pipeline`) for drops-off streaming
configs:

* :mod:`.ref` — numpy reference: a per-lane busy-chain state machine in
  python floats plus a table-driven TL update.  The bit-exactness oracle
  for the device paths and the host backend for TL strategies that cannot
  be lowered to table lookups (probabilistic coverage, kernel spotlight
  mode).
* :mod:`.ops` — jax ``lax.scan`` over ticks with an inner scan over padded
  lane slots; runs in x64 and returns the same rows bit-for-bit.
* :mod:`.kernel` — the Pallas per-lane chain step (grid over lanes), used
  by :mod:`.ops` when enabled and validated in interpret mode against the
  jnp reference step.

Drivers never call these directly; `repro.core.megastep` owns eligibility,
the host-precomputed plan (tick chains, visibility table, spotlight
distance/hop planes, radius tables, the shared CR uniform stream) and the
result assembly.
"""

from . import ref  # noqa: F401

__all__ = ["ref"]
