"""Pallas kernel for the mega-step's per-lane busy-chain slot sweep.

One program per VA/CR lane: each program replays its lane's padded slot
list for one tick — the VA chain step at the shared fused-FC arrival time,
the CR chain step at ``va_end + d_vc``, the per-lane uniform draw for the
verdict — exactly the float sequence of ``ref._LaneChain.step``.  The math
is pure f64 adds/compares (no multiplies, so no FMA contraction hazard),
which is what makes the kernel bit-identical to the numpy chain.

The sweep is inherently sequential per lane (slot ``s+1``'s start depends
on slot ``s``'s end), so the kernel is a ``fori_loop`` over slots with the
chain state in scalars; lanes are the grid.  Validated in interpret mode
against the jnp inner-scan in ``ops`` (see ``tests/test_megastep_props``);
on hardware without native f64 the engine keeps the jnp path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lane_chain_tick_pallas"]

# params layout: [t_arr, xi_va, xi_cr, d_vc, d_cu, p_tp]
N_PARAMS = 6


def _kernel(
    real_ref, has_ref, vab_ref, vaa_ref, crb_ref, cra_ref, draws_ref,
    unif_ref, par_ref,
    vab_o, vaa_o, crb_o, cra_o, draws_o,
    vend_o, qva_o, vafu_o, cend_o, qcr_o, crfu_o, auv_o, pos_o,
):
    t_arr = par_ref[0]
    xi_va = par_ref[1]
    xi_cr = par_ref[2]
    d_vc = par_ref[3]
    d_cu = par_ref[4]
    p_tp = par_ref[5]
    S = real_ref.shape[1]
    U = unif_ref.shape[0]

    def body(s, state):
        b_v, a_v, b_c, a_c, dr = state
        real = real_ref[0, s] != 0
        has = has_ref[0, s] != 0
        # VA chain (all slots of a tick share the fused-FC arrival).
        fu_v = t_arr >= b_v
        st_v = jnp.where(a_v != 0, b_v, t_arr + (b_v - t_arr))
        end_v = jnp.where(fu_v, t_arr + xi_va, st_v + xi_va)
        q_v = jnp.where(fu_v, 0.0, st_v - t_arr)
        b_v = jnp.where(real, end_v, b_v)
        a_v = jnp.where(real, jnp.where(fu_v, 0, 1), a_v)
        # CR chain.
        arr_c = end_v + d_vc
        fu_c = arr_c >= b_c
        st_c = jnp.where(a_c != 0, b_c, arr_c + (b_c - arr_c))
        end_c = jnp.where(fu_c, arr_c + xi_cr, st_c + xi_cr)
        q_c = jnp.where(fu_c, 0.0, st_c - arr_c)
        b_c = jnp.where(real, end_c, b_c)
        a_c = jnp.where(real, jnp.where(fu_c, 0, 1), a_c)
        # Verdict: one draw from the lane's position in the shared stream
        # per sourced frame that carries the entity.
        u = unif_ref[jnp.minimum(dr, U - 1)]
        drawn = jnp.logical_and(real, has)
        pos = jnp.logical_and(drawn, u <= p_tp)
        dr = dr + drawn.astype(dr.dtype)
        vend_o[0, s] = end_v
        qva_o[0, s] = q_v
        vafu_o[0, s] = fu_v.astype(jnp.int32)
        cend_o[0, s] = end_c
        qcr_o[0, s] = q_c
        crfu_o[0, s] = fu_c.astype(jnp.int32)
        auv_o[0, s] = end_c + d_cu
        pos_o[0, s] = pos.astype(jnp.int32)
        return b_v, a_v, b_c, a_c, dr

    state = (vab_ref[0], vaa_ref[0], crb_ref[0], cra_ref[0], draws_ref[0])
    b_v, a_v, b_c, a_c, dr = jax.lax.fori_loop(0, S, body, state)
    vab_o[0] = b_v
    vaa_o[0] = a_v
    crb_o[0] = b_c
    cra_o[0] = a_c
    draws_o[0] = dr


def lane_chain_tick_pallas(
    real, has, va_b, va_armed, cr_b, cr_armed, draws, uniforms, params,
    *, interpret: bool = False,
):
    """One tick's chain sweep for every lane.

    ``real/has``: (L, S) bool padded slot occupancy / entity visibility;
    ``va_b/cr_b``: (L,) f64 busy-until; ``va_armed/cr_armed``: (L,) bool;
    ``draws``: (L,) int64 per-lane draw counters; ``uniforms``: (U,) f64;
    ``params``: (6,) f64 ``[t_arr, xi_va, xi_cr, d_vc, d_cu, p_tp]``.

    Returns the updated chain state plus per-slot ``(L, S)`` outputs
    ``(va_end, q_va, va_fused, cr_end, q_cr, cr_fused, a_uv, positive)``,
    bit-identical to the jnp inner scan in :mod:`.ops`.
    """
    L, S = real.shape
    U = uniforms.shape[0]
    f64 = jnp.float64
    i32 = jnp.int32
    i64 = draws.dtype

    lane_state = pl.BlockSpec((1,), lambda l: (l,))
    lane_slots = pl.BlockSpec((1, S), lambda l: (l, 0))
    shared_u = pl.BlockSpec((U,), lambda l: (0,))
    shared_p = pl.BlockSpec((N_PARAMS,), lambda l: (0,))

    outs = pl.pallas_call(
        _kernel,
        grid=(L,),
        in_specs=[
            lane_slots, lane_slots,               # real, has
            lane_state, lane_state,               # va_b, va_armed
            lane_state, lane_state,               # cr_b, cr_armed
            lane_state,                           # draws
            shared_u, shared_p,                   # uniforms, params
        ],
        out_specs=[
            lane_state, lane_state, lane_state, lane_state, lane_state,
            lane_slots, lane_slots, lane_slots, lane_slots, lane_slots,
            lane_slots, lane_slots, lane_slots,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L,), f64),      # va_b
            jax.ShapeDtypeStruct((L,), i32),      # va_armed
            jax.ShapeDtypeStruct((L,), f64),      # cr_b
            jax.ShapeDtypeStruct((L,), i32),      # cr_armed
            jax.ShapeDtypeStruct((L,), i64),      # draws
            jax.ShapeDtypeStruct((L, S), f64),    # va_end
            jax.ShapeDtypeStruct((L, S), f64),    # q_va
            jax.ShapeDtypeStruct((L, S), i32),    # va_fused
            jax.ShapeDtypeStruct((L, S), f64),    # cr_end
            jax.ShapeDtypeStruct((L, S), f64),    # q_cr
            jax.ShapeDtypeStruct((L, S), i32),    # cr_fused
            jax.ShapeDtypeStruct((L, S), f64),    # a_uv
            jax.ShapeDtypeStruct((L, S), i32),    # positive
        ],
        interpret=interpret,
    )(
        real.astype(i32), has.astype(i32),
        va_b, va_armed.astype(i32), cr_b, cr_armed.astype(i32), draws,
        uniforms, params,
    )
    (vab, vaa, crb, cra, dr,
     va_end, q_va, va_fu, cr_end, q_cr, cr_fu, a_uv, pos) = outs
    return (
        vab, vaa != 0, crb, cra != 0, dr,
        va_end, q_va, va_fu != 0, cr_end, q_cr, cr_fu != 0, a_uv, pos != 0,
    )
