"""Numpy reference for the fused mega-step tick engine.

Replays the drops-off streaming pipeline (fused FC sourcing -> VA
pass-through -> CR verdict -> sink) as a per-lane busy-chain state machine
over precomputed tick tables, in plain python/numpy floats.  Every float
expression mirrors the discrete-event code path it replaces:

* fused streaming exec:   ``end = arrival + xi`` (``Task.on_arrival``)
* first queued exec:      ``start = A + (busy_until - A)`` — the drain
  callback is scheduled with a *relative* delay, so the anchor is the
  arrival of the first queued event of the busy period
  (``Task.on_arrival`` -> ``_drain_fused``)
* subsequent queued:      ``start = busy_until`` (``_finish_and_continue``
  pops at the previous exec's end)
* transits: arrival = exec_end + delay, one float add per hop, identical
  for the fused (``schedule_at(depart_at + delay)``) and queued
  (``schedule(delay)`` at exec end) paths.

The TL update is a callback so two backends share the chain: the table
update in :func:`make_table_tl` (base/bfs/wbfs via precomputed radius/hop
tables and per-candidate distance planes — what `ops.py` runs on device)
and the real-TL-object update the driver supplies for probabilistic /
kernel-spotlight configs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np

__all__ = ["SinkRow", "ChainOutput", "run_chain", "make_table_tl", "sink_sort_key"]


@dataclass
class SinkRow:
    """One event's end-to-end record: everything the sink, the TL and the
    result assembly need (a compact per-tick summary row)."""

    __slots__ = (
        "a_uv", "tick", "grank", "slot", "lane", "cam", "positive",
        "u", "q_bar", "va_fused", "va_end", "cr_arr", "cr_fused", "cr_end",
        "mask",
    )
    a_uv: float      # sink arrival time
    tick: int        # source frame tick index
    grank: int       # VA delivery-group rank at the source tick (tie order)
    slot: int        # slot within the lane at the source tick
    lane: int
    cam: int
    positive: bool
    u: float         # end-to-end latency (a_uv - tick time)
    q_bar: float     # accumulated queuing (VA + CR stages)
    va_fused: bool
    va_end: float
    cr_arr: float
    cr_fused: bool
    cr_end: float
    mask: np.ndarray  # (N,) bool: per-query tag bits at source time


def sink_sort_key(r: SinkRow) -> Tuple[float, int, int, int]:
    """Sink processing order.  Heap order is (time, seq); for equal arrival
    times the scheduling cascade preserves, per source tick, the VA
    delivery-group creation order (rank of each lane's first active camera)
    and the slot order within a lane; across ticks the earlier tick's
    events were scheduled earlier and thus carry smaller seqs."""
    return (r.a_uv, r.tick, r.grank, r.slot)


@dataclass
class ChainOutput:
    rows: List[SinkRow]                    # all sink rows, final sink order
    source_events: int
    positives_generated: int
    sourced: np.ndarray                    # (N,) per-query sourced frames
    query_positives: np.ndarray            # (N,) per-query positives generated
    tl_counts: List[Tuple[int, np.ndarray, int]]  # (tick, (N,) active, union)
    va_exec_counts: np.ndarray             # (L,) execs counted before horizon
    cr_exec_counts: np.ndarray             # (L,)
    final_req: Optional[np.ndarray] = None  # (N, C) last requested matrix


class _LaneChain:
    """The fused-streaming busy chain of one task instance (VA-i / CR-i)."""

    __slots__ = ("b", "armed")

    def __init__(self) -> None:
        self.b = -np.inf   # busy_until after the last scheduled exec
        self.armed = False  # a drain was armed for the current busy period

    def step(self, arrival: float, xi: float) -> Tuple[float, float, bool]:
        """Process one arrival; returns (exec_end, q, fused)."""
        b = self.b
        if arrival >= b:
            end = arrival + xi
            self.b = end
            self.armed = False
            return end, 0.0, True
        if not self.armed:
            # First queued event of the busy period: the drain fires at
            # now + (busy_until - now) — up to 1 ulp from busy_until.
            start = arrival + (b - arrival)
            self.armed = True
        else:
            start = b
        end = start + xi
        self.b = end
        return end, start - arrival, False


def run_chain(
    plan,
    tl_step: Callable[[int, List[SinkRow]], np.ndarray],
    seed_applied: np.ndarray,
) -> ChainOutput:
    """Run the whole drops-off pipeline over every tick of ``plan``.

    ``plan`` is duck-typed (see ``repro.core.megastep.MegastepPlan``):
    ``ftimes (T,)``, ``vis (T, C) bool``, ``lane_of (C,) int``,
    ``num_lanes``, ``xi_fc/xi_va/xi_cr``, ``d_fv/d_vc/d_cu``,
    ``uniforms (dmax,)``, ``p_tp``, ``horizon``.

    ``tl_step(k, dets)`` consumes the detections that arrived strictly
    before tick ``k``'s time (already in sink order) and returns the
    ``(N, C)`` bool requested matrix — which becomes the *applied* matrix
    for tick ``k``'s sourcing onwards (control latency < tick period).
    ``seed_applied`` is the t=0 matrix (pre-run activation is immediate).
    """
    ftimes = plan.ftimes
    vis = plan.vis
    lane_of = plan.lane_of
    L = plan.num_lanes
    xi_fc, xi_va, xi_cr = plan.xi_fc, plan.xi_va, plan.xi_cr
    d_fv, d_vc, d_cu = plan.d_fv, plan.d_vc, plan.d_cu
    uniforms = plan.uniforms
    p_tp = plan.p_tp
    horizon = plan.horizon
    T = len(ftimes)

    va = [_LaneChain() for _ in range(L)]
    cr = [_LaneChain() for _ in range(L)]
    draws = [0] * L
    applied = np.ascontiguousarray(seed_applied, dtype=bool)
    N = applied.shape[0]

    pending: List[SinkRow] = []
    rows: List[SinkRow] = []
    sourced = np.zeros(N, dtype=np.int64)
    query_pos = np.zeros(N, dtype=np.int64)
    g_source = 0
    g_pos = 0
    tl_counts: List[Tuple[int, np.ndarray, int]] = []

    for k in range(T):
        now = float(ftimes[k])
        if k >= 1:
            # TL tick fires before the frame tick at the shared time and
            # consumes every detection that arrived strictly before it.
            take = [r for r in pending if r.a_uv < now]
            if take:
                pending = [r for r in pending if not (r.a_uv < now)]
                take.sort(key=sink_sort_key)
            new_req = tl_step(k, take)
            tl_counts.append(
                (k, new_req.sum(axis=1, dtype=np.int64), int(new_req.any(axis=0).sum()))
            )
        else:
            new_req = applied

        # Sourcing uses the PREVIOUS tick's targets: the TL tick's control
        # deltas land one control latency later, after the same-time frame
        # tick (latency < tick period, checked by eligibility).
        union = applied.any(axis=0)
        cams = np.nonzero(union)[0]
        if cams.size == 0:
            applied = new_req
            continue
        sourced += applied.sum(axis=1, dtype=np.int64)
        vis_k = vis[k]
        query_pos += (applied & vis_k).sum(axis=1, dtype=np.int64)
        g_source += int(cams.size)
        g_pos += int(vis_k[cams].sum())

        # Fused FC: every sourced frame departs at t + xi_fc and arrives at
        # its VA (one grouped delivery per lane) at depart + transit.
        t_arr = (now + xi_fc) + d_fv
        lane_order: List[int] = []
        lane_slots: dict = {}
        for c in cams:
            l = int(lane_of[c])
            g = lane_slots.get(l)
            if g is None:
                lane_slots[l] = [int(c)]
                lane_order.append(l)
            else:
                g.append(int(c))
        for grank, l in enumerate(lane_order):
            va_l, cr_l = va[l], cr[l]
            for slot, c in enumerate(lane_slots[l]):
                va_end, q_va, va_fused = va_l.step(t_arr, xi_va)
                cr_arr = va_end + d_vc
                cr_end, q_cr, cr_fused = cr_l.step(cr_arr, xi_cr)
                has = bool(vis_k[c])
                if has:
                    positive = float(uniforms[draws[l]]) <= p_tp
                    draws[l] += 1
                else:
                    positive = False
                a_uv = cr_end + d_cu
                row = SinkRow(
                    a_uv=a_uv, tick=k, grank=grank, slot=slot, lane=l, cam=c,
                    positive=positive, u=a_uv - now, q_bar=(0.0 + q_va) + q_cr,
                    va_fused=va_fused, va_end=va_end, cr_arr=cr_arr,
                    cr_fused=cr_fused, cr_end=cr_end,
                    mask=applied[:, c].copy(),
                )
                rows.append(row)
                pending.append(row)
        applied = new_req

    rows.sort(key=sink_sort_key)

    # Exec counts for the global batch-size books: a fused exec is counted
    # at its arrival (always before the horizon: sourcing stops at
    # duration); a queued exec is counted by the finish callback at its
    # end, which the scheduler only processes up to the horizon.
    va_execs = np.zeros(L, dtype=np.int64)
    cr_execs = np.zeros(L, dtype=np.int64)
    for r in rows:
        if r.va_fused or r.va_end <= horizon:
            va_execs[r.lane] += 1
        if r.cr_arr <= horizon and (r.cr_fused or r.cr_end <= horizon):
            cr_execs[r.lane] += 1

    return ChainOutput(
        rows=rows,
        source_events=g_source,
        positives_generated=g_pos,
        sourced=sourced,
        query_positives=query_pos,
        tl_counts=tl_counts,
        va_exec_counts=va_execs,
        cr_exec_counts=cr_execs,
        final_req=applied.copy(),
    )


def make_table_tl(plan) -> Callable[[int, List[SinkRow]], np.ndarray]:
    """Table-driven TL update for base/bfs/wbfs queries — the host mirror
    of the device scan's TL step.

    Plan attrs used: ``modes (N,) int8`` (0 base / 1 bfs / 2 wbfs),
    ``rgroup (N,) int``, ``r_tabs[g] (T, T) f64``, ``h_tabs[g] (T, T)
    int64``, ``cand_of_cam (C,) int``, ``dist_plane (n_cand, C) f64``,
    ``hop_plane (n_cand, C) int64``, ``seed_ls_cam (N,)``, ``num_cameras``.

    Radius/hop arithmetic lives entirely in the host-built tables
    (``R[i, j] = min_radius + speed * (f_j - f_i)``), so the per-tick update
    is pure comparisons and gathers — no float math to diverge on.
    """
    N = len(plan.modes)
    C = plan.num_cameras
    ls_cam = np.asarray(plan.seed_ls_cam, dtype=np.int64).copy()
    ls_tick = np.zeros(N, dtype=np.int64)
    modes = plan.modes
    rgroup = plan.rgroup
    cand_of_cam = plan.cand_of_cam
    dist_plane = plan.dist_plane
    hop_plane = plan.hop_plane
    r_tabs = plan.r_tabs
    h_tabs = plan.h_tabs

    def tl_step(k: int, dets: List[SinkRow]) -> np.ndarray:
        nonlocal ls_cam, ls_tick
        if dets:
            # Per query: the newest positive wins (max timestamp == max
            # source tick; python max keeps the first among equals, i.e.
            # the earliest in sink order).
            masks = np.stack([r.mask for r in dets])          # (M, N)
            pos = np.fromiter((r.positive for r in dets), dtype=bool, count=len(dets))
            ticks = np.fromiter((r.tick for r in dets), dtype=np.int64, count=len(dets))
            cand = masks & pos[:, None]                        # (M, N)
            any_pos = cand.any(axis=0)
            if any_pos.any():
                t_masked = np.where(cand, ticks[:, None], -1)
                best_tick = t_masked.max(axis=0)               # (N,)
                # First row in sink order among max-tick positives.
                hit = cand & (ticks[:, None] == best_tick[None, :])
                first = hit.argmax(axis=0)                     # (N,)
                cams = np.fromiter((r.cam for r in dets), dtype=np.int64, count=len(dets))
                ls_cam = np.where(any_pos, cams[first], ls_cam)
                ls_tick = np.where(any_pos, best_tick, ls_tick)
        else:
            any_pos = np.zeros(N, dtype=bool)

        req = np.zeros((N, C), dtype=bool)
        for q in range(N):
            mode = modes[q]
            if mode == 0:
                # TLBase: every camera stays active even on a positive (its
                # update only tracks last_seen, which nothing reads).
                req[q, :] = True
                continue
            if any_pos[q]:
                req[q, ls_cam[q]] = True
                continue
            g = rgroup[q]
            src = cand_of_cam[ls_cam[q]]
            if mode == 1:  # bfs hop ball
                hops = h_tabs[g][ls_tick[q], k]
                req[q] = hop_plane[src] <= hops
            else:          # wbfs weighted ball
                radius = r_tabs[g][ls_tick[q], k]
                req[q] = dist_plane[src] <= radius
        return req

    return tl_step
