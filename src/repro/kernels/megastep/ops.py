"""Device mega-step: the whole drops-off run as K-tick ``lax.scan`` chunks.

The engine keeps everything the hot loop touches resident on device —
camera activity masks (the per-query ``applied`` bit matrix), the query tag
bits packed into one uint64 per camera, the visibility table, the spotlight
distance/hop planes, the radius/hop tables and the shared CR verdict
stream — and executes frames -> VA -> CR -> sink rows -> TL spotlight ->
control update for all queries and K ticks per dispatch.  Only compact
per-(tick, lane, slot) summary rows come back to the host, which rebuilds
``ref.SinkRow`` records and the per-query books from them.

Bit-exactness: every float op is an f64 add/sub/compare in the exact order
of the numpy reference (no multiplies anywhere on the device path, so no
FMA contraction; tables carrying the radius arithmetic are host-built), so
rows are bit-identical to ``ref.run_chain`` + ``ref.make_table_tl``.

Shapes are bucket-padded (cameras, queries, lane slots, detection ring,
ticks-per-chunk, table dims) so a sweep compiles the scan at most once per
bucket shape; the compile cache is bounded through
``dispatch.bound_jit_cache`` like every other padded kernel.  Data-driven
capacities (slots per lane, in-flight detections) carry sticky overflow
flags: on overflow the run is retried with the offending dimension
doubled, and past the caps the caller falls back to the host reference.
"""

from __future__ import annotations

import math
import time
from typing import List, Optional, Tuple

import numpy as np

from .. import dispatch
from . import ref as _ref

__all__ = ["run_chain_device", "last_xfer_seconds", "last_chunk_seconds",
           "KMAX", "RING_CAP"]

KMAX = 256        # ticks per dispatch (chunk) cap
RING_CAP = 1 << 14  # in-flight detection records before host fallback

_CHUNK_FN = None

# Device->host transfer wall of the most recent run_chain_device call (the
# per-chunk summary pulls + the final carry).  Benchmarks report this as
# the separate ``xfer_s`` column so compute and transfer don't blur.
_LAST_XFER_S = 0.0
_LAST_DEVICE_ERROR = ""
# Per-chunk host wall (dispatch + device compute + summary pull) of the most
# recent run_chain_device call — the observability plane's mega-step profile
# (repro.obs.collect_engine).  Attribution only, never a decision input.
_CHUNK_WALL_S: list = []


def last_xfer_seconds() -> float:
    return _LAST_XFER_S


def last_chunk_seconds() -> list:
    """Per-chunk wall times (seconds) of the most recent
    :func:`run_chain_device` call, in chunk order; empty when the device
    path was never tried or fell back before the scan."""
    return list(_CHUNK_WALL_S)


def last_device_error() -> str:
    """repr() of the exception that made the most recent
    :func:`run_chain_device` call hand the run to the host reference
    ("" when the device path succeeded or was never tried).  The broad
    catch is intentional — *any* backend failure must fall back, exactness
    preserved — but it must stay observable, not silent."""
    return _LAST_DEVICE_ERROR


def _build_chunk_fn():
    import jax
    import jax.numpy as jnp
    from jax import lax

    def chunk(carry, ftimes_k, valid_k, vis_k, k0, scalars, tables,
              *, use_pallas: bool, interpret: bool):
        xi_fc, xi_va, xi_cr, d_fv, d_vc, d_cu, p_tp = scalars
        (lane_of, uniforms, modes, rgroup, r_tab, h_tab,
         cand_of_cam, dist_plane, hop_plane, qvalid, cvalid, slot_iota) = tables

        Nb, Cb = carry[0].shape
        L = carry[3].shape[0]
        S = slot_iota.shape[0]
        R = carry[8].shape[0]
        Tb = r_tab.shape[-1]
        U = uniforms.shape[0]
        INT_BIG = jnp.iinfo(jnp.int64).max

        lane_ids = jnp.arange(L, dtype=jnp.int64)
        cam_ids = jnp.arange(Cb, dtype=jnp.int64)
        q_shift = jnp.arange(Nb, dtype=jnp.uint64)
        lane_onehot = lane_of[:, None] == lane_ids[None, :]      # (Cb, L)

        def tick_step(c, xs):
            (applied, ls_cam, ls_tick, va_b, va_armed, cr_b, cr_armed, draws,
             ring_valid, ring_auv, ring_tick, ring_gen, ring_cam, ring_pos,
             ring_mask, of_slots, of_ring) = c
            now, valid, vis_row, i = xs
            k = k0 + i

            # ---- TL tick (fires before the frame tick for k >= 1 and
            # consumes detections that arrived strictly before it) -------- #
            do_tl = valid & (k >= 1)
            take = ring_valid & (ring_auv < now) & do_tl          # (R,)
            cand = take[:, None] & ring_mask & ring_pos[:, None]  # (R, Nb)
            any_pos = cand.any(axis=0)
            # Newest positive per query: max source tick, then first in
            # sink order (min a_uv, then min generation index).
            tickv = jnp.where(cand, ring_tick[:, None], jnp.int64(-1))
            best_tick = tickv.max(axis=0)
            cand2 = cand & (ring_tick[:, None] == best_tick[None, :])
            auvv = jnp.where(cand2, ring_auv[:, None], jnp.inf)
            best_auv = auvv.min(axis=0)
            cand3 = cand2 & (ring_auv[:, None] == best_auv[None, :])
            genv = jnp.where(cand3, ring_gen[:, None], INT_BIG)
            win = jnp.argmin(genv, axis=0)                        # (Nb,)
            upd = do_tl & any_pos
            ls_cam = jnp.where(upd, ring_cam[win], ls_cam)
            ls_tick = jnp.where(upd, best_tick, ls_tick)
            ring_valid = ring_valid & ~take

            # Spotlight from the table planes: pure gathers + compares.
            kt = jnp.minimum(k, Tb - 1)
            lst = jnp.minimum(ls_tick, Tb - 1)
            src = jnp.maximum(cand_of_cam[ls_cam], 0)
            hops = h_tab[rgroup, lst, kt]                         # (Nb,)
            rad = r_tab[rgroup, lst, kt]
            req_hot = cam_ids[None, :] == ls_cam[:, None]
            req_bfs = hop_plane[src] <= hops[:, None]
            req_wbfs = dist_plane[src] <= rad[:, None]
            req = jnp.where(
                (modes == 0)[:, None], True,
                jnp.where(any_pos[:, None], req_hot,
                          jnp.where((modes == 1)[:, None], req_bfs, req_wbfs)),
            )
            req = req & qvalid[:, None] & cvalid[None, :]
            new_req = jnp.where(do_tl, req, applied)
            tl_counts = jnp.where(do_tl, new_req.sum(axis=1, dtype=jnp.int64), 0)
            tl_union = jnp.where(
                do_tl, new_req.any(axis=0).sum(dtype=jnp.int64), 0
            )

            # ---- sourcing: uses the PREVIOUS tick's applied matrix (the
            # TL's control deltas land one control latency later) --------- #
            bits = jnp.sum(
                jnp.where(applied, jnp.uint64(1) << q_shift[:, None],
                          jnp.uint64(0)),
                axis=0, dtype=jnp.uint64,
            )                                                     # (Cb,)
            active = applied.any(axis=0) & valid                  # (Cb,)
            act_lane = active[:, None] & lane_onehot              # (Cb, L)
            cum = jnp.cumsum(act_lane.astype(jnp.int64), axis=0)
            slot = jnp.take_along_axis(cum, lane_of[:, None], axis=1)[:, 0] - 1
            n_l = cum[-1]                                         # (L,)
            of_slots = of_slots | (n_l.max() > S)
            camv = jnp.where(act_lane, cam_ids[:, None], INT_BIG)
            min_cam = camv.min(axis=0)                            # (L,)
            grank = jnp.sum(
                min_cam[None, :] < min_cam[:, None], axis=1, dtype=jnp.int64
            )

            ok = active & (slot < S)
            scat = jnp.where(ok, lane_of * S + slot, L * S)
            cam_at = jnp.full(L * S, -1, dtype=jnp.int64).at[scat].set(
                cam_ids, mode="drop"
            ).reshape(L, S)
            real_ls = cam_at >= 0
            cam_c = jnp.maximum(cam_at, 0)
            has_ls = vis_row[cam_c] & real_ls

            t_arr = (now + xi_fc) + d_fv

            if use_pallas:
                from .kernel import lane_chain_tick_pallas

                params = jnp.stack([t_arr, xi_va, xi_cr, d_vc, d_cu, p_tp])
                (va_b, va_armed, cr_b, cr_armed, draws,
                 va_end, q_va, va_fu, cr_end, q_cr, cr_fu, a_uv, pos) = (
                    lane_chain_tick_pallas(
                        real_ls, has_ls, va_b, va_armed, cr_b, cr_armed,
                        draws, uniforms, params, interpret=interpret,
                    )
                )
            else:
                def slot_step(cc, s):
                    b_v, a_v, b_c, a_c, dr = cc
                    real = real_ls[:, s]
                    has = has_ls[:, s]
                    fu_v = t_arr >= b_v
                    st_v = jnp.where(a_v, b_v, t_arr + (b_v - t_arr))
                    end_v = jnp.where(fu_v, t_arr + xi_va, st_v + xi_va)
                    q_v = jnp.where(fu_v, 0.0, st_v - t_arr)
                    b_v = jnp.where(real, end_v, b_v)
                    a_v = jnp.where(real, ~fu_v, a_v)
                    arr_c = end_v + d_vc
                    fu_c = arr_c >= b_c
                    st_c = jnp.where(a_c, b_c, arr_c + (b_c - arr_c))
                    end_c = jnp.where(fu_c, arr_c + xi_cr, st_c + xi_cr)
                    q_c = jnp.where(fu_c, 0.0, st_c - arr_c)
                    b_c = jnp.where(real, end_c, b_c)
                    a_c = jnp.where(real, ~fu_c, a_c)
                    u = uniforms[jnp.minimum(dr, U - 1)]
                    drawn = real & has
                    p = drawn & (u <= p_tp)
                    dr = dr + drawn
                    return (b_v, a_v, b_c, a_c, dr), (
                        end_v, q_v, fu_v, end_c, q_c, fu_c, end_c + d_cu, p
                    )

                (va_b, va_armed, cr_b, cr_armed, draws), so = lax.scan(
                    slot_step, (va_b, va_armed, cr_b, cr_armed, draws),
                    slot_iota,
                )
                (va_end, q_va, va_fu, cr_end, q_cr, cr_fu, a_uv, pos) = (
                    x.T for x in so
                )

            # ---- detection ring insertion ------------------------------- #
            real_flat = real_ls.reshape(-1)
            gen_flat = (
                (k * L + grank[:, None]) * S + slot_iota[None, :]
            ).reshape(-1)
            cam_flat = cam_c.reshape(-1)
            mask_flat = applied.T[cam_flat]                        # (L*S, Nb)
            free = ~ring_valid
            n_free = free.sum(dtype=jnp.int64)
            n_new = real_flat.sum(dtype=jnp.int64)
            of_ring = of_ring | (n_new > n_free)
            frank = jnp.cumsum(free.astype(jnp.int64)) - 1
            slot_of_rank = jnp.full(R, R, dtype=jnp.int64).at[
                jnp.where(free, frank, R)
            ].set(jnp.arange(R, dtype=jnp.int64), mode="drop")
            erank = jnp.cumsum(real_flat.astype(jnp.int64)) - 1
            dest = jnp.where(
                real_flat, slot_of_rank[jnp.minimum(erank, R - 1)], R
            )
            ring_valid = ring_valid.at[dest].set(True, mode="drop")
            ring_auv = ring_auv.at[dest].set(a_uv.reshape(-1), mode="drop")
            ring_tick = ring_tick.at[dest].set(k, mode="drop")
            ring_gen = ring_gen.at[dest].set(gen_flat, mode="drop")
            ring_cam = ring_cam.at[dest].set(cam_flat, mode="drop")
            ring_pos = ring_pos.at[dest].set(pos.reshape(-1), mode="drop")
            ring_mask = ring_mask.at[dest].set(mask_flat, mode="drop")

            c2 = (new_req, ls_cam, ls_tick, va_b, va_armed, cr_b, cr_armed,
                  draws, ring_valid, ring_auv, ring_tick, ring_gen, ring_cam,
                  ring_pos, ring_mask, of_slots, of_ring)
            ys = (bits, tl_counts, tl_union, grank, cam_at, real_ls,
                  va_end, q_va, va_fu, cr_end, q_cr, cr_fu, a_uv, pos)
            return c2, ys

        K = ftimes_k.shape[0]
        xs = (ftimes_k, valid_k, vis_k, jnp.arange(K, dtype=jnp.int64))
        return lax.scan(tick_step, carry, xs)

    return jax.jit(chunk, static_argnames=("use_pallas", "interpret"))


def _plan_device_tables(plan, jnp, Nb, Cb, Tb):
    """Pad the host-built plan tables to bucket shapes and upload."""
    C = plan.num_cameras
    N = len(plan.modes)
    T = len(plan.ftimes)
    i64max = np.iinfo(np.int64).max

    G = max(len(plan.r_tabs), 1)
    Gb = dispatch.bucket(G)
    r_tab = np.zeros((Gb, Tb, Tb), dtype=np.float64)
    h_tab = np.zeros((Gb, Tb, Tb), dtype=np.int64)
    for g in range(len(plan.r_tabs)):
        r_tab[g, :T, :T] = plan.r_tabs[g]
        h_tab[g, :T, :T] = plan.h_tabs[g]

    ncand = max(plan.dist_plane.shape[0], 1)
    NCb = dispatch.bucket(ncand)
    dist = np.full((NCb, Cb), np.inf)
    hop = np.full((NCb, Cb), i64max, dtype=np.int64)
    nc = plan.dist_plane.shape[0]
    dist[:nc, :C] = plan.dist_plane
    hop[:nc, :C] = plan.hop_plane

    cand_of_cam = np.zeros(Cb, dtype=np.int64)
    cand_of_cam[:C] = plan.cand_of_cam
    lane_of = np.zeros(Cb, dtype=np.int64)
    lane_of[:C] = plan.lane_of
    modes = np.ones(Nb, dtype=np.int8)
    modes[:N] = plan.modes
    rgroup = np.zeros(Nb, dtype=np.int64)
    rgroup[:N] = plan.rgroup
    U = dispatch.bucket(max(len(plan.uniforms), 1))
    uniforms = np.full(U, 2.0)  # pad draws can never read as positive
    uniforms[: len(plan.uniforms)] = plan.uniforms
    qvalid = np.arange(Nb) < N
    cvalid = np.arange(Cb) < C
    return (
        jnp.asarray(lane_of), jnp.asarray(uniforms),
        jnp.asarray(modes), jnp.asarray(rgroup),
        jnp.asarray(r_tab), jnp.asarray(h_tab),
        jnp.asarray(cand_of_cam), jnp.asarray(dist), jnp.asarray(hop),
        jnp.asarray(qvalid), jnp.asarray(cvalid),
    ), (Gb, NCb, U)


def _initial_capacities(plan, seed_applied) -> Tuple[int, int, int]:
    L = plan.num_lanes
    C = plan.num_cameras
    union = seed_applied.any(axis=0)
    s0 = 0
    if union.any():
        s0 = int(np.bincount(plan.lane_of[union], minlength=L).max())
    s_max = dispatch.bucket(max(int(math.ceil(C / max(L, 1))), 1))
    S = min(dispatch.bucket(max(4, s0)), s_max)
    R = min(dispatch.bucket(max(64, 4 * L * S)), RING_CAP)
    return S, R, s_max


def _assemble(plan, seed_applied, ys, final_applied, d_vc, d_cu,
              counters=None):
    """Rebuild the ChainOutput (rows in final sink order, per-query books)
    from the device scan's per-tick summaries — every float reconstructed
    here is a single IEEE add of the same operands the reference uses.

    ``counters=(sourced, query_positives)`` skips the host-side per-query
    recount: the sharded engine all-reduces these on device (one psum per
    chunk) and hands the exact integer books over directly."""
    (bits, tlc, tlu, grank, cam_at, real,
     va_end, q_va, va_fu, cr_end, q_cr, cr_fu, a_uv, pos) = ys
    T = len(plan.ftimes)
    N = seed_applied.shape[0]
    C = plan.num_cameras
    ftimes = plan.ftimes
    horizon = plan.horizon

    ts, ls_, ss = np.nonzero(real)
    cam_e = cam_at[ts, ls_, ss]
    gr_e = grank[ts, ls_]
    bits_rows = bits[ts, cam_e]
    masks = (
        (bits_rows[:, None] >> np.arange(N, dtype=np.uint64)[None, :])
        & np.uint64(1)
    ).astype(bool)
    vend_e = va_end[ts, ls_, ss]
    qva_e = q_va[ts, ls_, ss]
    vafu_e = va_fu[ts, ls_, ss]
    cend_e = cr_end[ts, ls_, ss]
    qcr_e = q_cr[ts, ls_, ss]
    crfu_e = cr_fu[ts, ls_, ss]
    auv_e = a_uv[ts, ls_, ss]
    pos_e = pos[ts, ls_, ss]

    rows: List[_ref.SinkRow] = []
    for e in range(len(ts)):
        t = int(ts[e])
        now = float(ftimes[t])
        a = float(auv_e[e])
        vend = float(vend_e[e])
        rows.append(_ref.SinkRow(
            a_uv=a, tick=t, grank=int(gr_e[e]), slot=int(ss[e]),
            lane=int(ls_[e]), cam=int(cam_e[e]), positive=bool(pos_e[e]),
            u=a - now, q_bar=(0.0 + float(qva_e[e])) + float(qcr_e[e]),
            va_fused=bool(vafu_e[e]), va_end=vend, cr_arr=vend + d_vc,
            cr_fused=bool(crfu_e[e]), cr_end=float(cend_e[e]),
            mask=masks[e],
        ))
    rows.sort(key=_ref.sink_sort_key)

    union_rows = bits[:, :C] != 0
    g_source = int(union_rows.sum())
    g_pos = int((union_rows & plan.vis).sum())
    if counters is not None:
        sourced = np.asarray(counters[0], dtype=np.int64)
        qpos = np.asarray(counters[1], dtype=np.int64)
    else:
        sourced = np.zeros(N, dtype=np.int64)
        qpos = np.zeros(N, dtype=np.int64)
        for q in range(N):
            m = ((bits[:, :C] >> np.uint64(q)) & np.uint64(1)).astype(bool)
            sourced[q] = m.sum()
            qpos[q] = (m & plan.vis).sum()

    tl_counts = [
        (k, tlc[k, :N].astype(np.int64), int(tlu[k])) for k in range(1, T)
    ]

    L = plan.num_lanes
    va_execs = np.zeros(L, dtype=np.int64)
    cr_execs = np.zeros(L, dtype=np.int64)
    for r in rows:
        if r.va_fused or r.va_end <= horizon:
            va_execs[r.lane] += 1
        if r.cr_arr <= horizon and (r.cr_fused or r.cr_end <= horizon):
            cr_execs[r.lane] += 1

    return _ref.ChainOutput(
        rows=rows,
        source_events=g_source,
        positives_generated=g_pos,
        sourced=sourced,
        query_positives=qpos,
        tl_counts=tl_counts,
        va_exec_counts=va_execs,
        cr_exec_counts=cr_execs,
        final_req=np.ascontiguousarray(final_applied[:N, :C]),
    )


def run_chain_device(plan, seed_applied) -> Optional[_ref.ChainOutput]:
    """Run the fused scan on device; None means "use the host reference"
    (jax unavailable, capacities exceeded, or any backend failure)."""
    global _CHUNK_FN, _LAST_XFER_S, _LAST_DEVICE_ERROR
    if plan.modes is None:
        return None
    try:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64
    except ImportError:  # no jax: the caller falls back to the host ref
        return None
    _LAST_XFER_S = 0.0
    _LAST_DEVICE_ERROR = ""
    del _CHUNK_WALL_S[:]

    try:
        with enable_x64():
            if _CHUNK_FN is None:
                _CHUNK_FN = _build_chunk_fn()
            fn = _CHUNK_FN

            C = plan.num_cameras
            N = seed_applied.shape[0]
            L = plan.num_lanes
            T = len(plan.ftimes)
            Cb = dispatch.bucket(C)
            Nb = min(dispatch.bucket(N), 64)
            if N > Nb:
                return None
            Tb = dispatch.bucket(T)
            K = min(dispatch.bucket(T), KMAX)
            nchunk = (T + K - 1) // K

            tables_np, (Gb, NCb, U) = _plan_device_tables(plan, jnp, Nb, Cb, Tb)
            use_pallas = dispatch._use_pallas()
            interpret = jax.default_backend() != "tpu"
            scalars = tuple(
                jnp.asarray(v, jnp.float64)
                for v in (plan.xi_fc, plan.xi_va, plan.xi_cr,
                          plan.d_fv, plan.d_vc, plan.d_cu, plan.p_tp)
            )
            vis_pad = np.zeros((nchunk * K, Cb), dtype=bool)
            vis_pad[:T, :C] = plan.vis
            ft_pad = np.full(nchunk * K, float(plan.ftimes[-1]))
            ft_pad[:T] = plan.ftimes
            valid_pad = np.arange(nchunk * K) < T

            applied0 = np.zeros((Nb, Cb), dtype=bool)
            applied0[:N, :C] = seed_applied
            ls_cam0 = np.zeros(Nb, dtype=np.int64)
            ls_cam0[:N] = plan.seed_ls_cam

            S, R, s_max = _initial_capacities(plan, seed_applied)
            while True:
                tables = tables_np + (jnp.arange(S, dtype=jnp.int64),)
                carry = (
                    jnp.asarray(applied0),
                    jnp.asarray(ls_cam0),
                    jnp.zeros(Nb, dtype=jnp.int64),
                    jnp.full(L, -jnp.inf, dtype=jnp.float64),
                    jnp.zeros(L, dtype=bool),
                    jnp.full(L, -jnp.inf, dtype=jnp.float64),
                    jnp.zeros(L, dtype=bool),
                    jnp.zeros(L, dtype=jnp.int64),
                    jnp.zeros(R, dtype=bool),
                    jnp.full(R, jnp.inf, dtype=jnp.float64),
                    jnp.zeros(R, dtype=jnp.int64),
                    jnp.zeros(R, dtype=jnp.int64),
                    jnp.zeros(R, dtype=jnp.int64),
                    jnp.zeros(R, dtype=bool),
                    jnp.zeros((R, Nb), dtype=bool),
                    jnp.asarray(False),
                    jnp.asarray(False),
                )
                key = ("megastep", Cb, Nb, L, S, R, K, Tb, Gb, NCb, U,
                       use_pallas)
                dispatch._note_shape(key)
                dispatch.bound_jit_cache("megastep", fn, key)
                chunks = []
                del _CHUNK_WALL_S[:]  # capacity retry: re-profile the scan
                for ci in range(nchunk):
                    c0 = time.perf_counter()
                    sl = slice(ci * K, (ci + 1) * K)
                    carry, ys = fn(
                        carry,
                        jnp.asarray(ft_pad[sl]),
                        jnp.asarray(valid_pad[sl]),
                        jnp.asarray(vis_pad[sl]),
                        jnp.asarray(ci * K, dtype=jnp.int64),
                        scalars,
                        tables,
                        use_pallas=use_pallas,
                        interpret=interpret,
                    )
                    jax.block_until_ready(ys)  # compute, then time the pull
                    x0 = time.perf_counter()
                    chunks.append(jax.device_get(ys))
                    _LAST_XFER_S += time.perf_counter() - x0
                    _CHUNK_WALL_S.append(time.perf_counter() - c0)
                x0 = time.perf_counter()
                of_slots = bool(jax.device_get(carry[-2]))
                of_ring = bool(jax.device_get(carry[-1]))
                _LAST_XFER_S += time.perf_counter() - x0
                if not (of_slots or of_ring):
                    ys = tuple(
                        np.concatenate([c[f] for c in chunks], axis=0)[:T]
                        for f in range(len(chunks[0]))
                    )
                    x0 = time.perf_counter()
                    final_applied = np.asarray(jax.device_get(carry[0]))
                    _LAST_XFER_S += time.perf_counter() - x0
                    return _assemble(
                        plan, seed_applied, ys, final_applied,
                        plan.d_vc, plan.d_cu,
                    )
                # Divergence: grow the flagged capacity and retry; past the
                # caps, hand the run to the host reference.
                grew = False
                if of_slots and S < s_max:
                    S = min(S * 2, s_max)
                    R = min(max(R, dispatch.bucket(4 * L * S)), RING_CAP)
                    grew = True
                if of_ring and R < RING_CAP:
                    R = min(R * 2, RING_CAP)
                    grew = True
                if not grew:
                    return None
    except Exception as e:
        # Intentionally broad: whatever kills the device backend (XLA,
        # driver, shape divergence), the host reference takes over and the
        # result stays bit-exact — but the reason is recorded, not dropped.
        _LAST_DEVICE_ERROR = repr(e)
        return None
