"""Sharded mega-step: the fused tick scan over a ``cameras`` device mesh.

The camera-block world — per-query activity masks (``applied``), the
visibility table, the spotlight distance/hop planes and the per-camera lane
map — lives sharded over a 1-D ``cameras`` mesh axis via ``shard_map``
(through :mod:`repro.distributed.compat`); the query registry state (tag
bits, modes, radius tables, last-seen cameras) and the lane/ring machinery
are replicated.  Per tick, only the **frontier** crosses shard boundaries:

* per-lane active counts — one ``all_gather`` of (D, L) ints, giving each
  shard the exclusive prefix that turns its local lane slots into global
  sink-order slots;
* lane min-camera ranks — one ``pmin`` of (L,) ints;
* the (lane, slot) occupancy/visibility/tag-mask rows — ``psum``/``pmax``
  of (L, S) and (L, S, Nb) frontier tables that exactly one shard writes
  per slot (scatter-disjoint, so integer reductions are exact);
* TL spotlight counts — ``psum`` of (Nb,) ints.

Per-query budget counters (sourced / positives) accumulate **locally** in
the scan carry and are all-reduced once per K-tick chunk — the trace
cadence — not per tick.

Everything float stays replicated and is computed in the reference order on
every shard, so the result is **bit-identical** to the single-device scan
(`ops.run_chain_device`) and therefore to the interpreted pipeline; the
tests gate exactly that across 1/2/4/8 emulated host devices.  The
collective volume is O(L·S·Nb + D·L) per tick — frontier rows, never the
O(C) world — and is reported per run via
:func:`last_collective_bytes_per_tick`.
"""

from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import numpy as np

from .. import dispatch
from . import ops as _ops
from . import ref as _ref

__all__ = [
    "run_chain_sharded",
    "last_xfer_seconds",
    "last_chunk_seconds",
    "last_shards",
    "last_collective_bytes_per_tick",
]

_SHARDED_FNS: Dict[Tuple, object] = {}

_LAST_XFER_S = 0.0
_LAST_SHARDS = 1
_LAST_COLLECTIVE_BPT = 0.0
_LAST_ERROR = ""
# Per-chunk host wall of the most recent sharded scan (same contract as
# ``ops._CHUNK_WALL_S``): observability attribution, never a decision input.
_CHUNK_WALL_S: list = []


def last_xfer_seconds() -> float:
    return _LAST_XFER_S


def last_chunk_seconds() -> list:
    """Per-chunk wall times (seconds) of the most recent sharded scan, in
    chunk order; empty when the sharded path was never tried or fell back."""
    return list(_CHUNK_WALL_S)


def last_shards() -> int:
    """Shard count of the most recent successful run_chain_sharded call."""
    return _LAST_SHARDS


def last_collective_bytes_per_tick() -> float:
    """Analytic per-tick cross-shard traffic (bytes moved per device) of
    the most recent run: the frontier collectives listed in the module
    docstring, not the sharded world."""
    return _LAST_COLLECTIVE_BPT


def last_error() -> str:
    return _LAST_ERROR


def _build_sharded_chunk_fn(mesh, axis: str):
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from ...distributed.compat import shard_map

    D = mesh.shape[axis]

    def chunk(carry, ftimes_k, valid_k, vis_k, k0, scalars, tables):
        xi_fc, xi_va, xi_cr, d_fv, d_vc, d_cu, p_tp = scalars
        (lane_of, uniforms, modes, rgroup, r_tab, h_tab,
         cand_of_cam, dist_plane, hop_plane, qvalid, cvalid, slot_iota) = tables

        Nb = carry[0].shape[0]
        Cl = carry[0].shape[1]          # local camera-block width (Cb / D)
        L = carry[3].shape[0]
        S = slot_iota.shape[0]
        R = carry[8].shape[0]
        Tb = r_tab.shape[-1]
        U = uniforms.shape[0]
        INT_BIG = jnp.iinfo(jnp.int64).max

        lane_ids = jnp.arange(L, dtype=jnp.int64)
        cam0 = lax.axis_index(axis).astype(jnp.int64) * Cl
        cam_ids = cam0 + jnp.arange(Cl, dtype=jnp.int64)  # global ids
        q_shift = jnp.arange(Nb, dtype=jnp.uint64)
        shard_before = jnp.arange(D, dtype=jnp.int64) < lax.axis_index(axis)
        lane_onehot = lane_of[:, None] == lane_ids[None, :]   # (Cl, L)

        def tick_step(c, xs):
            (applied, ls_cam, ls_tick, va_b, va_armed, cr_b, cr_armed, draws,
             ring_valid, ring_auv, ring_tick, ring_gen, ring_cam, ring_pos,
             ring_mask, of_slots, of_ring, acc_src, acc_pos) = c
            now, valid, vis_row, i = xs
            k = k0 + i

            # ---- TL tick: replicated ring consume (same as ops) ---------- #
            do_tl = valid & (k >= 1)
            take = ring_valid & (ring_auv < now) & do_tl
            cand = take[:, None] & ring_mask & ring_pos[:, None]
            any_pos = cand.any(axis=0)
            tickv = jnp.where(cand, ring_tick[:, None], jnp.int64(-1))
            best_tick = tickv.max(axis=0)
            cand2 = cand & (ring_tick[:, None] == best_tick[None, :])
            auvv = jnp.where(cand2, ring_auv[:, None], jnp.inf)
            best_auv = auvv.min(axis=0)
            cand3 = cand2 & (ring_auv[:, None] == best_auv[None, :])
            genv = jnp.where(cand3, ring_gen[:, None], INT_BIG)
            win = jnp.argmin(genv, axis=0)
            upd = do_tl & any_pos
            ls_cam = jnp.where(upd, ring_cam[win], ls_cam)
            ls_tick = jnp.where(upd, best_tick, ls_tick)
            ring_valid = ring_valid & ~take

            # Spotlight over this shard's camera-block columns.
            kt = jnp.minimum(k, Tb - 1)
            lst = jnp.minimum(ls_tick, Tb - 1)
            src = jnp.maximum(cand_of_cam[ls_cam], 0)
            hops = h_tab[rgroup, lst, kt]
            rad = r_tab[rgroup, lst, kt]
            req_hot = cam_ids[None, :] == ls_cam[:, None]
            req_bfs = hop_plane[src] <= hops[:, None]
            req_wbfs = dist_plane[src] <= rad[:, None]
            req = jnp.where(
                (modes == 0)[:, None], True,
                jnp.where(any_pos[:, None], req_hot,
                          jnp.where((modes == 1)[:, None], req_bfs, req_wbfs)),
            )
            req = req & qvalid[:, None] & cvalid[None, :]
            new_req = jnp.where(do_tl, req, applied)
            tl_counts = jnp.where(
                do_tl,
                lax.psum(new_req.sum(axis=1, dtype=jnp.int64), axis),
                0,
            )
            tl_union = jnp.where(
                do_tl,
                lax.psum(new_req.any(axis=0).sum(dtype=jnp.int64), axis),
                0,
            )

            # ---- sourcing from the PREVIOUS tick's applied --------------- #
            bits = jnp.sum(
                jnp.where(applied, jnp.uint64(1) << q_shift[:, None],
                          jnp.uint64(0)),
                axis=0, dtype=jnp.uint64,
            )                                                     # (Cl,)
            active = applied.any(axis=0) & valid                  # (Cl,)
            act_lane = active[:, None] & lane_onehot              # (Cl, L)
            local_n = act_lane.sum(axis=0, dtype=jnp.int64)       # (L,)
            counts_all = lax.all_gather(local_n, axis)            # (D, L)
            # Exclusive prefix over shards: cameras are block-contiguous per
            # shard, so global sink order == (shard, local) order and each
            # local lane slot offsets by the active count on earlier shards.
            before = jnp.sum(
                jnp.where(shard_before[:, None], counts_all, 0), axis=0
            )                                                     # (L,)
            cum = jnp.cumsum(act_lane.astype(jnp.int64), axis=0)
            slot_l = jnp.take_along_axis(cum, lane_of[:, None], axis=1)[:, 0] - 1
            slot = slot_l + before[lane_of]                       # global slot
            n_l = counts_all.sum(axis=0)                          # (L,)
            of_slots = of_slots | (n_l.max() > S)
            camv = jnp.where(act_lane, cam_ids[:, None], INT_BIG)
            min_cam = lax.pmin(camv.min(axis=0), axis)            # (L,)
            grank = jnp.sum(
                min_cam[None, :] < min_cam[:, None], axis=1, dtype=jnp.int64
            )

            # Frontier scatter: exactly one shard owns each (lane, slot), so
            # pmax/psum over scatter-disjoint tables reassemble exactly.
            ok = active & (slot < S)
            scat = jnp.where(ok, lane_of * S + slot, L * S)
            cam_at = lax.pmax(
                jnp.full(L * S, -1, dtype=jnp.int64).at[scat].set(
                    cam_ids, mode="drop"
                ),
                axis,
            ).reshape(L, S)
            real_ls = cam_at >= 0
            cam_c = jnp.maximum(cam_at, 0)
            has_ls = lax.psum(
                jnp.zeros(L * S, dtype=jnp.int32).at[scat].set(
                    vis_row.astype(jnp.int32), mode="drop"
                ),
                axis,
            ).reshape(L, S) > 0
            mask_flat = lax.psum(
                jnp.zeros((L * S, Nb), dtype=jnp.int32).at[scat].set(
                    applied.T.astype(jnp.int32), mode="drop"
                ),
                axis,
            ) > 0                                                 # (L*S, Nb)

            t_arr = (now + xi_fc) + d_fv

            def slot_step(cc, s):
                b_v, a_v, b_c, a_c, dr = cc
                real = real_ls[:, s]
                has = has_ls[:, s]
                fu_v = t_arr >= b_v
                st_v = jnp.where(a_v, b_v, t_arr + (b_v - t_arr))
                end_v = jnp.where(fu_v, t_arr + xi_va, st_v + xi_va)
                q_v = jnp.where(fu_v, 0.0, st_v - t_arr)
                b_v = jnp.where(real, end_v, b_v)
                a_v = jnp.where(real, ~fu_v, a_v)
                arr_c = end_v + d_vc
                fu_c = arr_c >= b_c
                st_c = jnp.where(a_c, b_c, arr_c + (b_c - arr_c))
                end_c = jnp.where(fu_c, arr_c + xi_cr, st_c + xi_cr)
                q_c = jnp.where(fu_c, 0.0, st_c - arr_c)
                b_c = jnp.where(real, end_c, b_c)
                a_c = jnp.where(real, ~fu_c, a_c)
                u = uniforms[jnp.minimum(dr, U - 1)]
                drawn = real & has
                p = drawn & (u <= p_tp)
                dr = dr + drawn
                return (b_v, a_v, b_c, a_c, dr), (
                    end_v, q_v, fu_v, end_c, q_c, fu_c, end_c + d_cu, p
                )

            (va_b, va_armed, cr_b, cr_armed, draws), so = lax.scan(
                slot_step, (va_b, va_armed, cr_b, cr_armed, draws), slot_iota,
            )
            (va_end, q_va, va_fu, cr_end, q_cr, cr_fu, a_uv, pos) = (
                x.T for x in so
            )

            # ---- detection ring insertion (replicated, same as ops) ------ #
            real_flat = real_ls.reshape(-1)
            gen_flat = (
                (k * L + grank[:, None]) * S + slot_iota[None, :]
            ).reshape(-1)
            cam_flat = cam_c.reshape(-1)
            free = ~ring_valid
            n_free = free.sum(dtype=jnp.int64)
            n_new = real_flat.sum(dtype=jnp.int64)
            of_ring = of_ring | (n_new > n_free)
            frank = jnp.cumsum(free.astype(jnp.int64)) - 1
            slot_of_rank = jnp.full(R, R, dtype=jnp.int64).at[
                jnp.where(free, frank, R)
            ].set(jnp.arange(R, dtype=jnp.int64), mode="drop")
            erank = jnp.cumsum(real_flat.astype(jnp.int64)) - 1
            dest = jnp.where(
                real_flat, slot_of_rank[jnp.minimum(erank, R - 1)], R
            )
            ring_valid = ring_valid.at[dest].set(True, mode="drop")
            ring_auv = ring_auv.at[dest].set(a_uv.reshape(-1), mode="drop")
            ring_tick = ring_tick.at[dest].set(k, mode="drop")
            ring_gen = ring_gen.at[dest].set(gen_flat, mode="drop")
            ring_cam = ring_cam.at[dest].set(cam_flat, mode="drop")
            ring_pos = ring_pos.at[dest].set(pos.reshape(-1), mode="drop")
            ring_mask = ring_mask.at[dest].set(mask_flat, mode="drop")

            # ---- per-query budget counters: local accumulation ----------- #
            acc_src = acc_src + jnp.where(
                valid, applied.sum(axis=1, dtype=jnp.int64), 0
            )
            acc_pos = acc_pos + jnp.where(
                valid,
                (applied & vis_row[None, :]).sum(axis=1, dtype=jnp.int64),
                0,
            )

            c2 = (new_req, ls_cam, ls_tick, va_b, va_armed, cr_b, cr_armed,
                  draws, ring_valid, ring_auv, ring_tick, ring_gen, ring_cam,
                  ring_pos, ring_mask, of_slots, of_ring, acc_src, acc_pos)
            ys = (bits, tl_counts, tl_union, grank, cam_at, real_ls,
                  va_end, q_va, va_fu, cr_end, q_cr, cr_fu, a_uv, pos)
            return c2, ys

        K = ftimes_k.shape[0]
        xs = (ftimes_k, valid_k, vis_k, jnp.arange(K, dtype=jnp.int64))
        src0, pos0 = carry[-2], carry[-1]
        carry2, ys = lax.scan(tick_step, carry, xs)
        # Budgets all-reduce once per chunk — the trace cadence.  The
        # incoming counters are already global (replicated), so only this
        # chunk's local delta is summed; psum-ing the running total would
        # multiply every prior chunk's count by the shard count.
        carry2 = carry2[:-2] + (
            src0 + lax.psum(carry2[-2] - src0, axis),
            pos0 + lax.psum(carry2[-1] - pos0, axis),
        )
        return carry2, ys

    # applied is camera-sharded; lane/ring state, the detection ring and
    # the query-side tables are replicated; the bits summary comes back
    # camera-sharded while every per-(lane, slot) summary is replicated.
    P_cam = P(None, axis)
    carry_specs = (
        P_cam,                                  # applied (Nb, Cb)
        P(), P(),                               # ls_cam, ls_tick
        P(), P(), P(), P(), P(),                # va/cr busy state + draws
        P(), P(), P(), P(), P(), P(), P(),      # detection ring
        P(), P(),                               # overflow flags
        P(), P(),                               # per-query budget counters
    )
    tables_specs = (
        P(axis),                                # lane_of (Cb,)
        P(), P(), P(), P(), P(),                # uniforms..h_tab (replicated)
        P(),                                    # cand_of_cam: indexed by the
                                                # replicated last-seen cam
        P_cam, P_cam,                           # dist/hop planes (NCb, Cb)
        P(), P(axis), P(),                      # qvalid, cvalid, slot_iota
    )
    ys_specs = (P_cam,) + (P(),) * 13
    fn = shard_map(
        chunk,
        mesh=mesh,
        in_specs=(carry_specs, P(), P(), P_cam, P(),
                  (P(),) * 7, tables_specs),
        out_specs=(carry_specs, ys_specs),
        # Every shard computes the identical replicated outputs through the
        # deterministic psum/pmax combines; the replication checker cannot
        # infer that across lax.scan.
        check=False,
    )
    return jax.jit(fn)


def _collective_bytes_per_tick(D: int, L: int, S: int, Nb: int) -> float:
    """Per-device bytes moved by the frontier collectives each tick."""
    return float(
        D * L * 8        # all_gather of per-lane active counts
        + L * 8          # pmin of lane min-camera
        + L * S * 8      # pmax of slot occupancy (cam_at)
        + L * S * 4      # psum of slot visibility
        + L * S * Nb * 4  # psum of slot tag masks
        + Nb * 8 + 8     # psum of TL counts + union size
    )


def run_chain_sharded(plan, seed_applied, rules) -> Optional[_ref.ChainOutput]:
    """Run the fused scan sharded over the mesh in ``rules``; None means
    "use the unsharded path" (reason in :func:`last_error`) — mesh lacks a
    ``cameras`` axis, a single device, a non-dividing camera bucket, or
    capacities exceeded.  Bit-identical to ``ops.run_chain_device``."""
    global _LAST_XFER_S, _LAST_SHARDS, _LAST_COLLECTIVE_BPT, _LAST_ERROR
    _LAST_ERROR = ""
    if plan.modes is None:
        _LAST_ERROR = "no-table-planes"
        return None
    try:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64
    except ImportError:
        _LAST_ERROR = "no-jax"
        return None

    mesh = rules.mesh
    axis = "cameras" if "cameras" in mesh.axis_names else None
    if axis is None:
        _LAST_ERROR = "no-cameras-axis"
        return None
    D = int(mesh.shape[axis])
    if D <= 1:
        # Single visible device: the unsharded scan IS the single-shard
        # path and is bit-identical by construction.
        _LAST_ERROR = "single-device"
        return None

    C = plan.num_cameras
    N = seed_applied.shape[0]
    L = plan.num_lanes
    T = len(plan.ftimes)
    Cb = dispatch.bucket(C)
    if Cb % D != 0:
        _LAST_ERROR = f"camera-bucket {Cb} % {D} shards != 0"
        return None
    Nb = min(dispatch.bucket(N), 64)
    if N > Nb:
        _LAST_ERROR = "queries>64"
        return None
    Tb = dispatch.bucket(T)
    K = min(dispatch.bucket(T), _ops.KMAX)
    nchunk = (T + K - 1) // K
    _LAST_XFER_S = 0.0
    del _CHUNK_WALL_S[:]

    try:
        with enable_x64():
            fkey = (tuple(d.id for d in mesh.devices.flat), axis)
            fn = _SHARDED_FNS.get(fkey)
            if fn is None:
                fn = _build_sharded_chunk_fn(mesh, axis)
                _SHARDED_FNS[fkey] = fn

            tables_np, (Gb, NCb, U) = _ops._plan_device_tables(
                plan, jnp, Nb, Cb, Tb
            )
            scalars = tuple(
                jnp.asarray(v, jnp.float64)
                for v in (plan.xi_fc, plan.xi_va, plan.xi_cr,
                          plan.d_fv, plan.d_vc, plan.d_cu, plan.p_tp)
            )
            vis_pad = np.zeros((nchunk * K, Cb), dtype=bool)
            vis_pad[:T, :C] = plan.vis
            ft_pad = np.full(nchunk * K, float(plan.ftimes[-1]))
            ft_pad[:T] = plan.ftimes
            valid_pad = np.arange(nchunk * K) < T

            applied0 = np.zeros((Nb, Cb), dtype=bool)
            applied0[:N, :C] = seed_applied
            ls_cam0 = np.zeros(Nb, dtype=np.int64)
            ls_cam0[:N] = plan.seed_ls_cam

            S, R, s_max = _ops._initial_capacities(plan, seed_applied)
            while True:
                tables = tables_np + (jnp.arange(S, dtype=jnp.int64),)
                carry = (
                    jnp.asarray(applied0),
                    jnp.asarray(ls_cam0),
                    jnp.zeros(Nb, dtype=jnp.int64),
                    jnp.full(L, -jnp.inf, dtype=jnp.float64),
                    jnp.zeros(L, dtype=bool),
                    jnp.full(L, -jnp.inf, dtype=jnp.float64),
                    jnp.zeros(L, dtype=bool),
                    jnp.zeros(L, dtype=jnp.int64),
                    jnp.zeros(R, dtype=bool),
                    jnp.full(R, jnp.inf, dtype=jnp.float64),
                    jnp.zeros(R, dtype=jnp.int64),
                    jnp.zeros(R, dtype=jnp.int64),
                    jnp.zeros(R, dtype=jnp.int64),
                    jnp.zeros(R, dtype=bool),
                    jnp.zeros((R, Nb), dtype=bool),
                    jnp.asarray(False),
                    jnp.asarray(False),
                    jnp.zeros(Nb, dtype=jnp.int64),
                    jnp.zeros(Nb, dtype=jnp.int64),
                )
                key = ("megastep-sharded", D, Cb, Nb, L, S, R, K, Tb, Gb,
                       NCb, U)
                dispatch._note_shape(key)
                dispatch.bound_jit_cache("megastep_sharded", fn, key)
                chunks = []
                del _CHUNK_WALL_S[:]  # capacity retry: re-profile the scan
                for ci in range(nchunk):
                    c0 = time.perf_counter()
                    sl = slice(ci * K, (ci + 1) * K)
                    carry, ys = fn(
                        carry,
                        jnp.asarray(ft_pad[sl]),
                        jnp.asarray(valid_pad[sl]),
                        jnp.asarray(vis_pad[sl]),
                        jnp.asarray(ci * K, dtype=jnp.int64),
                        scalars,
                        tables,
                    )
                    jax.block_until_ready(ys)
                    x0 = time.perf_counter()
                    chunks.append(jax.device_get(ys))
                    _LAST_XFER_S += time.perf_counter() - x0
                    _CHUNK_WALL_S.append(time.perf_counter() - c0)
                x0 = time.perf_counter()
                of_slots = bool(jax.device_get(carry[15]))
                of_ring = bool(jax.device_get(carry[16]))
                _LAST_XFER_S += time.perf_counter() - x0
                if not (of_slots or of_ring):
                    ys = tuple(
                        np.concatenate([c[f] for c in chunks], axis=0)[:T]
                        for f in range(len(chunks[0]))
                    )
                    x0 = time.perf_counter()
                    final_applied = np.asarray(jax.device_get(carry[0]))
                    sourced = np.asarray(jax.device_get(carry[17]))[:N]
                    qpos = np.asarray(jax.device_get(carry[18]))[:N]
                    _LAST_XFER_S += time.perf_counter() - x0
                    _LAST_SHARDS = D
                    _LAST_COLLECTIVE_BPT = _collective_bytes_per_tick(
                        D, L, S, Nb
                    )
                    return _ops._assemble(
                        plan, seed_applied, ys, final_applied,
                        plan.d_vc, plan.d_cu,
                        counters=(sourced, qpos),
                    )
                grew = False
                if of_slots and S < s_max:
                    S = min(S * 2, s_max)
                    R = min(max(R, dispatch.bucket(4 * L * S)), _ops.RING_CAP)
                    grew = True
                if of_ring and R < _ops.RING_CAP:
                    R = min(R * 2, _ops.RING_CAP)
                    grew = True
                if not grew:
                    _LAST_ERROR = "capacity"
                    return None
    except Exception as e:
        # Same contract as the unsharded scan: any backend failure falls
        # back (here: to the unsharded device path), reason recorded.
        _LAST_ERROR = repr(e)
        return None
