"""Pallas TPU kernel for the batched spotlight-ball relaxation.

One dense min-plus product per call: ``out[q, v] = min(D[q, v],
min_u D[q, u] + W[u, v])`` — the inner step of the Bellman-Ford fixpoint in
``ops.spotlight_ball``.  Grid ``(Q_blocks, V_blocks, U_blocks)`` with the
reduction dimension innermost, exactly like a tiled matmul on the
``(min, +)`` semiring: each step loads a (block_q, block_u) tile of the
distance matrix and a (block_u, block_v) tile of the adjacency, reduces over
``u``, and accumulates ``min`` into the output tile resident in VMEM.

``min`` is exact and float addition of non-negative lengths is monotone, so
the tiled reduction is bit-identical to the dense reference regardless of
block sizes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["relax_step_pallas"]


def _kernel(d_ref, w_ref, dcur_ref, out_ref):
    k = pl.program_id(2)
    d = d_ref[...]  # (block_q, block_u)
    w = w_ref[...]  # (block_u, block_v)
    part = jnp.min(d[:, :, None] + w[None, :, :], axis=1)  # (block_q, block_v)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.minimum(dcur_ref[...], part)

    @pl.when(k > 0)
    def _accum():
        out_ref[...] = jnp.minimum(out_ref[...], part)


def relax_step_pallas(
    D: jax.Array,  # (Q, V) current distances
    W: jax.Array,  # (V, V) dense min-plus adjacency (inf off-edge)
    *,
    block_q: int = 8,
    block_v: int = 128,
    block_u: int = 128,
    interpret: bool = False,
) -> jax.Array:
    import math

    Q, V = D.shape
    block_q = min(block_q, Q)
    block_v = min(block_v, V)
    block_u = min(block_u, V)
    pad_q = (-Q) % block_q
    # V is tiled both as the reduction (block_u) and output (block_v) dim:
    # pad to a common multiple so both grids divide evenly.
    pad = (-V) % math.lcm(block_v, block_u)
    Dp = jnp.pad(D, ((0, pad_q), (0, pad)), constant_values=jnp.inf)
    Wp = jnp.pad(W, ((0, pad), (0, pad)), constant_values=jnp.inf)
    Qp, Vp = Dp.shape

    out = pl.pallas_call(
        _kernel,
        grid=(Qp // block_q, Vp // block_v, Vp // block_u),
        in_specs=[
            pl.BlockSpec((block_q, block_u), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_u, block_v), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_q, block_v), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_q, block_v), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp, Vp), D.dtype),
        interpret=interpret,
    )(Dp, Wp, Dp)
    return out[:Q, :V]
