"""jit-ready wrapper for the batched spotlight-ball search (see flash ops).

``spotlight_ball(indptr, indices, weights, sources, radii)`` relaxes a batch
of Q query balls over the CSR road graph and returns (Q, V) distances with
``inf`` outside each query's radius.  Backend selection mirrors
``reid_match``: the dense min-plus fixpoint runs through the Pallas kernel on
TPU (or when forced via ``REPRO_FORCE_PALLAS=1``, interpreted off-TPU) and
through the pure-jnp reference otherwise.
"""

from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .ref import dense_adjacency, relax_step_ref, spotlight_ball_ref

__all__ = ["spotlight_ball"]


def _use_pallas() -> bool:
    force = os.environ.get("REPRO_FORCE_PALLAS", "")
    if force == "1":
        return True
    if force == "0":
        return False
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def _iterate_pallas(W: jax.Array, D0: jax.Array, radii: jax.Array, *, interpret: bool):
    from .kernel import relax_step_pallas

    V = W.shape[0]

    def cond(state):
        D, changed, it = state
        return jnp.logical_and(changed, it < V)

    def body(state):
        D, _, it = state
        Dn = relax_step_pallas(D, W, interpret=interpret)
        return Dn, jnp.any(Dn < D), it + 1

    D, _, _ = jax.lax.while_loop(cond, body, (D0, jnp.bool_(True), jnp.int32(0)))
    inf = jnp.array(jnp.inf, dtype=D.dtype)
    return jnp.where(D <= radii[:, None], D, inf)


def spotlight_ball(
    indptr,
    indices,
    weights,
    sources,
    radii,
) -> jax.Array:
    """Batched Dijkstra balls over a CSR graph.

    Parameters are CSR arrays (``indptr`` (V+1,), ``indices``/``weights``
    (E,)) plus per-query ``sources`` (Q,) and ``radii`` (Q,).  Returns a
    (Q, V) distance matrix in the weights' dtype, ``inf`` where a vertex is
    unreachable or outside the query's radius.
    """
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    weights = np.asarray(weights)
    W = jnp.asarray(dense_adjacency(indptr, indices, weights))
    sources = jnp.asarray(sources, dtype=jnp.int32)
    radii = jnp.asarray(radii, dtype=W.dtype)
    if _use_pallas():
        Q, V = sources.shape[0], W.shape[0]
        inf = jnp.array(jnp.inf, dtype=W.dtype)
        D0 = jnp.full((Q, V), inf, dtype=W.dtype)
        D0 = D0.at[jnp.arange(Q), sources].set(jnp.zeros((), dtype=W.dtype))
        return _iterate_pallas(
            W, D0, radii, interpret=jax.default_backend() != "tpu"
        )
    return spotlight_ball_ref(W, sources, radii)
