"""Pure-jnp oracle for the batched spotlight-ball search (TL hot loop).

Given a road network in CSR form and a batch of ``Q`` queries (source vertex
+ radius), compute every query's Dijkstra ball at once: shortest road
distances from each source, masked to ``inf`` outside the query radius.

The relaxation is a dense min-plus fixpoint iteration (Bellman-Ford over the
dense adjacency): ``D <- min(D, min_u D[:, u] + W[u, :])`` until no entry
improves.  Because float addition of non-negative weights is monotone and
``min`` is exact, the fixpoint equals the per-path left-fold sums Dijkstra
computes — bit-exact agreement with ``RoadNetwork.weighted_ball`` at equal
dtype (run under x64 to compare against the pure-Python float64 search).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["dense_adjacency", "spotlight_ball_ref", "relax_step_ref"]


def dense_adjacency(
    indptr: np.ndarray, indices: np.ndarray, weights: np.ndarray
) -> np.ndarray:
    """Densify a CSR graph into a (V, V) min-plus adjacency matrix: edge
    lengths where an edge exists, ``+inf`` elsewhere (host-side, done once
    per network)."""
    num_vertices = len(indptr) - 1
    W = np.full((num_vertices, num_vertices), np.inf, dtype=weights.dtype)
    src = np.repeat(np.arange(num_vertices), np.diff(indptr))
    W[src, indices] = weights
    return W


def relax_step_ref(D: jax.Array, W: jax.Array) -> jax.Array:
    """One dense min-plus relaxation: ``min(D, min_u D[:,u] + W[u,:])``."""
    cand = jnp.min(D[:, :, None] + W[None, :, :], axis=1)
    return jnp.minimum(D, cand)


def spotlight_ball_ref(
    W: jax.Array,  # (V, V) dense min-plus adjacency
    sources: jax.Array,  # (Q,) int32 source vertices
    radii: jax.Array,  # (Q,) radii (same dtype as W)
) -> jax.Array:
    """Returns (Q, V) distances, ``inf`` where unreachable or beyond each
    query's radius."""
    V = W.shape[0]
    Q = sources.shape[0]
    inf = jnp.array(jnp.inf, dtype=W.dtype)
    D0 = jnp.full((Q, V), inf, dtype=W.dtype)
    D0 = D0.at[jnp.arange(Q), sources].set(jnp.zeros((), dtype=W.dtype))

    def cond(state):
        D, changed, it = state
        return jnp.logical_and(changed, it < V)

    def body(state):
        D, _, it = state
        Dn = relax_step_ref(D, W)
        return Dn, jnp.any(Dn < D), it + 1

    D, _, _ = jax.lax.while_loop(cond, body, (D0, jnp.bool_(True), jnp.int32(0)))
    return jnp.where(D <= radii[:, None], D, inf)
