"""jit-ready wrapper for prefill/train attention.

Dispatch: the Pallas TPU kernel when running on TPU (or when
``REPRO_FORCE_PALLAS=1``, which uses interpret mode on CPU — slow, test-only);
otherwise the pure-jnp reference, which XLA fuses well enough on CPU.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax

from .ref import attention_chunked_ref, attention_ref

__all__ = ["flash_attention"]


def _use_pallas() -> bool:
    force = os.environ.get("REPRO_FORCE_PALLAS", "")
    if force == "1":
        return True
    if force == "0":
        return False
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "scale")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Attention over (B, S, Hq, D) queries and (B, T, Hkv, D) KV."""
    if _use_pallas():
        from .kernel import flash_attention_pallas

        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale,
            interpret=jax.default_backend() != "tpu",
        )
    # Long sequences: blockwise online-softmax (flash working-set profile);
    # short ones: the dense oracle (faster to trace/execute on CPU).
    S, T = q.shape[1], k.shape[1]
    if S * T > (4096 * 4096) and S > 1024:
        return attention_chunked_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale
        )
    return attention_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale
    )
