"""Pure-jnp oracle for prefill/train attention (GQA, causal, sliding window).

This is the numerical ground truth the Pallas kernel is validated against
(``tests/test_kernels_flash.py`` sweeps shapes/dtypes with assert_allclose).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.partitioning import constrain, current_rules

__all__ = ["attention_ref", "attention_chunked_ref"]


def attention_ref(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,  # (B, T, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,  # 0 => unbounded; else attend to [i-window+1, i]
    q_offset: int = 0,  # absolute position of q[0] (prefill continuation)
    scale: Optional[float] = None,
) -> jax.Array:
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    groups = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    # Expand KV heads for grouped-query attention.
    k = jnp.repeat(k, groups, axis=2)  # (B, T, Hq, D)
    v = jnp.repeat(v, groups, axis=2)

    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale

    q_pos = jnp.arange(S) + q_offset  # absolute positions of queries
    k_pos = jnp.arange(T)
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)

    probs = jax.nn.softmax(logits, axis=-1)
    # Fully-masked rows (can happen with tiny windows) produce NaN; zero them.
    probs = jnp.where(jnp.any(mask, axis=-1)[None, None, :, None], probs, 0.0)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_chunked_ref(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,  # (B, T, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    scale: Optional[float] = None,
    q_block: int = 512,
    kv_block: int = 1024,
) -> jax.Array:
    """Blockwise online-softmax attention (flash-style, pure jnp).

    Working set is O(q_block * kv_block) instead of O(S * T) — this is the
    structural stand-in the dry-run lowers for long sequences, matching the
    Pallas kernel's memory profile (the kernel additionally skips fully
    masked blocks; the dry-run counts the full rectangle — see
    EXPERIMENTS.md §Roofline notes).  Numerics match :func:`attention_ref`.
    """
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    Dv = v.shape[-1]  # value head dim may differ (MLA: qk 192 / v 128)
    groups = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    pad_q = (-S) % q_block
    pad_k = (-T) % kv_block
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_block, kp.shape[1] // kv_block

    # (nq, B, qb, Hq, D) / (nk, B, kb, Hkv, D)
    qs = jnp.moveaxis(qp.reshape(B, nq, q_block, Hq, D), 1, 0).astype(jnp.float32)
    ks = jnp.moveaxis(kp.reshape(B, nk, kv_block, Hkv, D), 1, 0).astype(jnp.float32)
    vs = jnp.moveaxis(vp.reshape(B, nk, kv_block, Hkv, Dv), 1, 0).astype(jnp.float32)

    q_pos_base = jnp.arange(q_block)
    k_pos_base = jnp.arange(kv_block)

    # Sliding-window banding (§Perf H1): a q block only sees kv blocks in
    # [q_start - window, q_end] — a static band of
    # ceil((window + q_block) / kv_block) + 1 blocks.  Slicing the band out
    # per q step cuts FLOPs and the saved-for-backward stacks from O(S^2)
    # to O(S * window) — the Pallas kernel gets the same effect from its
    # tile-relevance pl.when.
    band = nk
    if window > 0:
        band = min(nk, (window + q_block + kv_block - 1) // kv_block + 1)

    def q_step(_, qi_qblk):
        qi, qblk = qi_qblk  # scalar, (B, qb, Hq, D)
        # Row-parallel attention for head counts that do not divide the
        # model axis (hymba 25, minicpm 36, whisper 20): shard the q-block
        # row dim (512 divides 16) so the (B, H, qb, kvb) intermediates
        # split across chips instead of replicating (§Perf H1).  Applied
        # only when the launcher activates "q_seq" — an unconditional
        # constraint fights XLA's own placement on well-shaped archs.
        rules = current_rules()
        if rules is not None and rules.rules.get("q_seq"):
            qblk = constrain(qblk, ("batch", "q_seq", None, None))
        q_pos = q_pos_base + qi * q_block + q_offset
        if window > 0 and band < nk:
            lo = (qi * q_block + q_offset - window) // kv_block
            start = jnp.clip(lo, 0, nk - band)
            ks_band = jax.lax.dynamic_slice_in_dim(ks, start, band, axis=0)
            vs_band = jax.lax.dynamic_slice_in_dim(vs, start, band, axis=0)
            kj_idx = start + jnp.arange(band)
        else:
            ks_band, vs_band = ks, vs
            kj_idx = jnp.arange(nk)

        def kv_step(carry, kj_kv):
            m, l, acc = carry
            kj, kblk, vblk = kj_kv
            k_pos = k_pos_base + kj * kv_block
            # GQA: expand KV heads within the block (block is small).
            ke = jnp.repeat(kblk, groups, axis=2)  # (B, kb, Hq, D)
            ve = jnp.repeat(vblk, groups, axis=2)
            logits = jnp.einsum("bqhd,bkhd->bhqk", qblk, ke) * scale
            mask = jnp.ones((q_block, kv_block), bool)
            mask &= (k_pos[None, :] < T)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            logits = jnp.where(mask[None, None], logits, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            # guard -inf rows: exp(-inf - -inf) -> use finite max
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(logits - m_safe[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, ve)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hq, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hq, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hq, q_block, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kj_idx, ks_band, vs_band)
        )
        y = acc / jnp.maximum(l[..., None], 1e-30)  # (B, Hq, qb, D)
        return None, jnp.moveaxis(y, 1, 2)  # (B, qb, Hq, D)

    _, ys = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(ys, 0, 1).reshape(B, nq * q_block, Hq, Dv)[:, :S]
    return out.astype(q.dtype)
