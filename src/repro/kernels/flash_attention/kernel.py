"""Pallas TPU flash attention (prefill/train).

Grid ``(B, Hq, nq, nk)`` — the kv dimension is innermost and sequential on
TPU, so the online-softmax state lives in VMEM scratch across kv steps:

* q tile   (block_q, D)    VMEM, revisited for every kv block
* k/v tile (block_k, D)    VMEM, streamed from the GQA head ``h // groups``
* acc      (block_q, D) f32 scratch;  m/l: (block_q, 1) f32 scratch

Causality/window masking is applied per tile from absolute positions; fully
masked-out kv tiles are skipped with ``pl.when`` (the MXU never sees them).
Block sizes default to (512, 512) — q/k tiles of 512x128 bf16 = 128 KiB each
plus the f32 accumulator keep the working set well under the ~16 MiB VMEM
per core, and both MXU dims stay multiples of 128.

Validated against ``ref.attention_ref`` in interpret mode (CPU) by
``tests/test_kernels_flash.py``.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, block_q, 1, D)
    k_ref,  # (1, block_k, 1, D)
    v_ref,  # (1, block_k, 1, D)
    o_ref,  # (1, block_q, 1, D)
    m_scr,  # (block_q, 1) f32
    l_scr,  # (block_q, 1) f32
    acc_scr,  # (block_q, D) f32
    *,
    causal: bool,
    window: int,
    q_offset: int,
    scale: float,
    block_q: int,
    block_k: int,
    seq_len_q: int,
    seq_len_k: int,
    num_k_blocks: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos_b = (
        qi * block_q
        + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
        + q_offset
    )  # (block_q, 1)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Tile-level relevance: skip tiles entirely above the causal diagonal or
    # entirely left of the window.
    first_q = qi * block_q + q_offset
    last_q = first_q + block_q - 1
    first_k = kj * block_k
    last_k = first_k + block_k - 1
    relevant = jnp.bool_(True)
    if causal:
        relevant = jnp.logical_and(relevant, first_k <= last_q)
    if window > 0:
        relevant = jnp.logical_and(relevant, last_k > first_q - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (block_q, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (block_k, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)
        mask = k_pos < seq_len_k
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos_b)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos_b - window)
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_scr[...]  # (block_q, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)  # (block_q, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(kj == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    Dv = v.shape[-1]  # may differ from D (MLA)
    groups = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, T)

    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sp, Tp = q.shape[1], k.shape[1]
    nq, nk = Sp // block_q, Tp // block_k

    kernel = functools.partial(
        _kernel,
        causal=causal,
        window=window,
        q_offset=q_offset,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        seq_len_q=S,
        seq_len_k=T,
        num_k_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, i, j, g=groups: (b, j, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, Dv), lambda b, h, i, j, g=groups: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, Dv), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Hq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
