"""repro: a growing reproduction of the Anveshak many-camera tracking
platform on a JAX/Pallas stack.

Subpackages are imported explicitly (``repro.core``, ``repro.sim``,
``repro.kernels``, ``repro.serving``, ``repro.query``, ...); this root only
lazily re-exports the multi-query tenancy plane so
``from repro import MultiQueryScenario`` works without importing the whole
stack at startup (PEP 562).
"""

_QUERY_EXPORTS = (
    "AdmissionController",
    "AdmissionPolicy",
    "MultiQueryResult",
    "MultiQueryScenario",
    "QueryRegistry",
    "QuerySpec",
    "QueryState",
    "normalize_queries",
    "run_queries_serial",
)

__all__ = list(_QUERY_EXPORTS)


def __getattr__(name):
    if name in _QUERY_EXPORTS:
        from repro import query

        return getattr(query, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
