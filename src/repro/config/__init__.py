from .base import (
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    get_config,
    list_configs,
    pad_vocab,
    register_config,
)

__all__ = [
    "INPUT_SHAPES", "InputShape", "ModelConfig", "MoEConfig", "SSMConfig",
    "get_config", "list_configs", "pad_vocab", "register_config",
]
