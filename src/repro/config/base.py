"""Configuration system: model/mesh/run configs + registry.

Every assigned architecture gets a ``ModelConfig`` in ``repro.configs.<id>``
citing its source.  Configs are plain frozen dataclasses: hashable, printable,
and safe to close over in jit.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = [
    "MoEConfig",
    "SSMConfig",
    "ModelConfig",
    "InputShape",
    "INPUT_SHAPES",
    "register_config",
    "get_config",
    "list_configs",
    "pad_vocab",
]


def pad_vocab(vocab_size: int, multiple: int = 256) -> int:
    """Megatron-style vocab padding so embedding/logit matrices shard evenly
    over the 16-wide model axis (DESIGN.md §4)."""
    return ((vocab_size + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block parameters."""

    num_experts: int = 0
    top_k: int = 1
    num_shared_experts: int = 0
    d_ff_expert: int = 0  # per-expert FFN hidden size
    d_ff_shared: int = 0  # total shared-expert hidden size
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    router_z_coef: float = 0.0001
    normalize_top_k: bool = True  # renormalize selected probabilities

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block parameters (arXiv:2405.21060)."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    a_init_range: Tuple[float, float] = (1.0, 16.0)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """One architecture.  ``arch_type`` selects the block wiring:

    dense | moe | ssm | hybrid | encdec | vlm
    """

    name: str
    arch_type: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads
    # Attention options
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen2
    sliding_window: int = 0        # 0 => full attention
    global_attn_layers: Tuple[int, ...] = ()  # layers that ignore the window
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w)
    # Norm / misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"              # silu (SwiGLU) | gelu (whisper MLP)
    # MoE / SSM / hybrid
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    first_k_dense_layers: int = 0  # deepseek: leading dense layers before MoE
    meta_tokens: int = 0           # hymba: learnable prefix tokens
    # MLA (deepseek)
    kv_lora_rank: int = 0          # 0 => standard GQA
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # Encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500        # whisper: 30 s of audio at 50 fps
    learned_pos_emb: bool = False
    # Modality frontend stub (audio/vlm): inputs are embeddings, not tokens.
    frontend_stub: bool = False
    # Training-substrate notes (minicpm: WSD)
    lr_schedule: str = "cosine"
    # Provenance
    citation: str = ""

    # ------------------------------------------------------------------ #
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for MODEL_FLOPS."""
        d, v = self.d_model, self.padded_vocab
        hd = self.head_dim_
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        per_layer_attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        if self.kv_lora_rank:  # MLA
            qd = self.qk_nope_head_dim + self.qk_rope_head_dim
            per_layer_attn = (
                d * n_q * qd  # q proj
                + d * (self.kv_lora_rank + self.qk_rope_head_dim)  # kv down
                + self.kv_lora_rank * n_q * (self.qk_nope_head_dim + self.v_head_dim)
                + n_q * self.v_head_dim * d  # o proj
            )
        per_layer_mlp = 3 * d * self.d_ff
        ssm_per_layer = 0
        if self.arch_type in ("ssm", "hybrid"):
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_heads(d)
            conv_dim = di + 2 * self.ssm.n_groups * self.ssm.d_state
            ssm_per_layer = (
                d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state + nh)
                + conv_dim * self.ssm.d_conv
                + di * d
                + 2 * nh  # A_log, D
            )
        n_moe_layers = 0
        if self.moe.enabled:
            n_moe_layers = self.n_layers - self.first_k_dense_layers
            moe_per_layer = (
                self.moe.num_experts * 3 * d * self.moe.d_ff_expert
                + 3 * d * self.moe.d_ff_shared
                + d * self.moe.num_experts  # router
            )
        total_layers = 0
        for layer in range(self.n_layers):
            if self.arch_type == "ssm":
                total_layers += ssm_per_layer + 2 * d  # norms
                continue
            attn = per_layer_attn
            mlp = per_layer_mlp
            if self.moe.enabled and layer >= self.first_k_dense_layers:
                mlp = moe_per_layer
            if self.arch_type == "hybrid":
                attn += ssm_per_layer
            total_layers += attn + mlp + 2 * d
        total += total_layers
        if self.arch_type == "encdec":
            # encoder blocks: self-attn + MLP (gelu: 2 matrices)
            enc_layer = per_layer_attn + 2 * d * self.d_ff + 2 * d
            # decoder adds cross-attention
            total += self.n_encoder_layers * enc_layer + self.n_layers * per_layer_attn
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k + shared)."""
        if not self.moe.enabled:
            return self.param_count()
        d = self.d_model
        n_moe_layers = self.n_layers - self.first_k_dense_layers
        inactive_experts = self.moe.num_experts - self.moe.top_k
        return int(
            self.param_count()
            - n_moe_layers * inactive_experts * 3 * d * self.moe.d_ff_expert
        )


@dataclass(frozen=True)
class InputShape:
    """A benchmark input shape (assigned to this paper)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_config(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str, **overrides: Any) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_configs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401

    return tuple(sorted(_REGISTRY))
