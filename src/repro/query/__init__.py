"""Multi-query tenancy plane: concurrent tracking queries over one shared
camera network.

The platform's unit of service becomes a *set* of live tracking queries:
one pipeline, one world, one discrete-event clock — N spotlights.  See
:mod:`repro.query.scenario` for the fused driver,
:mod:`repro.query.registry` for per-query state/lifecycle, and
:mod:`repro.query.admission` for load shedding.
"""

from .admission import AdmissionController, AdmissionPolicy
from .registry import QUERY_STATES, QueryRegistry, QuerySpec, QueryState
from .scenario import (
    MultiQueryResult,
    MultiQueryScenario,
    normalize_queries,
    run_queries_serial,
)

__all__ = [
    "QUERY_STATES",
    "AdmissionController",
    "AdmissionPolicy",
    "MultiQueryResult",
    "MultiQueryScenario",
    "QueryRegistry",
    "QuerySpec",
    "QueryState",
    "normalize_queries",
    "run_queries_serial",
]
