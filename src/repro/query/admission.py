"""Admission control: cap live queries while the shared pipeline is loaded.

The platform's shared resource is the CR tier: every live query's spotlight
adds cameras to the union the pipeline must serve, and the CR completion
budget ``beta`` (paper §4.5) is the live health signal the dynamism plane
already samples (:class:`~repro.sim.dynamism.DynamismTrace`, PR 4).  The
admission controller closes the loop: when the CR budget degrades past a
threshold, new query submissions are **queued** (or hard-rejected) instead
of admitted, and queued queries are re-evaluated on the control cadence once
the budget recovers — so admitted queries keep their QoS instead of everyone
collapsing together.

Fairness: drops are charged per query (the three drop points fire the
compiled app's drop hook with the event's ``query_mask``), so the
controller's view of "who is hurting" is per-query, not global; the
per-query virtual-task budgets (:meth:`QueryState.beta`) expose it.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

__all__ = ["AdmissionPolicy", "AdmissionController"]


@dataclass(frozen=True)
class AdmissionPolicy:
    """Operator knobs for the admission controller.

    ``beta_floor``: admit only while the CR-tier completion budget is at
    least this many seconds (``inf`` samples — bootstrap, or drops disabled
    — always admit: there is no evidence of load).
    ``beta_frac_of_gamma`` expresses the same floor as a fraction of the
    app's ``gamma`` and takes precedence when set.  ``max_live`` is a hard
    cap on concurrently-live queries.  ``queue_rejected`` keeps turned-away
    submissions in a FIFO retried on the control cadence; False rejects
    them outright (terminal ``cancelled``/``admission-rejected``).

    ``signal_prefix`` names the telemetry rows whose min budget is the
    health signal.  The default ``"VA"`` is *the budget toward the CR
    tier*: per §4.3.4 a task holds one completion budget per downstream, so
    the budget that collapses when CR is overloaded — lowered by the reject
    signals CR's drop points emit — is held at the VA tasks, keyed by CR
    instance.  (CR's own row tracks the UV hop, which the sink's accepts
    keep near ``gamma`` — drops upstream shield it, see
    ``DynamismTrace.budget_recovery``.)
    """

    beta_floor: float = 0.0
    beta_frac_of_gamma: Optional[float] = None
    max_live: Optional[int] = None
    queue_rejected: bool = True
    signal_prefix: str = "VA"
    #: Shed new submissions to the queue while a NetworkPartition window is
    #: open (fault plane, PR 6): a partitioned pipeline cannot honor a new
    #: query's QoS, and queued queries requeue FIFO on heal via the existing
    #: control-cadence drain.
    shed_on_partition: bool = True

    def floor(self, gamma: float) -> float:
        if self.beta_frac_of_gamma is not None:
            return self.beta_frac_of_gamma * gamma
        return self.beta_floor


class AdmissionController:
    """Decides admit/queue/reject for query submissions.

    The CR-budget signal is read from the scenario's telemetry plane: the
    last sampled ``DynamismTrace`` CR row when a trace is attached (the PR-4
    cadence, off the hot path), falling back to a live probe of the compiled
    CR tasks' budgets.  Decisions and queue occupancy are counted for the
    run report.
    """

    def __init__(self, policy: Optional[AdmissionPolicy] = None) -> None:
        self.policy = policy or AdmissionPolicy()
        self.queue: List[int] = []  # query ids awaiting admission (FIFO)
        self.decisions: Dict[str, int] = {"admit": 0, "queue": 0, "reject": 0}
        self.requeued = 0

    # ------------------------------------------------------------------ #
    def cr_beta(self, scenario) -> float:
        """The CR-tier admission budget — min over the ``signal_prefix``
        rows (default: the VA-held budgets toward the CR instances): the
        last telemetry sample when the run carries a trace (the PR-4
        cadence), else a live probe of the compiled tasks."""
        prefix = self.policy.signal_prefix
        trace = getattr(scenario, "_trace", None)
        if trace is not None and trace.times:
            series = trace.min_beta(prefix)
            if series:
                return series[-1]
        compiled = scenario.compiled
        tasks = [
            t
            for t in compiled.va_tasks + compiled.cr_tasks
            if t.name.startswith(prefix)
        ]
        return min((t.budget.min_budget() for t in tasks), default=math.inf)

    def partition_active(self, scenario) -> bool:
        """True while any ``NetworkPartition`` window of the scenario's fault
        plane contains the current sim time (duck-typed, like the dynamism
        plane's own perturbation discovery)."""
        sim = getattr(scenario, "sim", None)
        faults = getattr(sim, "faults", None)
        if faults is None:
            return False
        return faults.partition_active(sim.time)

    # ------------------------------------------------------------------ #
    def admittable(self, scenario, live_count: int) -> bool:
        """Would a query be admitted right now?  (No decision counted —
        the queue-drain retry loop polls this on the control cadence.)"""
        pol = self.policy
        if pol.max_live is not None and live_count >= pol.max_live:
            return False
        if pol.shed_on_partition and self.partition_active(scenario):
            return False
        floor = pol.floor(scenario.app.gamma)
        if floor > 0.0:
            beta = self.cr_beta(scenario)
            # inf = no evidence of load (bootstrap / drops off): admit.
            if not math.isinf(beta) and beta < floor:
                return False
        return True

    def decide(self, scenario, live_count: int) -> str:
        """``admit`` | ``queue`` | ``reject`` for one submission, given the
        current live-query count."""
        if self.admittable(scenario, live_count):
            verdict = "admit"
        else:
            verdict = "queue" if self.policy.queue_rejected else "reject"
        self.decisions[verdict] += 1
        return verdict

    def stats(self) -> Dict[str, int]:
        return {
            "adm_admitted": self.decisions["admit"] + self.requeued,
            "adm_queued": self.decisions["queue"],
            "adm_rejected": self.decisions["reject"],
            "adm_requeued": self.requeued,
            "adm_queue_left": len(self.queue),
        }
