"""MultiQueryScenario: N concurrent tracking queries through ONE pipeline.

The single-query platform activates a spotlight of cameras and routes their
frames through the shared FC -> VA -> CR -> UV dataflow.  This driver makes
*a set of concurrent queries* the served unit while keeping the pipeline
singular:

* **Union sourcing** — each tick sources one frame per camera in the
  *union* of the live queries' applied spotlights.  A camera wanted by ten
  queries costs one event, not ten: per-event cost grows with O(union
  active cameras), not O(N x cameras).
* **Query tagging** — every sourced event carries a ``query_mask`` bit per
  interested live query; the runtime's 1:1 fast paths reuse event objects,
  so the tag rides for free through VA/CR to the sink, where completions
  (and, via the compiled app's drop hook, drops at all three drop points)
  are charged **per query**.
* **Fused analytics** — with embeddings enabled, each VA batch runs ONE
  query-major ``reid_match_multi`` dispatch over all live query embeddings
  (per-pair tenancy mask), instead of one ``reid_match`` per query.  With
  ``spotlight_mode="kernel"`` the blind-spot queries' balls are computed by
  ONE multi-source ``spotlight_ball`` invocation
  (:func:`repro.core.tracking.multi_source_spotlight` — the same
  implementation backing ``TLProbabilistic.spotlight_multi``).
* **Admission control** — an optional
  :class:`~repro.query.admission.AdmissionController` queues/rejects
  submissions while the CR completion budget (sampled by the PR-4
  telemetry plane) is degraded, shedding load so admitted queries keep
  their QoS.

Bit-exactness contract (the tenancy plane's correctness anchor): with
interference disabled — admission off, and every query identical and
submitted at t=0 so the union equals each query's own spotlight — the fused
run's *per-query* summaries are **bit-identical** to N independent
single-query ``TrackingScenario`` runs, drops on or off.  ``tests/
test_query.py`` freezes this as a golden; the hypothesis suite checks the
lifecycle/accounting invariants under arbitrary submit/cancel schedules.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import (
    TYPE_CHECKING,
    Any,
    Dict,
    List,
    Optional,
    Sequence,
    Set,
    Tuple,
    Union,
)

if TYPE_CHECKING:  # pragma: no cover - typing only (avoids the jax-heavy
    # repro.serving package import at module load)
    from repro.serving.journal import Journal

import numpy as np

from repro.core.budget import TaskBudget
from repro.core.events import Event
from repro.core.pipeline import DP_FAULT
from repro.core.tracking import TLProbabilistic, TLWBFS, multi_source_spotlight
from repro.sim.scenario import ScenarioConfig, ScenarioResult, TrackingScenario

from .admission import AdmissionController, AdmissionPolicy
from .registry import QueryRegistry, QuerySpec, QueryState

__all__ = [
    "MultiQueryScenario",
    "MultiQueryResult",
    "normalize_queries",
    "run_queries_serial",
]


def normalize_queries(
    queries: Union[int, Sequence[QuerySpec]]
) -> List[QuerySpec]:
    """``N`` -> N default (identical, t=0) queries; a sequence passes
    through.  Identical default queries are the scaling benchmark's shape:
    many users tracking the same entity, deduplicated by the fused plane."""
    if isinstance(queries, int):
        if queries < 1:
            raise ValueError(f"need at least one query, got {queries}")
        return [QuerySpec() for _ in range(queries)]
    out = list(queries)
    if not out:
        raise ValueError("need at least one query")
    for q in out:
        if not isinstance(q, QuerySpec):
            raise TypeError(f"expected QuerySpec, got {type(q).__name__}")
    return out


def _zero_xi(b: int) -> float:
    return 0.0


@dataclass
class MultiQueryResult:
    """Fused-run outputs: the global (shared-pipeline) result plus the
    per-query views and the registry/admission state."""

    result: ScenarioResult
    per_query: Dict[int, ScenarioResult]
    registry: QueryRegistry
    admission: Optional[AdmissionController] = None
    states: Dict[int, str] = field(default_factory=dict)

    def per_query_summary(self, qid: int) -> Dict[str, float]:
        """Summary of one query's view — with interference disabled this is
        bit-identical to the query's solo ``TrackingScenario`` summary."""
        return self.per_query[qid].summary()

    def summary(self) -> Dict[str, Any]:
        reg = self.registry
        out = dict(self.result.summary())
        out["queries"] = len(self.per_query)
        out["queries_live_end"] = reg.live_count()
        out["queries_found"] = sum(
            1 for s in reg.states.values() if s.found_at is not None
        )
        # The global timeline is the union spotlight: its peak/mean are the
        # tenancy plane's cost metric (vs sum of per-query actives).
        sizes = [c for _, c in self.result.active_timeline]
        out["union_peak_active"] = self.result.peak_active
        out["union_mean_active"] = (
            round(float(np.mean(sizes)), 2) if sizes else 0.0
        )
        per_q_sourced = sum(s.sourced for s in reg.states.values())
        out["per_query_sourced_sum"] = per_q_sourced
        if self.admission is not None:
            out.update(self.admission.stats())
            out["adm_submitted"] = reg.submitted
        return out


class MultiQueryScenario(TrackingScenario):
    """Drive N concurrent queries through one compiled app.

    ``queries`` is an int (N identical default queries) or a sequence of
    :class:`QuerySpec`.  ``admission`` is an
    :class:`~repro.query.admission.AdmissionPolicy` /
    :class:`~repro.query.admission.AdmissionController` (None admits
    everything).  ``spotlight_mode`` is ``"per-query"`` (each query's own
    TL strategy instance, the bit-exactness reference) or ``"kernel"``
    (blind-spot balls batched into one multi-source ``spotlight_ball``
    dispatch; weighted-ball TLs only, bit-equal for TLWBFS).
    """

    def __init__(
        self,
        config: ScenarioConfig,
        queries: Union[int, Sequence[QuerySpec]],
        *,
        admission: Union[AdmissionPolicy, AdmissionController, None] = None,
        spotlight_mode: str = "per-query",
        app: Any = None,
        deployment: Any = None,
        journal: Optional["Journal"] = None,
        mesh: Any = None,
    ) -> None:
        if spotlight_mode not in ("per-query", "kernel"):
            raise ValueError(f"unknown spotlight_mode {spotlight_mode!r}")
        self._spotlight_mode = spotlight_mode
        #: Optional ``distributed.MeshRules`` handle (see
        #: ``distributed.camera_mesh``): with ``engine="megastep"`` the
        #: device backend shards the camera-block world over the mesh's
        #: ``cameras`` axis (``kernels.megastep.sharded``), bit-identically
        #: to the single-shard scan.  The registry itself stays replicated —
        #: every shard sees all query tag bits/tables — and the per-query
        #: budget counters come back all-reduced on the chunk cadence.
        self.mesh_rules = mesh
        #: Optional append-only journal + snapshot ring
        #: (:class:`repro.serving.journal.Journal`): the accounting hooks
        #: record the observable event stream, and a periodic tick appends
        #: frontier snapshots for crash recovery.  None costs one attribute
        #: test per hook invocation.
        self.journal = journal
        self.registry = QueryRegistry()
        if isinstance(admission, AdmissionPolicy):
            admission = AdmissionController(admission)
        self.admission: Optional[AdmissionController] = admission
        self._started = False
        self._specs = normalize_queries(queries)

        super().__init__(config, app=app, deployment=deployment)

        # Undo the single-query seeding the parent applied from the app's
        # template TL: the union mirrors start empty and are rebuilt from
        # the t=0 submissions below.
        self.compiled.fc_active.clear()
        self._ctrl_target = set()
        self._mask_of = {}
        self._source_hook = self._on_sourced
        self._pending_masks: List[int] = []
        self.compiled.install_drop_hook(self._on_pipeline_drop)

        t_q = time.perf_counter()
        for spec in self._specs:
            st = self.registry.register(spec, now=max(spec.submit_at, 0.0))
            if spec.cancel_at is not None:
                self.sim.schedule_at(
                    spec.cancel_at, self._cancel_query, st.query_id, "cancelled"
                )
            if spec.ttl_s is not None:
                self.sim.schedule_at(
                    max(spec.submit_at, 0.0) + spec.ttl_s,
                    self._expire_query,
                    st.query_id,
                )
            if spec.submit_at <= 0.0:
                self._submit_query(st.query_id)
            else:
                self.sim.schedule_at(spec.submit_at, self._submit_query, st.query_id)
        self.build_seconds += time.perf_counter() - t_q

    # ------------------------------------------------------------------ #
    # Lifecycle: submit -> scoped -> found -> expired/cancelled           #
    # ------------------------------------------------------------------ #
    def _submit_query(self, qid: int) -> None:
        st = self.registry.get(qid)
        if st.dead or st.live:
            return  # cancelled while pending, or double submission
        ctrl = self.admission
        if ctrl is not None:
            verdict = ctrl.decide(self, self.registry.live_count())
            if verdict == "queue":
                ctrl.queue.append(qid)
                self.registry.queued_peak = max(
                    self.registry.queued_peak, len(ctrl.queue)
                )
                return
            if verdict == "reject":
                self.registry.rejected += 1
                self.registry.mark(
                    st, "cancelled", self.sim.time, reason="admission-rejected"
                )
                return
        self.registry.admitted += 1
        self._activate_query(st, immediate=not self._started)

    def _activate_query(self, st: QueryState, immediate: bool) -> None:
        spec, cfg = st.spec, self.cfg
        now = self.sim.time
        if spec.make_tl is not None:
            tl = spec.make_tl(self.world, self.cameras)
        else:
            tl = spec.solo_config(cfg).make_tl(
                self.world.road, self.cameras.camera_vertices
            )
        if spec.coverage is not None and hasattr(tl, "coverage"):
            tl.coverage = float(spec.coverage)
        if self._spotlight_mode == "kernel" and not isinstance(
            tl, (TLWBFS, TLProbabilistic)
        ):
            raise ValueError(
                "spotlight_mode='kernel' needs weighted-ball TLs "
                f"(TLWBFS/TLProbabilistic); query {st.query_id} uses "
                f"{type(tl).__name__}"
            )
        if tl.last_seen_camera is None:
            # Same seeding rule as the single-query scenario: the nearest
            # camera to the entity's position (at t=0 that is the walk's
            # start vertex — byte-for-byte the solo `_seed_tl`).
            if spec.last_seen_camera is not None:
                tl.last_seen_camera = spec.last_seen_camera
            else:
                cams = self.cameras.camera_vertices
                cam_ids = list(cams)
                cam_pos = self.road.positions[
                    np.fromiter(cams.values(), dtype=np.int64)
                ]
                if now <= 0.0:
                    pos = self.road.positions[self.walk.vertices[0]]
                else:
                    pos = self.walk.position(now)
                d = np.linalg.norm(cam_pos - pos, axis=1)
                tl.last_seen_camera = cam_ids[int(np.argmin(d))]
            tl.last_seen_time = now
            tl.active = tl.spotlight(now)
        st.tl = tl
        st.budget = TaskBudget(f"Q{st.query_id}", _zero_xi, m_max=1)
        if cfg.embed_dim:
            if spec.embedding_seed is None:
                st.embedding = self.cameras.entity_embedding
            else:
                rng = np.random.default_rng(spec.embedding_seed)
                st.embedding = rng.normal(size=(cfg.embed_dim,)).astype(np.float32)
        self.registry.mark(st, "scoped", now)
        st.requested = set(tl.active)
        if immediate:
            # Pre-run activation: applied instantly, exactly like the solo
            # scenario's initial active set (no control latency at t=0).
            for cam in st.requested:
                self._apply_query_active(st.query_id, cam, True)
            self.compiled.fc_active |= st.requested
            self._ctrl_target |= st.requested
        else:
            lat = self.sim.network.man_latency_s
            sched = self.sim.schedule
            for cam in sorted(st.requested):
                sched(lat, self._apply_query_active, st.query_id, cam, True)
            set_active = self.compiled.set_fc_active
            for cam in sorted(st.requested - self._ctrl_target):
                sched(lat, set_active, cam, True)
            self._ctrl_target |= st.requested

    def cancel(self, qid: int, reason: str = "cancelled") -> None:
        """Cancel a query now (or schedule via ``QuerySpec.cancel_at``)."""
        self._cancel_query(qid, reason)

    def _cancel_query(self, qid: int, reason: str = "cancelled") -> None:
        st = self.registry.get(qid)
        if st.dead:
            return
        ctrl = self.admission
        if ctrl is not None and qid in ctrl.queue:
            ctrl.queue.remove(qid)
        was_live = st.live
        self.registry.mark(st, "cancelled", self.sim.time, reason=reason)
        if was_live:
            self._end_query_control(st)

    def _expire_query(self, qid: int) -> None:
        st = self.registry.get(qid)
        if st.dead or st.state == "found":
            return  # found queries keep tracking; ttl only bounds the search
        ctrl = self.admission
        if ctrl is not None and qid in ctrl.queue:
            ctrl.queue.remove(qid)
        was_live = st.live
        self.registry.mark(st, "expired", self.sim.time, reason="ttl")
        if was_live:
            self._end_query_control(st)

    def _end_query_control(self, st: QueryState) -> None:
        """Release a dead query's cameras: its applied set drains after one
        control latency; union cameras no other live query wants go dark."""
        lat = self.sim.network.man_latency_s
        sched = self.sim.schedule
        for cam in sorted(st.requested):
            sched(lat, self._apply_query_active, st.query_id, cam, False)
        st.requested = set()
        union: Set[int] = set()
        for s in self.registry.live_states():
            union |= s.requested
        set_active = self.compiled.set_fc_active
        for cam in sorted(self._ctrl_target - union):
            sched(lat, set_active, cam, False)
        self._ctrl_target = union

    # ------------------------------------------------------------------ #
    # Control application: per-query mirrors + the event tag map          #
    # ------------------------------------------------------------------ #
    def _apply_query_active(self, qid: int, cam: int, want: bool) -> None:
        st = self.registry.states.get(qid)
        if st is None:
            return
        mask_of = self._mask_of
        if want:
            if st.dead:
                return  # in-flight activation outlived its query
            st.applied.add(cam)
            mask_of[cam] = mask_of.get(cam, 0) | st.bit
        else:
            st.applied.discard(cam)
            mask_of[cam] = mask_of.get(cam, 0) & ~st.bit

    # ------------------------------------------------------------------ #
    # TL plane: per-query spotlights, one union control delta             #
    # ------------------------------------------------------------------ #
    def _tl_tick(self) -> None:  # overrides TrackingScenario
        now = self.sim.time
        dets = self._pending_detections
        masks = self._pending_masks
        self._pending_detections = []
        self._pending_masks = []
        live = self.registry.live_states()
        targets = self._query_targets(live, dets, masks, now)
        lat = self.sim.network.man_latency_s
        sched = self.sim.schedule
        union: Set[int] = set()
        for st, new_active in zip(live, targets):
            st.active_timeline.append((now, len(new_active)))
            prev = st.requested
            for cam in new_active - prev:
                sched(lat, self._apply_query_active, st.query_id, cam, True)
            for cam in prev - new_active:
                sched(lat, self._apply_query_active, st.query_id, cam, False)
            st.requested = new_active
            union |= new_active
        self._stats_active.append((now, len(union)))
        prev = self._ctrl_target
        set_active = self.compiled.set_fc_active
        for cam in union - prev:
            sched(lat, set_active, cam, True)
        for cam in prev - union:
            sched(lat, set_active, cam, False)
        self._ctrl_target = union
        self._drain_admission_queue()
        if now + self.cfg.tl_update_period <= self.cfg.duration_s:
            self.sim.schedule(self.cfg.tl_update_period, self._tl_tick)

    def _query_targets(
        self, live: List[QueryState], dets, masks, now: float
    ) -> List[Set[int]]:
        if self._spotlight_mode != "kernel":
            # Reference path: each query's own TL strategy, the exact solo
            # code path (what the bit-exactness harness freezes).
            return [
                st.tl.update(
                    [d for d, m in zip(dets, masks) if m & st.bit], now
                )
                for st in live
            ]
        # Fused path: contraction handled inline; every blind-spot ball is
        # computed by ONE multi-source spotlight_ball dispatch (grouped by
        # coverage so TLWBFS and TLProbabilistic queries can mix).
        targets: List[Optional[Set[int]]] = [None] * len(live)
        groups: Dict[Optional[float], List[Tuple[int, int, float]]] = {}
        for i, st in enumerate(live):
            tl = st.tl
            bit = st.bit
            positives = [
                d for d, m in zip(dets, masks) if (m & bit) and d.positive
            ]
            if positives:
                latest = max(positives, key=lambda d: d.timestamp)
                tl.last_seen_camera = latest.camera_id
                tl.last_seen_time = latest.timestamp
                tl.active = {latest.camera_id}
                targets[i] = set(tl.active)
                continue
            src = (
                tl.camera_vertices.get(tl.last_seen_camera)
                if tl.last_seen_camera is not None
                else None
            )
            radius = tl._radius_m(now)
            if src is None or math.isinf(radius):
                tl.active = set(tl.camera_vertices)
                targets[i] = set(tl.active)
                continue
            coverage = tl.coverage if isinstance(tl, TLProbabilistic) else None
            groups.setdefault(coverage, []).append((i, src, radius))
        for coverage, entries in groups.items():
            per_source = multi_source_spotlight(
                self.road,
                self.cameras.camera_vertices,
                [src for _, src, _ in entries],
                [rad for _, _, rad in entries],
                coverage=coverage,
            )
            for (i, _, _), cams in zip(entries, per_source):
                live[i].tl.active = set(cams)
                targets[i] = cams
        return targets  # type: ignore[return-value]

    def _drain_admission_queue(self) -> None:
        ctrl = self.admission
        if ctrl is None or not ctrl.queue:
            return
        reg = self.registry
        while ctrl.queue:
            qid = ctrl.queue[0]
            st = reg.get(qid)
            if st.dead:
                ctrl.queue.pop(0)
                continue
            if not ctrl.admittable(self, reg.live_count()):
                break  # FIFO head blocked: budget still degraded / cap hit
            ctrl.queue.pop(0)
            ctrl.requeued += 1
            reg.admitted += 1
            self._activate_query(st, immediate=False)

    # ------------------------------------------------------------------ #
    # Per-query accounting hooks                                          #
    # ------------------------------------------------------------------ #
    def _on_sourced(self, frames, t: float) -> None:
        if self.journal is not None:
            self.journal.append("source", t, len(frames))
        mask_of = self._mask_of
        for_mask = self.registry.for_mask
        # Aggregate per distinct mask first: N identical queries share one
        # mask value, so the charge loop runs once per mask per tick, not
        # once per (frame, query).
        counts: Dict[int, int] = {}
        for f in frames:
            m = mask_of.get(f.camera_id, 0)
            counts[m] = counts.get(m, 0) + 1
            if f.has_entity:
                for st in for_mask(m):
                    st.positives_generated += 1
        for m, c in counts.items():
            for st in for_mask(m):
                st.sourced += c

    def _on_sink_event(self, ev: Event, now: float) -> None:
        mask = ev.query_mask
        super()._on_sink_event(ev, now)
        self._pending_masks.append(mask)
        det = self._pending_detections[-1]
        if self.journal is not None:
            self.journal.append("sink", now, mask, 1.0 if det.positive else 0.0)
        h = ev.header
        u = now - h.source_arrival
        gamma = self.app.gamma
        eps_max = self.deployment.epsilon_max
        positive = det.positive
        on_time = u <= gamma
        for st in self.registry.for_mask(mask):
            if st.live:
                st.completed += 1
                st.latencies.append((now, u))
                if on_time:
                    st.on_time += 1
                else:
                    st.delayed += 1
                if positive:
                    st.positives_completed += 1
                    if on_time:
                        st.detections_on_time += 1
                    if self._quality_on:
                        st.sink_positive_pairs.append(
                            (det.camera_id, det.timestamp)
                        )
                    if st.state == "scoped":
                        self.registry.mark(st, "found", now)
                st.record_completion(
                    h.event_id, u, h.q_bar, h.xi_bar, gamma, eps_max
                )
            else:
                # In flight when its query ended: never *executed for* the
                # dead query — orphan-accounted so the books still balance.
                st.orphan_completed += 1

    def _on_pipeline_drop(self, ev: Event, point: int, epsilon: float) -> None:
        mask = ev.query_mask
        if self.journal is not None:
            self.journal.append("drop", self.sim.time, point, mask)
        if not mask:
            return
        h = ev.header
        u = self.sim.time - h.source_arrival
        for st in self.registry.for_mask(mask):
            if st.live:
                st.dropped += 1
                st.dp[point] += 1
                if point != DP_FAULT:
                    # A fault loss is not a §4.3 deadline reject: it carries
                    # no information about the query's budget, so it must not
                    # drive the per-query beta down.
                    st.record_drop(h.event_id, u, h.q_bar, h.xi_bar, epsilon)
            else:
                st.orphan_dropped += 1

    # ------------------------------------------------------------------ #
    # Fused cross-query re-ID (overrides the single-query VA batch hook)  #
    # ------------------------------------------------------------------ #
    def _va_reid(self, events: List[Event], state: Dict) -> None:
        from repro.kernels import dispatch

        block, block_states = self.registry.embedding_block()
        if not block_states:
            return
        embs = [getattr(ev.value, "embedding", None) for ev in events]
        idx = [i for i, e in enumerate(embs) if e is not None]
        if not idx:
            return
        gallery = np.stack([embs[i] for i in idx])
        nq = len(block_states)
        mask = np.zeros((len(idx), nq), dtype=bool)
        for row, i in enumerate(idx):
            m = events[i].query_mask
            for col, st in enumerate(block_states):
                if m & st.bit:
                    mask[row, col] = True
        _, matched = dispatch.reid_match_multi(
            gallery, block, mask=mask, threshold=self.cfg.reid_threshold
        )
        matched = np.asarray(matched)
        avoid = self.deployment.avoid_drop_positives
        for row, i in enumerate(idx):
            hit = False
            for col, st in enumerate(block_states):
                if matched[row, col]:
                    st.reid_matched += 1
                    hit = True
            if hit:
                self._reid_matched += 1
                if avoid:
                    events[i].header.avoid_drop = True

    # ------------------------------------------------------------------ #
    # Telemetry + quality: per-query keyed rows                           #
    # ------------------------------------------------------------------ #
    def _sample_telemetry_now(self) -> None:
        super()._sample_telemetry_now()
        trace = self._trace
        for qid, st in sorted(self.registry.states.items()):
            trace.sample_keyed(f"Q:{qid}", st.telemetry_row())

    def _per_query_quality(self, st: QueryState) -> Dict[str, float]:
        """Track recall/precision over the query's live window — the same
        (camera, tick) ground-truth pairs as the global report, restricted
        to [scoped_at, ended_at]."""
        w0 = st.scoped_at if st.scoped_at is not None else math.inf
        w1 = st.ended_at if st.ended_at is not None else math.inf
        truth = {(c, t) for (c, t) in self._truth_pairs if w0 <= t <= w1}
        detected = set(st.sink_positive_pairs)
        tp = len(detected & truth)
        return {
            "truth_events": len(truth),
            "track_recall": round(tp / len(truth), 4) if truth else 1.0,
            "track_precision": round(tp / len(detected), 4) if detected else 1.0,
        }

    # ------------------------------------------------------------------ #
    # Durability: journal ticks + snapshot/restore (repro.serving.journal) #
    # ------------------------------------------------------------------ #
    _STATE_INDEX = ("submitted", "scoped", "found", "cancelled", "expired")

    def _schedule_ticks(self) -> None:  # overrides TrackingScenario
        if self._ticks_scheduled:
            return
        super()._schedule_ticks()
        j = self.journal
        if j is not None and j.snapshot_period_s > 0:
            # First snapshot one period in (t=0 state is the constructor's).
            self.sim.schedule(j.snapshot_period_s, self._journal_tick)

    def _journal_tick(self) -> None:
        j = self.journal
        j.snapshots.append(self.snapshot())
        if self.sim.time + j.snapshot_period_s <= self._horizon:
            self.sim.schedule(j.snapshot_period_s, self._journal_tick)

    def run_until(self, t: float) -> None:  # overrides TrackingScenario
        # Mark started *before* events fire so mid-run submissions take the
        # control-latency path, exactly as in an uninterrupted run().
        self._started = True
        super().run_until(t)

    def snapshot(self) -> Dict[str, float]:
        """The serving frontier as a flat ``str -> float`` dict: global
        counters, the compiled pipeline's per-task counters/budgets, every
        query's registry ledger, and the admission queue.  Bit-comparable
        between a replayed and an uninterrupted run (and npz-persistable via
        :mod:`repro.training.checkpoint`)."""
        snap: Dict[str, float] = {
            "time": float(self.sim.time),
            "source_events": float(self._source_events),
            "positives_generated": float(self._positives_generated),
            "positives_completed": float(self._positives_completed),
            "reid_matched": float(self._reid_matched),
        }
        snap.update(self.compiled.snapshot())
        for qid, st in sorted(self.registry.states.items()):
            p = f"q{qid}"
            try:
                state_ix = self._STATE_INDEX.index(st.state)
            except ValueError:
                state_ix = -1
            snap[f"{p}::state"] = float(state_ix)
            for k in (
                "sourced",
                "completed",
                "dropped",
                "on_time",
                "delayed",
                "orphan_completed",
                "orphan_dropped",
                "positives_generated",
                "positives_completed",
                "detections_on_time",
                "reid_matched",
                "accepts",
                "rejects",
            ):
                snap[f"{p}::{k}"] = float(getattr(st, k))
            for i in (1, 2, 3, 4):
                snap[f"{p}::dp{i}"] = float(st.dp[i])
            snap[f"{p}::beta"] = float(st.beta())
        ctrl = self.admission
        if ctrl is not None:
            snap["adm::queue_len"] = float(len(ctrl.queue))
            snap["adm::requeued"] = float(ctrl.requeued)
            for k, v in ctrl.decisions.items():
                snap[f"adm::{k}"] = float(v)
        return snap

    def restore(self, source: Any) -> "MultiQueryScenario":
        """Recover a crashed driver: replay this (freshly built) scenario to
        the snapshot's timestamp and verify the reconstructed frontier is
        bit-identical to it.

        ``source`` is a snapshot dict or a :class:`~repro.serving.journal.
        Journal` (its last snapshot is used).  The simulation is
        deterministic in (config, spec, seed), so replaying the same inputs
        reconstructs the exact pre-crash state; the bit-compare is the gate
        that proves it (``RestoreMismatch`` lists every differing key).
        After restore, ``run()`` continues to the horizon and the final
        per-query summaries equal an uninterrupted run's exactly."""
        from repro.serving.journal import RestoreMismatch, diff_snapshots

        snap = source.last_snapshot() if hasattr(source, "last_snapshot") else source
        if self.sim.time > 0.0:
            raise RuntimeError(
                "restore() replays from t=0 and needs a freshly built "
                f"scenario; this one already ran to t={self.sim.time}"
            )
        self.run_until(snap["time"])
        if self.journal is not None and self.journal.snapshots:
            # Aligned compare: the replay's own journal tick fires at the
            # *identical position in the event order* as the original's
            # (same seeds, same schedule seqs), so its latest snapshot is
            # the exact frontier the stored one captured — even when other
            # events share the snapshot's timestamp.
            mine = self.journal.snapshots[-1]
        else:
            # No journal on the replay: compare the end-of-timestamp
            # frontier (exact only when the snapshot time falls between
            # event timestamps — prefer restoring with a journal).
            mine = self.snapshot()
        diff = diff_snapshots(snap, mine)
        if diff:
            raise RestoreMismatch(
                "replayed state does not match snapshot:\n  " + "\n  ".join(diff)
            )
        return self

    # ------------------------------------------------------------------ #
    def run(self) -> MultiQueryResult:  # type: ignore[override]
        self._started = True
        self.engine_used = "interpreted"
        self.engine_fallback_reason = "engine=interpreted"
        self.engine_xfer_s = 0.0  # device->host pull wall (device backend)
        self.shards_used = 1  # mesh shards the scan actually ran on
        # Sharding totality (GRF005 extended): "" means the sharded scan
        # ran; anything else says why it didn't — never silent.  The
        # sharded path overwrites this once it decides.
        self.shard_fallback_reason = (
            "mesh-unused" if self.mesh_rules is not None else "no-mesh"
        )
        self.collective_bytes_per_tick = 0.0
        if getattr(self.cfg, "engine", "interpreted") == "megastep":
            from repro.core.megastep import try_run_megastep

            fused = try_run_megastep(self)
            if fused is not None:
                return fused
            # None: either ineligible (interpreted fallback) or the drops-on
            # backend primed its tick chain — both continue below.
        base = super().run()
        per_query: Dict[int, ScenarioResult] = {}
        for qid, st in sorted(self.registry.states.items()):
            quality = self._per_query_quality(st) if self._quality_on else None
            per_query[qid] = ScenarioResult(
                config=self.cfg,
                active_timeline=list(st.active_timeline),
                latencies=list(st.latencies),
                on_time=st.on_time,
                delayed=st.delayed,
                source_events=st.sourced,
                dropped=st.dropped,
                drops_by_task={
                    **{f"dp{i}": st.dp[i] for i in (1, 2, 3) if st.dp[i]},
                    **({"dp_fault": st.dp[4]} if st.dp[4] else {}),
                },
                batch_sizes={},
                positives_generated=st.positives_generated,
                positives_completed=st.positives_completed,
                positives_dropped=st.positives_generated - st.positives_completed,
                detections_on_time=st.detections_on_time,
                reid_matched=st.reid_matched,
                query_pushes=0,
                trace=None,
                quality=quality,
            )
        return MultiQueryResult(
            result=base,
            per_query=per_query,
            registry=self.registry,
            admission=self.admission,
            states={qid: st.state for qid, st in sorted(self.registry.states.items())},
        )

    def publish_metrics(  # type: ignore[override]
        self, registry, res: MultiQueryResult
    ) -> None:
        """Publish global + per-query telemetry into an obs-plane registry.

        Thin delegation to :func:`repro.obs.collect_query_result` (lazy
        import so the query layer never depends on the obs package at
        module load).
        """
        from repro.obs import collect_query_result

        collect_query_result(registry, self, res)


# --------------------------------------------------------------------- #
# Per-query-serial baseline                                              #
# --------------------------------------------------------------------- #
def _solo_scenario(config: ScenarioConfig, spec: QuerySpec) -> TrackingScenario:
    """One independent single-query scenario equivalent to ``spec`` —
    including the overrides ``ScenarioConfig`` cannot express (``coverage``,
    ``last_seen_camera`` warm start, ``make_tl``), which are applied by
    building the preset app's TL exactly the way ``_activate_query`` does."""
    cfg = spec.solo_config(config)
    if (
        spec.coverage is None
        and spec.last_seen_camera is None
        and spec.make_tl is None
    ):
        return TrackingScenario(cfg)

    def app_factory(world, cameras):
        from dataclasses import replace

        app = cfg.to_app(world, cameras)
        if spec.make_tl is not None:
            tl = spec.make_tl(world, cameras)
        else:
            tl = cfg.make_tl(world.road, cameras.camera_vertices)
        if spec.coverage is not None and hasattr(tl, "coverage"):
            tl.coverage = float(spec.coverage)
        if spec.last_seen_camera is not None:
            tl.last_seen_camera = spec.last_seen_camera
            tl.last_seen_time = 0.0
            tl.active = tl.spotlight(0.0)
        return replace(app, tl=tl)

    return TrackingScenario(cfg, app=app_factory)


def run_queries_serial(
    config: ScenarioConfig, queries: Union[int, Sequence[QuerySpec]]
) -> Tuple[List[ScenarioResult], float]:
    """The baseline the fused plane is measured (and bit-compared) against:
    one independent single-query ``TrackingScenario`` per spec, run
    sequentially (worlds shared through the process-wide warm cache).
    ``submit_at``/``cancel_at``/``ttl_s`` have no solo equivalent — each
    baseline runs its query for the whole horizon.  Returns the per-query
    results and the total wall time."""
    specs = normalize_queries(queries)
    t0 = time.perf_counter()
    results = [_solo_scenario(config, spec).run() for spec in specs]
    return results, time.perf_counter() - t0
