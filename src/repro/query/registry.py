"""Query registry: per-query state for the multi-query tenancy plane.

A *query* is the unit the platform serves: "track this entity" submitted by
one user over the shared camera network.  The registry owns every query's
state — its entity embedding, its TL spotlight strategy instance, its
per-query completion :class:`~repro.core.budget.TaskBudget`, its lifecycle —
and the counters that make per-query accounting reconcile exactly with the
shared pipeline's global :class:`~repro.sim.scenario.ScenarioResult`:

* lifecycle: ``submitted -> scoped -> found`` and the terminal states
  ``expired`` / ``cancelled`` (admission rejects are ``cancelled`` with
  ``reason='admission-rejected'``).  ``found`` is sticky: a query that has
  seen its entity keeps tracking it.
* tagging: each live query holds a unique ``bit``; a sourced event's
  ``query_mask`` is the OR of the bits of every live query whose *applied*
  spotlight contains the camera at source time.  Bits are never reused, so
  an in-flight event of a dead query can never be mis-attributed to a newer
  one.
* counters: ``sourced`` (events tagged at the source), ``completed`` /
  ``dropped`` (attributed while the query was live), and the orphan pair
  (events completing/dropping *after* the query ended — they were in flight
  at cancellation; no event is ever *executed for* a dead query, see the
  property tests).  After the drain window,
  ``sourced == completed + dropped + orphan_completed + orphan_dropped``.
* per-query budget: the query is treated as a virtual pipeline task whose
  event record is the end-to-end trip — completions record
  ``<u, q_bar, 1, xi_bar>`` and raise the budget via accept signals when
  early; drops charged to the query lower it via reject signals.  The
  resulting per-query ``beta`` feeds the admission controller's fairness
  view and the per-query telemetry row (``DynamismTrace`` key ``Q:<id>``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.budget import TaskBudget
from repro.core.events import AcceptSignal, EventRecord, RejectSignal

__all__ = ["QUERY_STATES", "QuerySpec", "QueryState", "QueryRegistry"]

#: Lifecycle states.  ``submitted`` covers both "just arrived" and "queued
#: by admission"; ``scoped`` means the TL spotlight is live.
QUERY_STATES = ("submitted", "scoped", "found", "expired", "cancelled")
_DEAD = ("expired", "cancelled")


@dataclass
class QuerySpec:
    """One tracking query as submitted by a user.

    ``tl`` / ``tl_peak_speed`` / ``coverage`` override the workload config's
    TL knobs for this query only (None inherits).  ``submit_at`` /
    ``cancel_at`` schedule the lifecycle mid-run; ``ttl_s`` expires a query
    that has not reached ``found`` within the window.  ``embedding_seed``
    draws a distinct entity embedding for the fused re-ID plane (None uses
    the world's true entity embedding, the single-query behavior);
    ``last_seen_camera`` warm-starts the spotlight (None seeds from the
    entity walk exactly like a single-query scenario).
    """

    query_id: Optional[int] = None
    tl: Optional[str] = None
    tl_peak_speed: Optional[float] = None
    coverage: Optional[float] = None
    submit_at: float = 0.0
    cancel_at: Optional[float] = None
    ttl_s: Optional[float] = None
    embedding_seed: Optional[int] = None
    last_seen_camera: Optional[int] = None
    # Escape hatch for custom apps: ``(world, cameras) -> TrackingLogic``.
    make_tl: Optional[Callable[..., Any]] = None

    def solo_config(self, base):
        """The single-query ``ScenarioConfig`` this query corresponds to —
        the per-query-serial baseline (and the bit-exactness oracle) runs
        one ``TrackingScenario`` per spec over these."""
        from dataclasses import replace

        kw: Dict[str, Any] = {}
        if self.tl is not None:
            kw["tl"] = self.tl
        if self.tl_peak_speed is not None:
            kw["tl_peak_speed"] = self.tl_peak_speed
        return replace(base, **kw) if kw else base


@dataclass
class QueryState:
    """Registry-owned mutable state of one query."""

    spec: QuerySpec
    query_id: int
    bit: int  # unique tag bit: event.query_mask & bit <=> tagged for us
    state: str = "submitted"
    reason: str = ""
    tl: Any = None  # TrackingLogic, built at activation
    budget: Optional[TaskBudget] = None
    embedding: Optional[np.ndarray] = None
    # Control-plane mirrors (same split as the scenario's union mirrors):
    # ``requested`` is the last TL-requested set; ``applied`` what the
    # control events have delivered so far (one control latency behind).
    requested: Set[int] = field(default_factory=set)
    applied: Set[int] = field(default_factory=set)
    # Counters (see module docstring for the reconciliation contract).
    sourced: int = 0
    positives_generated: int = 0
    completed: int = 0
    positives_completed: int = 0
    detections_on_time: int = 0
    on_time: int = 0
    delayed: int = 0
    dropped: int = 0
    # [_, dp1..3, dp_fault] — slot 4 counts fault losses (crash/partition,
    # repro.core.pipeline.DP_FAULT); telemetry_row exposes dp1..3 only so the
    # trace digest stays stable across fault-free runs.
    dp: List[int] = field(default_factory=lambda: [0, 0, 0, 0, 0])
    orphan_completed: int = 0
    orphan_dropped: int = 0
    reid_matched: int = 0
    accepts: int = 0
    rejects: int = 0
    latencies: List[Tuple[float, float]] = field(default_factory=list)
    active_timeline: List[Tuple[float, int]] = field(default_factory=list)
    sink_positive_pairs: List[Tuple[int, float]] = field(default_factory=list)
    submitted_at: float = 0.0
    scoped_at: Optional[float] = None
    found_at: Optional[float] = None
    ended_at: Optional[float] = None

    @property
    def live(self) -> bool:
        return self.state in ("scoped", "found")

    @property
    def dead(self) -> bool:
        return self.state in _DEAD

    @property
    def in_flight(self) -> int:
        return self.sourced - (
            self.completed + self.dropped + self.orphan_completed + self.orphan_dropped
        )

    # -- per-query virtual-task budget ---------------------------------- #
    # The query is a virtual task with xi == 0 and m_max == 1, for which the
    # paper's update formulas reduce exactly: an accept's lam and a reject's
    # lam are both 0, so an accept sets beta = max(beta, u) and a reject
    # beta = min(beta, u).  The hot-path guard below skips the TaskBudget
    # record/signal machinery whenever the update provably would not move
    # the budget — the resulting trajectory is identical, at one cached
    # min_budget() read per event instead of an allocation per event.
    def record_completion(
        self, event_id: int, u: float, q_bar: float, xi_bar: float, gamma: float,
        epsilon_max: float,
    ) -> None:
        b = self.budget
        if b is None:
            return
        epsilon = gamma - u
        cur = b.min_budget()
        if not math.isinf(cur) and (epsilon <= epsilon_max or u <= cur):
            return  # no accept would fire, or it could not raise the budget
        b.record(event_id, EventRecord(departure=u, queuing=q_bar, batch_size=1, xi=xi_bar))
        if epsilon > epsilon_max:
            self.accepts += 1
            b.on_accept(AcceptSignal(event_id, epsilon, xi_bar))

    def record_drop(
        self, event_id: int, u: float, q_bar: float, xi_bar: float, epsilon: float
    ) -> None:
        b = self.budget
        if b is None:
            return
        self.rejects += 1
        cur = b.min_budget()
        if not math.isinf(cur) and u >= cur:
            return  # reject could not lower the budget further
        # A drop is this virtual task's own "departure": record the trip so
        # far, then apply the reject (bootstrap-initializes on first drop).
        b.record(event_id, EventRecord(departure=u, queuing=q_bar, batch_size=1, xi=xi_bar))
        b.on_reject(RejectSignal(event_id, max(epsilon, 0.0), q_bar))

    def beta(self) -> float:
        return self.budget.min_budget() if self.budget is not None else math.inf

    def telemetry_row(self) -> Dict[str, float]:
        """One ``TRACE_FIELDS``-shaped sample (the ``Q:<id>`` trace row)."""
        return {
            "beta": self.beta(),
            "queue": self.in_flight,
            "dp1": self.dp[1],
            "dp2": self.dp[2],
            "dp3": self.dp[3],
            "probes": 0.0,
            "accepts": self.accepts,
            "rejects": self.rejects,
            "batches": 0.0,
            "executed": self.completed,
        }


class QueryRegistry:
    """Owns every query of a multi-query run, live or dead."""

    def __init__(self) -> None:
        self.states: Dict[int, QueryState] = {}
        self._by_bit_index: Dict[int, QueryState] = {}
        self._next_bit = 0
        self._next_auto_id = 0
        # Admission bookkeeping (filled by the driver/controller).
        self.submitted = 0
        self.admitted = 0
        self.rejected = 0
        self.queued_peak = 0
        self._live_cache: Optional[List[QueryState]] = None
        # mask -> states cache: a bit is never reassigned, so the state
        # tuple for a given mask value is immutable for the registry's
        # lifetime (liveness is the caller's concern).
        self._mask_cache: Dict[int, Tuple[QueryState, ...]] = {}
        self._emb_cache: Optional[Tuple[np.ndarray, List[QueryState]]] = None

    # ------------------------------------------------------------------ #
    def register(self, spec: QuerySpec, now: float = 0.0) -> QueryState:
        qid = spec.query_id
        if qid is None:
            qid = self._next_auto_id
        if qid in self.states:
            raise ValueError(f"query id {qid} already registered")
        self._next_auto_id = max(self._next_auto_id, qid + 1)
        bit_index = self._next_bit
        self._next_bit += 1
        st = QueryState(spec=spec, query_id=qid, bit=1 << bit_index)
        st.submitted_at = now
        self.states[qid] = st
        self._by_bit_index[bit_index] = st
        self.submitted += 1
        self._live_cache = None
        self._emb_cache = None
        return st

    def get(self, qid: int) -> QueryState:
        return self.states[qid]

    def live_states(self) -> List[QueryState]:
        cache = self._live_cache
        if cache is None:
            cache = self._live_cache = [
                s for s in self.states.values() if s.live
            ]
        return cache

    def live_count(self) -> int:
        return len(self.live_states())

    def mark(self, st: QueryState, state: str, now: float, reason: str = "") -> None:
        if state not in QUERY_STATES:
            raise ValueError(f"unknown query state {state!r}")
        st.state = state
        if reason:
            st.reason = reason
        if state == "scoped" and st.scoped_at is None:
            st.scoped_at = now
        elif state == "found" and st.found_at is None:
            st.found_at = now
        elif state in _DEAD:
            st.ended_at = now
        self._live_cache = None
        self._emb_cache = None

    # ------------------------------------------------------------------ #
    def for_mask(self, mask: int) -> Tuple[QueryState, ...]:
        """The QueryStates of every bit set in ``mask`` (live or dead — the
        caller decides attribution vs orphan accounting).  Memoized per mask
        value: bits are never reassigned, so the tuple is stable, and event
        streams repeat the same handful of masks."""
        cached = self._mask_cache.get(mask)
        if cached is not None:
            return cached
        by_index = self._by_bit_index
        out = []
        m = mask
        while m:
            low = m & -m
            m ^= low
            st = by_index.get(low.bit_length() - 1)
            if st is not None:
                out.append(st)
        self._mask_cache[mask] = result = tuple(out)
        return result

    def embedding_block(self) -> Tuple[np.ndarray, List[QueryState]]:
        """Stacked live-query embeddings + the matching states, in bit
        order (the query-major axis of ``reid_match_multi``).

        The stacked array is cached until the live set changes (it is on
        the per-VA-batch hot path), and the *same object* is returned
        across calls so ``reid_match_multi`` keeps it device-resident via
        the dispatch layer's identity-keyed cache."""
        cached = self._emb_cache
        if cached is not None:
            return cached
        live = [s for s in self.live_states() if s.embedding is not None]
        live.sort(key=lambda s: s.bit)
        if not live:
            block: np.ndarray = np.zeros((0, 0), dtype=np.float32)
        else:
            block = np.stack([s.embedding for s in live]).astype(np.float32)
        self._emb_cache = (block, live)
        return self._emb_cache

    # ------------------------------------------------------------------ #
    def reconcile(self) -> Dict[int, Dict[str, int]]:
        """Per-query reconciliation view: after the drain window every
        query's ``unaccounted`` is 0 (the property suite asserts this)."""
        out: Dict[int, Dict[str, int]] = {}
        for qid, st in sorted(self.states.items()):
            out[qid] = {
                "sourced": st.sourced,
                "completed": st.completed,
                "dropped": st.dropped,
                "orphan_completed": st.orphan_completed,
                "orphan_dropped": st.orphan_dropped,
                "unaccounted": st.in_flight,
            }
        return out
