"""Mega-step driver: eligibility, the host-precomputed plan, and result
assembly for the fused tick engine (`repro.kernels.megastep`).

``ScenarioConfig.engine = "megastep"`` lowers eligible multi-query runs to
one engine invocation instead of one scheduler event per pipeline hop:

* **device** — base/bfs/wbfs per-query TLs, drops off, at most 64 queries:
  the whole run executes as one jax ``lax.scan`` over ticks
  (`kernels.megastep.ops`), with camera activity masks, query tag bits,
  the spotlight distance/hop planes and the radius tables resident on
  device; only compact per-(tick, lane, slot) summary rows come back.
* **host** — probabilistic TLs, kernel spotlight mode, or > 64 queries:
  the same chain state machine in numpy (`kernels.megastep.ref`) with the
  real TL objects doing the spotlight step.
* **des** (drops on) — the per-event drop/budget/probe machinery is
  inherently sequential (reject/accept signals mutate budgets between
  events), so the mega-step keeps the event-driven task graph and replaces
  the source plane with its plan-driven tick driver (precomputed tick
  chain + visibility table).

Everything else — faults, dynamism, non-static xi, admission control,
journaling, staged query lifecycles — falls back to the interpreted
pipeline, which remains the reference.  The engine is gated on
bit-exactness: per-query and global summaries must equal the interpreted
``MultiQueryScenario`` exactly (see ``tests/test_megastep.py``).
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..kernels.megastep import ref as _ref
from .tracking import Detection, TLBase, TLBFS, TLWBFS

__all__ = ["MegastepPlan", "megastep_backend", "try_run_megastep"]


# --------------------------------------------------------------------- #
# Eligibility                                                            #
# --------------------------------------------------------------------- #
def megastep_backend(scn) -> Tuple[Optional[str], str]:
    """Classify a ``MultiQueryScenario`` for the mega-step engine.

    Returns ``(backend, reason)`` where backend is ``"device"``, ``"host"``,
    ``"des"`` or ``None`` (fall back to the interpreted pipeline; ``reason``
    says why).
    """
    cfg = scn.cfg
    if getattr(cfg, "engine", "interpreted") != "megastep":
        return None, "engine!=megastep"
    if cfg.dynamism is not None:
        return None, "dynamism"
    if getattr(scn.sim, "faults", None) is not None:
        return None, "faults"
    if scn.journal is not None:
        return None, "journal"
    if scn.admission is not None:
        return None, "admission"
    if cfg.embed_dim:
        return None, "embed_dim"
    if scn.sim.time != 0.0 or scn._ticks_scheduled:
        return None, "already-running"
    states = scn.registry.states
    if not states:
        return None, "no-queries"
    for st in states.values():
        spec = st.spec
        if (
            spec.submit_at > 0.0
            or spec.cancel_at is not None
            or spec.ttl_s is not None
            or spec.make_tl is not None
            or spec.embedding_seed is not None
        ):
            return None, "query-lifecycle"
        if not st.live or st.state != "scoped":
            return None, "query-state"
        tl = st.tl
        if tl.last_seen_time != 0.0 or tl.last_seen_camera is None:
            return None, "tl-seed"
    if cfg.drops_enabled:
        # The signal machinery is sequential by design; keep the event DAG
        # and drive it from the plan (host tick driver).
        return "des", ""
    compiled = scn.compiled
    if not compiled.fuse_fc:
        # fuse_fc already encodes: pass-through FC, static transit + xi,
        # fps > 0 and a frame period longer than xi_fc(1).
        return None, "no-fuse-fc"
    L = len(compiled.va_tasks)
    if len(compiled.cr_tasks) != L or L == 0:
        return None, "va/cr-instances"
    if cfg.batching == "static":
        if cfg.static_batch != 1:
            return None, "static-batch>1"
    elif cfg.batching != "dynamic":
        # Budget-less dynamic batching is pinned to b=1 (bootstrap regime),
        # i.e. streaming — anything else keeps the interpreted pipeline.
        return None, f"batching={cfg.batching}"
    if cfg.tl_update_period != 1.0 / cfg.fps:
        return None, "tl-period!=frame-period"
    net = getattr(scn.sim, "network", None)
    lat = getattr(net, "man_latency_s", None)
    if lat is None or not (0.0 < lat < cfg.tl_update_period):
        return None, "control-latency"
    if not (cfg.duration_s >= 0.0 and math.isfinite(cfg.duration_s)):
        return None, "duration"
    for i in range(L):
        va, cr = compiled.va_tasks[i], compiled.cr_tasks[i]
        if va.node != cr.node:
            return None, "va/cr-colocation"
    if scn._spotlight_mode == "kernel":
        return "host", ""
    for st in states.values():
        tl = st.tl
        if type(tl) not in (TLBase, TLBFS, TLWBFS):
            return "host", ""
        if not (math.isfinite(tl.entity_speed) and math.isfinite(tl.min_radius_m)):
            return "host", ""
    if len(states) > 64:
        return "host", ""
    return "device", ""


# --------------------------------------------------------------------- #
# Plan: everything the engine needs, precomputed once on the host        #
# --------------------------------------------------------------------- #
@dataclass
class MegastepPlan:
    ftimes: np.ndarray          # (T,) f64 frame/TL tick chain
    vis: np.ndarray             # (T, C) bool entity visibility
    lane_of: np.ndarray         # (C,) int64 cam -> VA/CR lane
    num_lanes: int
    num_cameras: int
    xi_fc: float
    xi_va: float
    xi_cr: float
    xi_bar: float               # (xi_fc + xi_va) + xi_cr, header float order
    d_fv: float                 # fused FC -> VA transit
    d_vc: float                 # VA -> CR (same-host ipc)
    d_cu: float                 # CR -> sink
    uniforms: np.ndarray        # (dmax,) shared CR verdict stream
    p_tp: float
    gamma: float
    eps_max: float
    duration: float
    horizon: float
    # Table-TL planes (device backend; None for the host-object backend)
    modes: Optional[np.ndarray] = None        # (N,) 0 base / 1 bfs / 2 wbfs
    rgroup: Optional[np.ndarray] = None       # (N,) radius-table group
    r_tabs: List[np.ndarray] = field(default_factory=list)   # [(T, T) f64]
    h_tabs: List[np.ndarray] = field(default_factory=list)   # [(T, T) i64]
    cand_of_cam: Optional[np.ndarray] = None  # (C,) i64, -1 = not candidate
    dist_plane: Optional[np.ndarray] = None   # (n_cand, C) f64
    hop_plane: Optional[np.ndarray] = None    # (n_cand, C) i64
    seed_ls_cam: Optional[np.ndarray] = None  # (N,) i64


def _dijkstra_row(adjacency, source: int, n: int) -> np.ndarray:
    """Full Dijkstra with the exact float semantics of
    ``RoadNetwork.weighted_ball`` (heap pops, ``nd = d + w``, strict ``<``),
    so plane distances equal the ball's distances bit-for-bit."""
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    heap: List[Tuple[float, int]] = [(0.0, source)]
    pop, push = heapq.heappop, heapq.heappush
    while heap:
        d, u = pop(heap)
        if d > dist[u]:
            continue
        for v, w in adjacency[u]:
            nd = d + w
            if nd < dist[v]:
                dist[v] = nd
                push(heap, (nd, v))
    return dist


def _bfs_row(adjacency, source: int, n: int) -> np.ndarray:
    hops = np.full(n, np.iinfo(np.int64).max, dtype=np.int64)
    hops[source] = 0
    frontier = [source]
    h = 0
    while frontier:
        h += 1
        nxt: List[int] = []
        for u in frontier:
            for v, _ in adjacency[u]:
                if hops[v] > h:
                    hops[v] = h
                    nxt.append(v)
        frontier = nxt
    return hops


def build_plan(scn, backend: str) -> MegastepPlan:
    cfg = scn.cfg
    compiled = scn.compiled
    sim = scn.sim
    C = scn.cameras.num_cameras
    L = len(compiled.va_tasks)

    # Frame tick chain: t=0, then t += 1/fps while the next tick still fits
    # in the generation window — the scheduler's accumulated-float times.
    dt = 1.0 / cfg.fps
    ftimes = [0.0]
    t = 0.0
    while t + dt <= cfg.duration_s:
        t = t + dt
        ftimes.append(t)
    ftimes_arr = np.asarray(ftimes, dtype=np.float64)
    T = len(ftimes)

    all_ids = np.arange(C, dtype=np.int64)
    vis = np.empty((T, C), dtype=bool)
    for k in range(T):
        vis[k] = scn.cameras.visible_batch(all_ids, float(ftimes_arr[k]))

    lane_of = all_ids % L

    if backend == "des":
        # The drops-on tick driver keeps the real task DAG: it only needs
        # the tick chain and the visibility table.
        xi_fc = xi_va = xi_cr = d_fv = d_vc = d_cu = 0.0
        uniforms = np.empty(0)
    else:
        va0, cr0 = compiled.va_tasks[0], compiled.cr_tasks[0]
        d_fv = compiled.fc_transit
        d_vc = sim.transit_delay(va0.node, cr0.node, va0.output_event_bytes)
        d_cu = sim.transit_delay(cr0.node, scn.sink.node, cr0.output_event_bytes)
        xi_fc = compiled.fc_xi1
        xi_va = va0.xi(1)
        xi_cr = cr0.xi(1)
        visc = vis.sum(axis=0, dtype=np.int64)
        lane_draws = np.bincount(lane_of, weights=visc, minlength=L)
        dmax = int(lane_draws.max()) if L else 0
        uniforms = np.random.default_rng(cfg.seed + 101).uniform(size=dmax)

    plan = MegastepPlan(
        ftimes=ftimes_arr,
        vis=vis,
        lane_of=lane_of,
        num_lanes=L,
        num_cameras=C,
        xi_fc=xi_fc,
        xi_va=xi_va,
        xi_cr=xi_cr,
        xi_bar=(xi_fc + xi_va) + xi_cr,
        d_fv=d_fv,
        d_vc=d_vc,
        d_cu=d_cu,
        uniforms=uniforms,
        p_tp=cfg.p_true_positive,
        gamma=scn.app.gamma,
        eps_max=scn.deployment.epsilon_max,
        duration=cfg.duration_s,
        horizon=scn._horizon,
    )
    if backend != "device":
        return plan

    # ---- table-TL planes ------------------------------------------------ #
    live = scn.registry.live_states()
    N = len(live)
    cam_vertex = np.fromiter(
        (scn.cameras.camera_vertices[int(c)] for c in all_ids),
        dtype=np.int64,
        count=C,
    )
    modes = np.zeros(N, dtype=np.int8)
    seed_ls = np.zeros(N, dtype=np.int64)
    group_key: Dict[Tuple[float, float, float], int] = {}
    rgroup = np.zeros(N, dtype=np.int64)
    r_tabs: List[np.ndarray] = []
    h_tabs: List[np.ndarray] = []
    elapsed = np.maximum(ftimes_arr[None, :] - ftimes_arr[:, None], 0.0)
    for i, st in enumerate(live):
        tl = st.tl
        modes[i] = {TLBase: 0, TLBFS: 1, TLWBFS: 2}[type(tl)]
        seed_ls[i] = int(tl.last_seen_camera)
        fe = getattr(tl, "fixed_edge_length_m", 84.5)
        key = (float(tl.min_radius_m), float(tl.entity_speed), float(fe))
        g = group_key.get(key)
        if g is None:
            g = len(r_tabs)
            group_key[key] = g
            r = tl.min_radius_m + tl.entity_speed * elapsed
            r_tabs.append(r)
            h_tabs.append(np.ceil(r / fe).astype(np.int64))
        rgroup[i] = g

    ever_vis = np.nonzero(vis.any(axis=0))[0]
    cand_cams = set(int(c) for c in ever_vis) | set(int(c) for c in seed_ls)
    cand_vertices: List[int] = []
    vert_row: Dict[int, int] = {}
    for c in sorted(cand_cams):
        v = int(cam_vertex[c])
        if v not in vert_row:
            vert_row[v] = len(cand_vertices)
            cand_vertices.append(v)
    cand_of_cam = np.full(C, -1, dtype=np.int64)
    for c in sorted(cand_cams):
        cand_of_cam[c] = vert_row[int(cam_vertex[c])]

    adjacency = scn.road.adjacency
    V = scn.road.num_vertices
    n_cand = len(cand_vertices)
    dist_plane = np.empty((n_cand, C), dtype=np.float64)
    hop_plane = np.empty((n_cand, C), dtype=np.int64)
    need_hops = bool((modes == 1).any())
    need_dist = bool((modes == 2).any())
    for r_i, v in enumerate(cand_vertices):
        if need_dist or True:
            dist_plane[r_i] = _dijkstra_row(adjacency, v, V)[cam_vertex]
        if need_hops:
            hop_plane[r_i] = _bfs_row(adjacency, v, V)[cam_vertex]
    if not need_hops:
        hop_plane[:] = 0

    plan.modes = modes
    plan.rgroup = rgroup
    plan.r_tabs = r_tabs
    plan.h_tabs = h_tabs
    plan.cand_of_cam = cand_of_cam
    plan.dist_plane = dist_plane
    plan.hop_plane = hop_plane
    plan.seed_ls_cam = seed_ls
    return plan


# --------------------------------------------------------------------- #
# Result assembly (drops-off backends)                                   #
# --------------------------------------------------------------------- #
def _seed_applied(live, C: int) -> np.ndarray:
    req = np.zeros((len(live), C), dtype=bool)
    for i, st in enumerate(live):
        if st.requested:
            req[i, np.fromiter(st.requested, dtype=np.int64, count=len(st.requested))] = True
    return req


def _make_object_tl(scn, plan, live):
    """TL callback using the real per-query TL objects (host backend) —
    exactly ``MultiQueryScenario._query_targets``, including kernel
    spotlight mode."""
    ftimes = plan.ftimes
    C = plan.num_cameras
    bits = [st.bit for st in live]

    def tl_step(k: int, dets: List[_ref.SinkRow]) -> np.ndarray:
        now = float(ftimes[k])
        det_objs = [
            Detection(camera_id=r.cam, positive=r.positive, timestamp=float(ftimes[r.tick]))
            for r in dets
        ]
        masks = [
            int(sum(b for b, m in zip(bits, r.mask) if m)) for r in dets
        ]
        targets = scn._query_targets(live, det_objs, masks, now)
        req = np.zeros((len(live), C), dtype=bool)
        for i, (st, cams) in enumerate(zip(live, targets)):
            st.requested = set(cams)
            if cams:
                req[i, np.fromiter(cams, dtype=np.int64, count=len(cams))] = True
        return req

    return tl_step


def _finalize(scn, plan: MegastepPlan, out: _ref.ChainOutput, live):
    """Build the MultiQueryResult from the engine's summary rows, writing
    the same per-query registry books the interpreted hooks fill."""
    from ..query.scenario import MultiQueryResult
    from ..sim.scenario import ScenarioResult

    reg = scn.registry
    gamma = plan.gamma
    eps_max = plan.eps_max
    horizon = plan.horizon
    xi_bar = plan.xi_bar

    for k, counts, union_count in out.tl_counts:
        now = float(plan.ftimes[k])
        for st, c in zip(live, counts):
            st.active_timeline.append((now, int(c)))
        scn._stats_active.append((now, union_count))
    for i, st in enumerate(live):
        st.sourced = int(out.sourced[i])
        st.positives_generated = int(out.query_positives[i])
    scn._source_events = out.source_events
    scn._positives_generated = out.positives_generated

    latencies: List[Tuple[float, float]] = []
    on_time = delayed = 0
    for j, r in enumerate(out.rows):
        if r.a_uv > horizon:
            continue  # still in flight when the drain window closed
        u = r.u
        latencies.append((r.a_uv, u))
        ok = u <= gamma
        if ok:
            on_time += 1
        else:
            delayed += 1
        if r.positive:
            scn._positives_completed += 1
            if ok:
                scn._detections_on_time += 1
        for i in np.nonzero(r.mask)[0]:
            st = live[i]
            st.completed += 1
            st.latencies.append((r.a_uv, u))
            if ok:
                st.on_time += 1
            else:
                st.delayed += 1
            if r.positive:
                st.positives_completed += 1
                if ok:
                    st.detections_on_time += 1
                if st.state == "scoped":
                    reg.mark(st, "found", r.a_uv)
            st.record_completion(j, u, r.q_bar, xi_bar, gamma, eps_max)

    cfg = scn.cfg
    base = ScenarioResult(
        config=cfg,
        active_timeline=scn._stats_active,
        latencies=latencies,
        on_time=on_time,
        delayed=delayed,
        source_events=scn._source_events,
        dropped=0,
        drops_by_task={},
        batch_sizes={
            "VA": [1] * int(out.va_exec_counts.sum()),
            "CR": [1] * int(out.cr_exec_counts.sum()),
        },
        positives_generated=scn._positives_generated,
        positives_completed=scn._positives_completed,
        positives_dropped=scn._positives_generated - scn._positives_completed,
        detections_on_time=scn._detections_on_time,
        reid_matched=0,
        query_pushes=scn.compiled.query_pushes,
        trace=None,
        quality=None,
    )
    per_query: Dict[int, ScenarioResult] = {}
    for qid, st in sorted(reg.states.items()):
        per_query[qid] = ScenarioResult(
            config=cfg,
            active_timeline=list(st.active_timeline),
            latencies=list(st.latencies),
            on_time=st.on_time,
            delayed=st.delayed,
            source_events=st.sourced,
            dropped=st.dropped,
            drops_by_task={
                **{f"dp{i}": st.dp[i] for i in (1, 2, 3) if st.dp[i]},
                **({"dp_fault": st.dp[4]} if st.dp[4] else {}),
            },
            batch_sizes={},
            positives_generated=st.positives_generated,
            positives_completed=st.positives_completed,
            positives_dropped=st.positives_generated - st.positives_completed,
            detections_on_time=st.detections_on_time,
            reid_matched=st.reid_matched,
            query_pushes=0,
            trace=None,
            quality=None,
        )
    return MultiQueryResult(
        result=base,
        per_query=per_query,
        registry=reg,
        admission=scn.admission,
        states={qid: st.state for qid, st in sorted(reg.states.items())},
    )


# --------------------------------------------------------------------- #
# Drops-on: plan-driven source plane over the event DAG                  #
# --------------------------------------------------------------------- #
def _prime_des(scn, plan: MegastepPlan) -> None:
    """Install the mega-step source plane: the precomputed tick chain and
    visibility table replace the per-tick position interpolation + FOV
    test, while the real tasks keep the drop/budget/probe semantics.  The
    caller then proceeds with the normal run loop."""
    from .events import Event, new_event_id, source_header
    from ..sim.cameras import Frame

    cfg = scn.cfg
    compiled = scn.compiled
    sim = scn.sim
    vis = plan.vis
    dt = 1.0 / cfg.fps
    tick_idx = [0]

    def frame_tick() -> None:
        t = sim.time
        k = tick_idx[0]
        tick_idx[0] += 1
        fc_active = compiled.fc_active
        if fc_active:
            ids = np.fromiter(fc_active, dtype=np.int64, count=len(fc_active))
            ids.sort()
            vis_k = vis[k]
            mask_of = scn._mask_of
            frames = [
                Frame(camera_id=int(c), timestamp=t, has_entity=bool(vis_k[c]))
                for c in ids
                if mask_of.get(int(c), 0)
            ]
            n_pos = 0
            fc_tasks = compiled.fc_tasks
            make_fc = compiled.make_fc
            for frame in frames:
                if frame.has_entity:
                    n_pos += 1
                cam = frame.camera_id
                fc = fc_tasks.get(cam)
                if fc is None:
                    fc = make_fc(cam)
                header = source_header(new_event_id(), t)
                ev = Event(header=header, key=cam, value=frame)
                ev.query_mask = mask_of[cam]
                fc.on_arrival(ev)
            scn._positives_generated += n_pos
            scn._source_events += len(frames)
            if scn._source_hook is not None:
                scn._source_hook(frames, t)
        if t + dt <= cfg.duration_s:
            sim.schedule(dt, frame_tick)

    scn._ticks_scheduled = True
    sim.schedule(0.0, frame_tick)
    sim.schedule(cfg.tl_update_period, scn._tl_tick)


# --------------------------------------------------------------------- #
# Entry point                                                            #
# --------------------------------------------------------------------- #
def try_run_megastep(scn):
    """Run the mega-step engine for ``scn`` if it is eligible.

    Returns a finished ``MultiQueryResult`` (drops-off device/host
    backends), or ``None`` — in which case the caller continues with the
    interpreted run loop (either as a plain fallback, or with the plan's
    source plane already primed for the drops-on backend)."""
    backend, reason = megastep_backend(scn)
    if backend is None:
        scn.engine_used = "interpreted"
        # Engine contract (verified by repro.analysis.graphcheck GRF005):
        # a requested-but-skipped megastep is never silent — every fallback
        # records why, even if a future classifier branch forgets to.
        scn.engine_fallback_reason = reason or "unclassified"
        return None
    live = scn.registry.live_states()
    plan = build_plan(scn, backend)
    if backend == "des":
        _prime_des(scn, plan)
        scn.engine_used = "megastep-des"
        scn.engine_fallback_reason = ""
        return None
    seed = _seed_applied(live, plan.num_cameras)
    if backend == "device":
        out = _run_device(scn, plan, seed)
        if out is None:
            backend = "host"  # jax missing or shape divergence: host mirror
    if backend == "host":
        if scn._spotlight_mode == "kernel" or any(
            type(st.tl) not in (TLBase, TLBFS, TLWBFS) for st in live
        ):
            tl_step = _make_object_tl(scn, plan, live)
        elif plan.modes is not None:
            tl_step = _ref.make_table_tl(plan)
        else:
            tl_step = _make_object_tl(scn, plan, live)
        out = _ref.run_chain(plan, tl_step, seed)
        scn.engine_used = "megastep-host"
    else:
        scn.engine_used = "megastep-device"
    scn.engine_fallback_reason = ""
    if out.final_req is not None:
        # Leave the registry's requested sets at the last TL tick's targets
        # (the object-TL callback already does; the table/device paths
        # report them through the chain output).
        for i, st in enumerate(live):
            st.requested = {int(c) for c in np.nonzero(out.final_req[i])[0]}
    res = _finalize(scn, plan, out, live)
    _sync_control_mirrors(scn, live)
    return res


def _sync_control_mirrors(scn, live) -> None:
    """Leave the scenario's control mirrors in their end-of-run state so
    post-run inspection matches the interpreted pipeline."""
    union: set = set()
    mask_of: Dict[int, int] = {}
    for st in live:
        st.applied = set(st.requested)
        union |= st.requested
        for cam in st.requested:
            mask_of[cam] = mask_of.get(cam, 0) | st.bit
    scn._ctrl_target = union
    scn._mask_of = mask_of
    scn.compiled.fc_active.clear()
    scn.compiled.fc_active |= union


def _run_device(scn, plan: MegastepPlan, seed_applied: np.ndarray):
    """Device scan backend; returns a ChainOutput or None (unavailable /
    diverged beyond the largest bucket).

    With a mesh handle (``MultiQueryScenario(..., mesh=...)`` /
    ``distributed.camera_mesh()``) the scan runs camera-sharded via
    ``kernels.megastep.sharded``; any sharded-path refusal (single visible
    device, no ``cameras`` axis, non-dividing bucket) is recorded in
    ``scn.shard_fallback_reason`` — the GRF005 totality contract extended
    to sharding — and the run continues bit-identically on the unsharded
    single-shard path."""
    try:
        from ..kernels.megastep import ops as _ops
    except ImportError:  # jax unavailable: host reference takes over
        return None
    if plan.modes is None:
        return None
    rules = getattr(scn, "mesh_rules", None)
    if rules is not None:
        from ..kernels.megastep import sharded as _sharded

        out = _sharded.run_chain_sharded(plan, seed_applied, rules)
        if out is not None:
            scn.engine_xfer_s = _sharded.last_xfer_seconds()
            scn.shards_used = _sharded.last_shards()
            scn.collective_bytes_per_tick = (
                _sharded.last_collective_bytes_per_tick()
            )
            scn.shard_fallback_reason = ""
            chunk_walls = _sharded.last_chunk_seconds()
            scn.megastep_chunk_s = sum(chunk_walls)
            scn.megastep_chunks = len(chunk_walls)
            return out
        scn.shard_fallback_reason = _sharded.last_error() or "unclassified"
    out = _ops.run_chain_device(plan, seed_applied)
    if out is not None:
        scn.engine_xfer_s = _ops.last_xfer_seconds()
        chunk_walls = _ops.last_chunk_seconds()
        scn.megastep_chunk_s = sum(chunk_walls)
        scn.megastep_chunks = len(chunk_walls)
    return out
