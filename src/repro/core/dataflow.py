"""The domain-specific dataflow model (paper §2.2, Fig. 2).

The dataflow shape is *fixed* (like MapReduce): the user supplies only the
functional logic of six module types and the platform wires, parallelizes and
tunes them:

    FC --> VA --> CR --> { TL, QF, UV }
     ^______________________|   |
         (activation ctrl)      |--> VA/CR query update

* **FC** (Filter Controls): per-camera entry point; forwards a frame iff its
  local state says so (``isActive``, frame-rate).  Updated by TL control
  events.
* **VA** (Video Analytics): per-camera batched analytics (detection), may
  invoke external models; state updatable by QF.
* **CR** (Contention Resolution): cross-camera re-identification on grouped
  detections; heavier model, runs less often; state updatable by QF.
* **TL** (Tracking Logic): the paper's novel module — interprets detections,
  expands/contracts the spotlight, (de)activates FCs.
* **QF** (Query Fusion): fuses high-confidence detections into the entity
  query and pushes the new query to VA/CR.
* **UV** (User Visualization): sink; receives annotated detections.

This module defines the *interfaces* and the :class:`TrackingApp` composition
used by both the discrete-event simulator (``repro.sim``) and the JAX serving
engine (``repro.serving.scheduler``), which plugs jit-compiled model steps in
as VA/CR logic.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Protocol, Sequence, Tuple

from .events import Event
from .tracking import Detection, TrackingLogic

__all__ = [
    "FCLogic",
    "VALogic",
    "CRLogic",
    "QFLogic",
    "ModuleSpec",
    "TrackingApp",
]


class FCLogic(Protocol):
    """``fc(frame, state) -> bool`` — forward the frame?  (paper Alg. 1)."""

    def __call__(self, frame: Any, state: Dict[str, Any]) -> bool: ...


class VALogic(Protocol):
    """``va(camera_id, frames, state) -> [(camera_id, value)]``.

    Receives a batch of frames grouped by camera; emits key-value pairs
    (e.g. bounding boxes with scores).  May read ``state['entity_query']``.
    """

    def __call__(
        self, camera_id: Any, frames: Sequence[Any], state: Dict[str, Any]
    ) -> List[Tuple[Any, Any]]: ...


class CRLogic(Protocol):
    """``cr(camera_id, values, state) -> [(camera_id, detection)]``.

    Cross-camera contention resolution / re-id on VA outputs.
    """

    def __call__(
        self, camera_id: Any, values: Sequence[Any], state: Dict[str, Any]
    ) -> List[Tuple[Any, Any]]: ...


class QFLogic(Protocol):
    """``qf(detections, state) -> new_query | None`` — query fusion (§2.2.5)."""

    def __call__(
        self, detections: Sequence[Detection], state: Dict[str, Any]
    ) -> Optional[Any]: ...


@dataclass
class ModuleSpec:
    """Deployment spec for one module type (paper §3: Master/Scheduler)."""

    instances: int = 1
    resource_tier: str = "fog"  # edge | fog | cloud
    m_max: int = 25
    batching: str = "dynamic"  # dynamic | static | nob
    static_batch: int = 1
    # xi(b): expected execution duration (seconds) for a batch of b events.
    xi: Callable[[int], float] = lambda b: 0.0


@dataclass
class TrackingApp:
    """A composed tracking application (paper Table 1).

    ``fc``/``va``/``cr``/``qf`` are the user logics; ``tl`` is a
    :class:`TrackingLogic` strategy instance.  ``specs`` gives per-module
    deployment/tuning parameters.  The app is executed either by the
    discrete-event simulator (`repro.sim.scenario.run_app`) or, for the VA/CR
    compute, by the JAX serving engine.
    """

    name: str
    fc: FCLogic
    va: VALogic
    cr: CRLogic
    tl: TrackingLogic
    qf: Optional[QFLogic] = None
    specs: Dict[str, ModuleSpec] = field(default_factory=dict)
    entity_query: Any = None
    gamma: float = 15.0  # max tolerable latency (paper §5.1)

    def spec(self, module: str) -> ModuleSpec:
        return self.specs.get(module, ModuleSpec())


# --------------------------------------------------------------------- #
# Reference user logics (paper Alg. 1 / Table 1), analytics-agnostic:   #
# the actual detectors are injected (HoG / DNN / JAX model).            #
# --------------------------------------------------------------------- #
def fc_is_active(frame: Any, state: Dict[str, Any]) -> bool:
    """App 1/2/4 FC: forward iff the camera is active."""
    return bool(state.get("isActive", True))


def fc_frame_rate(frame: Any, state: Dict[str, Any]) -> bool:
    """App 3 FC: subsample to the commanded frame-rate."""
    rate = max(int(state.get("frame_rate", 1)), 1)
    count = state.get("_count", 0)
    state["_count"] = count + 1
    return count % rate == 0


def make_va(detector: Callable[[Sequence[Any], Any], List[Any]]) -> VALogic:
    """Wrap a batched detector ``detector(frames, query) -> per-frame boxes``
    as VA logic (HoG in App 1/2, YOLO in App 3, small re-id in App 4)."""

    def va(camera_id, frames, state):
        boxes = detector(frames, state.get("entity_query"))
        return [(camera_id, (frame, bb)) for frame, bb in zip(frames, boxes)]

    return va


def make_cr(reid: Callable[[Sequence[Any], Any], List[bool]]) -> CRLogic:
    """Wrap a batched re-id matcher ``reid(crops, query) -> [bool]`` as CR."""

    def cr(camera_id, values, state):
        crops = [v for v in values]
        verdicts = reid(crops, state.get("entity_query"))
        return [(camera_id, bool(v)) for v in verdicts]

    return cr
