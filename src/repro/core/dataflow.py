"""The domain-specific dataflow model (paper §2.2, Fig. 2).

The dataflow shape is *fixed* (like MapReduce): the user supplies only the
functional logic of six module types and the platform wires, parallelizes and
tunes them:

    FC --> VA --> CR --> { TL, QF, UV }
     ^______________________|   |
         (activation ctrl)      |--> VA/CR query update

* **FC** (Filter Controls): per-camera entry point; forwards a frame iff its
  local state says so (``isActive``, frame-rate).  Updated by TL control
  events.
* **VA** (Video Analytics): per-camera batched analytics (detection), may
  invoke external models; state updatable by QF.
* **CR** (Contention Resolution): cross-camera re-identification on grouped
  detections; heavier model, runs less often; state updatable by QF.
* **TL** (Tracking Logic): the paper's novel module — interprets detections,
  expands/contracts the spotlight, (de)activates FCs.
* **QF** (Query Fusion): fuses high-confidence detections into the entity
  query and pushes the new query to VA/CR.
* **UV** (User Visualization): sink; receives annotated detections.

This module defines the *interfaces* and the :class:`TrackingApp`
composition.  A composed app is the platform's **executable unit**: the app
compiler (:func:`repro.core.compile.compile_app`) lowers a ``TrackingApp`` +
a world + a :class:`repro.core.compile.DeploymentSpec` onto the
:mod:`repro.core.pipeline` Task DAG (FC fan-in, VA/CR replicas, UV sink, the
TL control loop and the QF query-fusion feedback edge), and
:func:`repro.serving.scheduler.lower_app_stages` lowers the same spec onto
jit-compiled :class:`~repro.serving.scheduler.ServedStage` instances.  The
discrete-event simulator's :class:`~repro.sim.scenario.TrackingScenario` is a
thin driver over the compiled app; ``ScenarioConfig.to_app()`` exposes the
simulator's historical knob presets as app factories.

Per-module deployment is declared with :class:`ModuleSpec`.  Every field is
optional: ``None`` means "inherit the platform default" from the
``DeploymentSpec`` the app is compiled against, so an app only pins what it
cares about (paper §2.3: the platform does the wiring, tuning and
placement).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Protocol, Sequence, Tuple

from .events import Event
from .tracking import Detection, TrackingLogic

__all__ = [
    "BATCHING_STRATEGIES",
    "RESOURCE_TIERS",
    "FCLogic",
    "VALogic",
    "CRLogic",
    "QFLogic",
    "ModuleSpec",
    "TrackingApp",
]


class FCLogic(Protocol):
    """``fc(frame, state) -> bool`` — forward the frame?  (paper Alg. 1)."""

    def __call__(self, frame: Any, state: Dict[str, Any]) -> bool: ...


class VALogic(Protocol):
    """``va(camera_id, frames, state) -> [(camera_id, value)]``.

    Receives a batch of frames grouped by camera; emits key-value pairs
    (e.g. bounding boxes with scores).  May read ``state['entity_query']``.

    Lowering contract (``repro.core.compile``): output attribution is
    *positional* — pair ``i`` rides frame ``i``'s event.  Emit one pair per
    frame; to filter a frame out, put ``None`` in its position (do NOT
    return a compacted shorter list — the survivors would be matched to the
    wrong frames' events).
    """

    def __call__(
        self, camera_id: Any, frames: Sequence[Any], state: Dict[str, Any]
    ) -> List[Tuple[Any, Any]]: ...


class CRLogic(Protocol):
    """``cr(camera_id, values, state) -> [(camera_id, detection)]``.

    Cross-camera contention resolution / re-id on VA outputs.  Same
    positional lowering contract as :class:`VALogic`: one pair (or ``None``
    to filter) per input value, in input order.
    """

    def __call__(
        self, camera_id: Any, values: Sequence[Any], state: Dict[str, Any]
    ) -> List[Tuple[Any, Any]]: ...


class QFLogic(Protocol):
    """``qf(detections, state) -> new_query | None`` — query fusion (§2.2.5)."""

    def __call__(
        self, detections: Sequence[Detection], state: Dict[str, Any]
    ) -> Optional[Any]: ...


#: Valid values for :attr:`ModuleSpec.batching` / :attr:`ModuleSpec.resource_tier`.
BATCHING_STRATEGIES = ("dynamic", "static", "nob")
RESOURCE_TIERS = ("edge", "fog", "cloud")


@dataclass
class ModuleSpec:
    """Per-module deployment overrides (paper §3: Master/Scheduler).

    Every field defaults to ``None`` — "inherit the platform default" — so a
    :class:`TrackingApp` only pins the knobs it cares about and the compiler
    (:func:`repro.core.compile.resolve_module`) fills in the rest from the
    :class:`~repro.core.compile.DeploymentSpec`.  ``batching`` and
    ``resource_tier`` are validated at construction; ``xi`` (the expected
    execution duration, seconds, for a batch of ``b`` events) is a plain
    optional callable — the old shared default-``lambda`` sentinel made
    "no cost model" indistinguishable from "explicitly free" and was a
    mutable-default footgun shared across every spec instance.
    """

    instances: Optional[int] = None
    resource_tier: Optional[str] = None  # edge | fog | cloud
    m_max: Optional[int] = None
    batching: Optional[str] = None  # dynamic | static | nob
    static_batch: Optional[int] = None
    # xi(b): expected execution duration (seconds) for a batch of b events.
    xi: Optional[Callable[[int], float]] = None

    def __post_init__(self) -> None:
        if self.batching is not None and self.batching not in BATCHING_STRATEGIES:
            raise ValueError(
                f"unknown batching {self.batching!r}; expected one of {BATCHING_STRATEGIES}"
            )
        if self.resource_tier is not None and self.resource_tier not in RESOURCE_TIERS:
            raise ValueError(
                f"unknown resource_tier {self.resource_tier!r}; expected one of {RESOURCE_TIERS}"
            )
        for name in ("instances", "m_max", "static_batch"):
            value = getattr(self, name)
            if value is not None and int(value) < 1:
                raise ValueError(f"{name} must be >= 1, got {value!r}")
        if self.xi is not None and not callable(self.xi):
            raise ValueError("xi must be callable (b -> seconds) or None")


@dataclass
class TrackingApp:
    """A composed tracking application (paper Table 1).

    ``fc``/``va``/``cr``/``qf`` are the user logics; ``tl`` is a
    :class:`TrackingLogic` strategy instance.  ``specs`` gives per-module
    deployment/tuning overrides (merged over the ``DeploymentSpec`` by the
    compiler).  The app is executed by lowering it:
    ``repro.core.compile.compile_app`` builds the discrete-event Task DAG
    (driven by ``repro.sim.scenario.TrackingScenario``), and
    ``repro.serving.scheduler.lower_app_stages`` builds the jit'd serving
    stages for the VA/CR compute.
    """

    name: str
    fc: FCLogic
    va: VALogic
    cr: CRLogic
    tl: TrackingLogic
    qf: Optional[QFLogic] = None
    specs: Dict[str, ModuleSpec] = field(default_factory=dict)
    entity_query: Any = None
    gamma: float = 15.0  # max tolerable latency (paper §5.1)

    def spec(self, module: str) -> ModuleSpec:
        return self.specs.get(module, ModuleSpec())


# --------------------------------------------------------------------- #
# Reference user logics (paper Alg. 1 / Table 1), analytics-agnostic:   #
# the actual detectors are injected (HoG / DNN / JAX model).            #
# --------------------------------------------------------------------- #
def fc_is_active(frame: Any, state: Dict[str, Any]) -> bool:
    """App 1/2/4 FC: forward iff the camera is active."""
    return bool(state.get("isActive", True))


# Lowering override (see ``repro.core.compile``): the activation gate needs
# one state read per *batch*, not one call per event — and the compiler
# additionally recognizes this exact logic as fusable into the frame source.
fc_is_active.task_logic = (
    lambda events, state: events if state.get("isActive", True) else []
)


def fc_frame_rate(frame: Any, state: Dict[str, Any]) -> bool:
    """App 3 FC: subsample to the commanded frame-rate."""
    rate = max(int(state.get("frame_rate", 1)), 1)
    count = state.get("_count", 0)
    state["_count"] = count + 1
    return count % rate == 0


def make_va(detector: Callable[[Sequence[Any], Any], List[Any]]) -> VALogic:
    """Wrap a batched detector ``detector(frames, query) -> per-frame boxes``
    as VA logic (HoG in App 1/2, YOLO in App 3, small re-id in App 4)."""

    def va(camera_id, frames, state):
        boxes = detector(frames, state.get("entity_query"))
        return [(camera_id, (frame, bb)) for frame, bb in zip(frames, boxes)]

    return va


def make_cr(reid: Callable[[Sequence[Any], Any], List[bool]]) -> CRLogic:
    """Wrap a batched re-id matcher ``reid(crops, query) -> [bool]`` as CR."""

    def cr(camera_id, values, state):
        crops = [v for v in values]
        verdicts = reid(crops, state.get("entity_query"))
        return [(camera_id, bool(v)) for v in verdicts]

    return cr
