"""Event model for the Anveshak dataflow (paper §2.2, §4.2).

Every event entering a pipeline at the source task ``tau_1`` gets a unique ID
``k``; with 1:1 task selectivity, the pair ``(k, i)`` uniquely identifies the
causal event ``e_k^i`` input to task ``tau_i``.  Events carry a small header
with the *source arrival time* ``a_k^1`` (measured on the source clock) plus
the running sums of upstream execution time (``xi_bar``) and queuing delay
(``q_bar``) used by the budget-update protocol (paper §4.5).

Performance note: headers and events sit on the runtime's per-event hot path
(a 1000-camera scenario creates one header per frame per task hop), so both
carry ``__slots__`` and ``advanced()`` avoids :func:`dataclasses.replace`,
drawing recycled header objects from a small free-list pool instead.  Code
that provably ends an event's life inside the runtime (drop points, sink)
may return its header via :func:`release_header`; everything else can simply
let headers be garbage collected.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, List, Optional

__all__ = [
    "EventHeader",
    "Event",
    "EventRecord",
    "RejectSignal",
    "AcceptSignal",
    "ProbeSignal",
    "new_event_id",
    "release_header",
    "source_header",
]

_id_counter = itertools.count()


def new_event_id() -> int:
    """Globally unique, monotonically increasing source-event ID ``k``."""
    return next(_id_counter)


class EventHeader:
    """Header propagated with every causal downstream event (paper §4.2, §4.5).

    Attributes
    ----------
    event_id:
        The source-event ID ``k``.
    source_arrival:
        ``a_k^1`` — the arrival time of the source event at the source task,
        measured on the *source device clock* kappa_1.  Propagated verbatim.
    xi_bar:
        ``sum_{j=1..i} xi_j(m_k^j)`` — total execution duration spent at the
        preceding tasks (durations; clock-skew free).
    q_bar:
        ``sum_{j=1..i} q_k^j`` — total queuing delay at the preceding tasks.
    avoid_drop:
        The user logic may flag an event (e.g. a positive detection) so the
        platform will not drop it even past its budget (paper §4.3.3).
    is_probe:
        Probe signals are forwarded downstream without drops to recover from
        budget collapse (paper §4.5.2).
    path:
        The task-path this event has traversed (its *pipeline*, §4.2):
        signals are delivered to the tasks on this path, not the whole DAG.
    """

    __slots__ = (
        "event_id",
        "source_arrival",
        "xi_bar",
        "q_bar",
        "avoid_drop",
        "is_probe",
        "path",
    )

    def __init__(
        self,
        event_id: int,
        source_arrival: float,
        xi_bar: float = 0.0,
        q_bar: float = 0.0,
        avoid_drop: bool = False,
        is_probe: bool = False,
        path: tuple = (),
    ) -> None:
        self.event_id = event_id
        self.source_arrival = source_arrival
        self.xi_bar = xi_bar
        self.q_bar = q_bar
        self.avoid_drop = avoid_drop
        self.is_probe = is_probe
        self.path = path

    def __repr__(self) -> str:  # keep the old dataclass ergonomics
        return (
            f"EventHeader(event_id={self.event_id!r}, "
            f"source_arrival={self.source_arrival!r}, xi_bar={self.xi_bar!r}, "
            f"q_bar={self.q_bar!r}, avoid_drop={self.avoid_drop!r}, "
            f"is_probe={self.is_probe!r}, path={self.path!r})"
        )

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, EventHeader):
            return NotImplemented
        return (
            self.event_id == other.event_id
            and self.source_arrival == other.source_arrival
            and self.xi_bar == other.xi_bar
            and self.q_bar == other.q_bar
            and self.avoid_drop == other.avoid_drop
            and self.is_probe == other.is_probe
            and self.path == other.path
        )

    def advanced(self, xi: float, q: float, task: str = "") -> "EventHeader":
        """Header for the causal downstream event after this task."""
        h = _acquire_header()
        h.event_id = self.event_id
        h.source_arrival = self.source_arrival
        h.xi_bar = self.xi_bar + xi
        h.q_bar = self.q_bar + q
        h.avoid_drop = self.avoid_drop
        h.is_probe = self.is_probe
        h.path = self.path + (task,) if task else self.path
        return h

    def advance_in_place(self, xi: float, q: float, task: str = "") -> "EventHeader":
        """In-place variant of :meth:`advanced` for the common 1:1 case where
        the caller holds the only reference (no allocation at all)."""
        self.xi_bar += xi
        self.q_bar += q
        if task:
            self.path = self.path + (task,)
        return self


# Free-list pool for headers: ``advanced()`` is called once per event per task
# hop, which made header construction the single largest allocation site in
# the scenario engine.  The pool is bounded and purely an optimization —
# failing to release a header is always safe.
_HEADER_POOL: List[EventHeader] = []
_HEADER_POOL_MAX = 4096


def _acquire_header() -> EventHeader:
    if _HEADER_POOL:
        return _HEADER_POOL.pop()
    return EventHeader.__new__(EventHeader)


def release_header(header: Optional[EventHeader]) -> None:
    """Return a header to the pool.  Only call when the event is provably
    dead (dropped inside the runtime, or fully consumed at the sink)."""
    if header is not None and len(_HEADER_POOL) < _HEADER_POOL_MAX:
        _HEADER_POOL.append(header)


def source_header(event_id: int, source_arrival: float) -> EventHeader:
    """Pool-backed constructor for a fresh source-event header (the one
    allocation every sourced frame must make)."""
    h = _acquire_header()
    h.event_id = event_id
    h.source_arrival = source_arrival
    h.xi_bar = 0.0
    h.q_bar = 0.0
    h.avoid_drop = False
    h.is_probe = False
    h.path = ()
    return h


class Event:
    """A key-value event on a stream (paper §2.2.1).

    ``key`` is typically the camera ID; ``value`` the frame / detections.
    ``batch_slowest`` is set by the runtime on the slowest event of a batch
    so the sink can generate accept signals (§4.5.2).
    ``query_mask`` is the multi-query tenancy tag (``repro.query``): a bit
    per live tracking query interested in this event at source time.  0 (the
    default everywhere outside a multi-query run) means "untagged"; the
    runtime's 1:1 fast paths reuse the event object, so the tag survives
    value transforms without any per-hop copying.
    """

    __slots__ = ("header", "key", "value", "batch_slowest", "query_mask")

    def __init__(self, header: EventHeader, key: Any, value: Any = None) -> None:
        self.header = header
        self.key = key
        self.value = value
        self.batch_slowest = False
        self.query_mask = 0

    def __repr__(self) -> str:
        return f"Event(header={self.header!r}, key={self.key!r}, value={self.value!r})"

    @property
    def event_id(self) -> int:
        return self.header.event_id


@dataclass(slots=True)
class EventRecord:
    """The 3-tuple ``<d_k^i, q_k^i, m_k^i>`` each task stores per processed
    event (paper §4.5), used when an accept/reject signal arrives later.

    ``departure`` is ``d_k^i = u_k^i + pi_k^i``; ``queuing`` is ``q_k^i``;
    ``batch_size`` is ``m_k^i``; ``xi`` is ``xi_i(m_k^i)`` kept for the
    accept-side proportionality term.
    """

    departure: float
    queuing: float
    batch_size: int
    xi: float


@dataclass(slots=True)
class RejectSignal:
    """Sent upstream when task ``tau_j`` drops event ``k`` (paper §4.5.1)."""

    event_id: int
    epsilon: float  # excess over the dropping task's budget
    q_bar: float  # sum of queuing delays upstream of the dropping task
    from_task: str = ""


@dataclass(slots=True)
class AcceptSignal:
    """Sent upstream when the sink sees the slowest event of a batch arrive
    more than ``epsilon_max`` early (paper §4.5.2)."""

    event_id: int
    epsilon: float  # early-arrival margin under gamma
    xi_bar: float  # sum of upstream execution times (excluding sink)
    from_task: str = ""


@dataclass(slots=True)
class ProbeSignal:
    """Every k-th dropped event is forwarded as a probe that cannot be
    dropped; if it reaches the sink within gamma an accept is generated so
    collapsed budgets can recover (paper §4.5.2)."""

    event_id: int
    source_arrival: float
    xi_bar: float = 0.0
    q_bar: float = 0.0
