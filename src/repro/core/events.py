"""Event model for the Anveshak dataflow (paper §2.2, §4.2).

Every event entering a pipeline at the source task ``tau_1`` gets a unique ID
``k``; with 1:1 task selectivity, the pair ``(k, i)`` uniquely identifies the
causal event ``e_k^i`` input to task ``tau_i``.  Events carry a small header
with the *source arrival time* ``a_k^1`` (measured on the source clock) plus
the running sums of upstream execution time (``xi_bar``) and queuing delay
(``q_bar``) used by the budget-update protocol (paper §4.5).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Any, Optional

__all__ = [
    "EventHeader",
    "Event",
    "EventRecord",
    "RejectSignal",
    "AcceptSignal",
    "ProbeSignal",
    "new_event_id",
]

_id_counter = itertools.count()


def new_event_id() -> int:
    """Globally unique, monotonically increasing source-event ID ``k``."""
    return next(_id_counter)


@dataclass
class EventHeader:
    """Header propagated with every causal downstream event (paper §4.2, §4.5).

    Attributes
    ----------
    event_id:
        The source-event ID ``k``.
    source_arrival:
        ``a_k^1`` — the arrival time of the source event at the source task,
        measured on the *source device clock* kappa_1.  Propagated verbatim.
    xi_bar:
        ``sum_{j=1..i} xi_j(m_k^j)`` — total execution duration spent at the
        preceding tasks (durations; clock-skew free).
    q_bar:
        ``sum_{j=1..i} q_k^j`` — total queuing delay at the preceding tasks.
    avoid_drop:
        The user logic may flag an event (e.g. a positive detection) so the
        platform will not drop it even past its budget (paper §4.3.3).
    is_probe:
        Probe signals are forwarded downstream without drops to recover from
        budget collapse (paper §4.5.2).
    """

    event_id: int
    source_arrival: float
    xi_bar: float = 0.0
    q_bar: float = 0.0
    avoid_drop: bool = False
    is_probe: bool = False
    # The task-path this event has traversed (its *pipeline*, §4.2): signals
    # are delivered to the tasks on this path, not the whole dataflow DAG.
    path: tuple = ()

    def advanced(self, xi: float, q: float, task: str = "") -> "EventHeader":
        """Header for the causal downstream event after this task."""
        return replace(
            self,
            xi_bar=self.xi_bar + xi,
            q_bar=self.q_bar + q,
            path=self.path + (task,) if task else self.path,
        )


@dataclass
class Event:
    """A key-value event on a stream (paper §2.2.1).

    ``key`` is typically the camera ID; ``value`` the frame / detections.
    """

    header: EventHeader
    key: Any
    value: Any = None

    @property
    def event_id(self) -> int:
        return self.header.event_id


@dataclass
class EventRecord:
    """The 3-tuple ``<d_k^i, q_k^i, m_k^i>`` each task stores per processed
    event (paper §4.5), used when an accept/reject signal arrives later.

    ``departure`` is ``d_k^i = u_k^i + pi_k^i``; ``queuing`` is ``q_k^i``;
    ``batch_size`` is ``m_k^i``; ``xi`` is ``xi_i(m_k^i)`` kept for the
    accept-side proportionality term.
    """

    departure: float
    queuing: float
    batch_size: int
    xi: float


@dataclass
class RejectSignal:
    """Sent upstream when task ``tau_j`` drops event ``k`` (paper §4.5.1)."""

    event_id: int
    epsilon: float  # excess over the dropping task's budget
    q_bar: float  # sum of queuing delays upstream of the dropping task
    from_task: str = ""


@dataclass
class AcceptSignal:
    """Sent upstream when the sink sees the slowest event of a batch arrive
    more than ``epsilon_max`` early (paper §4.5.2)."""

    event_id: int
    epsilon: float  # early-arrival margin under gamma
    xi_bar: float  # sum of upstream execution times (excluding sink)
    from_task: str = ""


@dataclass
class ProbeSignal:
    """Every k-th dropped event is forwarded as a probe that cannot be
    dropped; if it reaches the sink within gamma an accept is generated so
    collapsed budgets can recover (paper §4.5.2)."""

    event_id: int
    source_arrival: float
    xi_bar: float = 0.0
    q_bar: float = 0.0
