"""Anveshak core: the paper's contribution (dataflow model + runtime tuning).

Layout mirrors the paper:

* §2  dataflow model  -> :mod:`repro.core.dataflow`, :mod:`repro.core.events`
* §2.2.4 tracking     -> :mod:`repro.core.tracking`, :mod:`repro.core.roadnet`
* §4.3 dropping       -> :mod:`repro.core.dropping`
* §4.4 batching       -> :mod:`repro.core.batching`
* §4.5 budgets        -> :mod:`repro.core.budget`
* §4.6 bounds/skew    -> :mod:`repro.core.bounds`, :mod:`repro.core.clock`
* §3  runtime         -> :mod:`repro.core.pipeline`
"""

from .batching import DynamicBatcher, NOBBatcher, PendingEvent, StaticBatcher, build_nob_table
from .bounds import (
    batching_latency_overhead,
    drop_rate,
    max_sustainable_rate,
    stable_batch_size,
)
from .budget import BudgetState, TaskBudget
from .clock import Clock
from .compile import (
    CompiledApp,
    DeploymentSpec,
    ResolvedModule,
    compile_app,
    linear_xi,
    resolve_module,
)
from .dataflow import ModuleSpec, TrackingApp, fc_frame_rate, fc_is_active, make_cr, make_va
from .dropping import drop_before_exec, drop_before_queuing, drop_before_transmit
from .events import (
    AcceptSignal,
    Event,
    EventHeader,
    EventRecord,
    ProbeSignal,
    RejectSignal,
    new_event_id,
)
from .pipeline import PipelineStats, Scheduler, SinkTask, Task
from .roadnet import RoadNetwork, make_road_network
from .tracking import Detection, TLBFS, TLBase, TLProbabilistic, TLWBFS, TrackingLogic

__all__ = [
    "AcceptSignal", "BudgetState", "Clock", "CompiledApp", "DeploymentSpec",
    "Detection", "DynamicBatcher", "Event", "EventHeader", "EventRecord",
    "ModuleSpec", "NOBBatcher", "PendingEvent", "PipelineStats",
    "ProbeSignal", "RejectSignal", "ResolvedModule", "RoadNetwork",
    "Scheduler", "SinkTask", "StaticBatcher", "TLBFS", "TLBase",
    "TLProbabilistic", "TLWBFS", "Task", "TaskBudget", "TrackingApp",
    "TrackingLogic", "batching_latency_overhead", "build_nob_table",
    "compile_app", "drop_before_exec", "drop_before_queuing",
    "drop_before_transmit", "drop_rate", "fc_frame_rate", "fc_is_active",
    "linear_xi", "make_cr", "make_road_network", "make_va",
    "max_sustainable_rate", "new_event_id", "resolve_module",
    "stable_batch_size",
]
