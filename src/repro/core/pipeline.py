"""Task pipeline runtime (paper §3, §4.2 Fig. 4).

A pipeline is a DAG of :class:`Task` instances.  Each task owns a FIFO input
queue, a batcher (dynamic/static/NOB), a :class:`TaskBudget`, a cost model
``xi(b)``, a user logic callable and a partitioner that routes each output
event to a downstream task instance.  Pipelines are normally not wired by
hand: the app compiler (:mod:`repro.core.compile`) lowers a
:class:`~repro.core.dataflow.TrackingApp` onto this runtime.  Execution is single-server per task
(one batch at a time), matching one Executor process per module instance in
Anveshak.

The runtime is driven by a discrete-event scheduler (``sim``) that provides
``now`` (true time) and ``schedule(delay, fn, *args)``; each task reads time
through its own skewed :class:`Clock`, so the clock-skew resilience of the
drop / batch / budget logic (§4.6.2) is exercised for real.

Event life-cycle inside a task (Fig. 4):

    arrival --DP1--> queue --batcher--> batch --DP2--> execute --DP3-->
      partition --> transmit(network delay) --> downstream.on_arrival

Reject signals flow to *all upstream* tasks of the pipeline path; accept
signals originate at the sink for the slowest event of a batch arriving more
than ``epsilon_max`` early.  Probe events (every ``probe_every``-th drop) are
forwarded un-droppably to let collapsed budgets recover (§4.5.2).

Hot-path notes: this module runs ~10 times per source event in a full
scenario, so it avoids per-event closures (``schedule`` takes ``(fn, *args)``
instead), advances headers in place for the common 1:1-selectivity case, and
keeps the per-event bookkeeping (``_event_downstream``) in a bounded LRU so a
long run cannot grow memory without bound.
"""

from __future__ import annotations

import math
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

from .batching import DynamicBatcher, PendingEvent, StaticBatcher, _BatcherBase
from .budget import TaskBudget
from .clock import Clock
from .dropping import drop_before_exec, drop_before_queuing, drop_before_transmit
from .events import (
    AcceptSignal,
    Event,
    EventHeader,
    EventRecord,
    RejectSignal,
    release_header,
)

__all__ = ["Task", "SinkTask", "PipelineStats", "STAT_FIELDS", "Scheduler", "DP_FAULT"]

#: Drop-point index for fault losses (crashed host, exhausted retries across
#: a partition) — the fourth drop class next to DP1/DP2/DP3.  Charged through
#: the same ``on_drop_hook`` so per-query accounting reconciles exactly, but
#: it is *not* a §4.3 deadline decision: no reject signal, no probe.
DP_FAULT = 4

UserLogic = Callable[[List[Event], Dict[str, Any]], List[Event]]
Partitioner = Callable[[Event], str]


class Scheduler:
    """Protocol the tasks expect from the discrete-event engine."""

    @property
    def time(self) -> float:  # pragma: no cover - interface
        raise NotImplementedError

    def schedule(self, delay: float, fn: Callable[..., None], *args: Any) -> None:  # pragma: no cover
        raise NotImplementedError

    def transit_delay(self, src: str, dst: str, size_bytes: float) -> float:
        return 0.0

    # Task registry (name -> Task) for path-based signal delivery (§4.3.4).
    tasks: Dict[str, "Task"] = {}


@dataclass(slots=True)
class PipelineStats:
    """Counters a task accumulates (drives the §5 analyses)."""

    arrived: int = 0
    dropped_dp1: int = 0
    dropped_dp2: int = 0
    dropped_dp3: int = 0
    executed: int = 0
    batches: int = 0
    # Signal-plane counters (cold path: drops/signals only) sampled by the
    # dynamism telemetry alongside the drop points.
    probes: int = 0
    accepts_rx: int = 0
    rejects_rx: int = 0
    # Fault losses (DP_FAULT): events lost to a crashed host or to retries
    # exhausted across a partition.  Deliberately *not* in STAT_FIELDS — the
    # dynamism trace digests its columns, and fault losses are a different
    # phenomenon from the §4.3 deadline drops it tracks.
    dropped_fault: int = 0
    batch_sizes: List[int] = field(default_factory=list)

    @property
    def dropped(self) -> int:
        return (
            self.dropped_dp1
            + self.dropped_dp2
            + self.dropped_dp3
            + self.dropped_fault
        )


#: Telemetry field -> PipelineStats attribute for the cumulative counters a
#: dynamism trace samples per task.  Lives next to PipelineStats so the
#: per-task, aggregate (``FC*``) and serving
#: (:meth:`repro.serving.scheduler.ServedStage.telemetry`) rows share one
#: mapping without the serving plane importing the sim package.
STAT_FIELDS = (
    ("dp1", "dropped_dp1"),
    ("dp2", "dropped_dp2"),
    ("dp3", "dropped_dp3"),
    ("probes", "probes"),
    ("accepts", "accepts_rx"),
    ("rejects", "rejects_rx"),
    ("batches", "batches"),
    ("executed", "executed"),
)


class Task:
    """One module instance (Executor) in the dataflow."""

    # Bounded size of the event-id -> downstream-name map used to attribute
    # late accept/reject signals (§4.3.4).  One entry per routed event was an
    # unbounded leak; signals for evicted (old) events are safely ignored
    # because budget updates clamp against ``beta_old``.
    EVENT_DOWNSTREAM_CAPACITY = 8192

    def __init__(
        self,
        name: str,
        sim: Scheduler,
        xi: Callable[[int], float],
        batcher: _BatcherBase,
        *,
        logic: Optional[UserLogic] = None,
        clock: Optional[Clock] = None,
        budget: Optional[TaskBudget] = None,
        partitioner: Optional[Partitioner] = None,
        drops_enabled: bool = True,
        probe_every: int = 16,
        node: str = "",
    ) -> None:
        self.name = name
        self.sim = sim
        self.xi = xi
        self.batcher = batcher
        self.logic = logic or (lambda events, state: list(events))
        self.clock = clock or Clock()
        self.budget = budget or TaskBudget(name, xi, m_max=getattr(batcher, "m_max", 25))
        self.partitioner = partitioner or (lambda ev: next(iter(self.downstream)))
        self.drops_enabled = drops_enabled
        self.probe_every = int(probe_every)
        self.node = node or name
        # Which dataflow module type this task lowers (FC/VA/CR/UV, set by
        # the app compiler); empty for hand-wired tasks.
        self.module: str = ""
        self.state: Dict[str, Any] = {}
        self.downstream: Dict[str, "Task"] = {}
        self.upstream: List["Task"] = []
        self.stats = PipelineStats()
        self._drop_count = 0
        self._busy = False
        self._run_queue: Deque[List[PendingEvent]] = deque()
        self._event_downstream: "OrderedDict[int, str]" = OrderedDict()
        self._timer_pending = False
        self._upstream_cache = None
        self._batcher_is_dynamic = isinstance(batcher, DynamicBatcher)
        self._batcher_is_static = type(batcher) is StaticBatcher
        # Streaming tasks (static batch of 1) skip the batcher entirely:
        # every arrival is its own batch, so ``offer``/timer bookkeeping is
        # pure overhead for them (FC sources are all in this regime).
        self._streaming = (
            isinstance(batcher, StaticBatcher) and getattr(batcher, "batch_size", 0) == 1
        )
        # Dynamism plane: optional (host, t) -> duration multiplier applied
        # to *actual* execution time (never to the xi estimates the drop /
        # batching decisions use — stragglers are unannounced).  None in
        # every undisturbed run: the hot path pays one attribute test.
        self._xi_mult = getattr(sim, "xi_multiplier", None)
        # Fault plane (repro.sim.dynamism.FaultPlane) snapshotted like the
        # xi multiplier: None in every undisturbed run, so healthy transmits
        # pay one attribute test.  When present, every inter-task send goes
        # through the fault-checked `_send` path (timeout + retry + loss).
        self._faults = getattr(sim, "faults", None)
        # Fused streaming (opt-in, see ``fuse_streaming``): collapse the
        # execute->transmit pair into a single scheduled downstream arrival.
        self.fuse_streaming = False
        # Multi-query tenancy (repro.query): optional observer invoked once
        # per dropped event as ``hook(ev, point, epsilon)`` with the drop
        # point (1/2/3) — lets the query plane charge a drop to every query
        # tagged on the event *before* the header is recycled.  None (the
        # default) costs a single attribute test on the drop cold path only.
        self.on_drop_hook: Optional[Callable[[Event, int, float], None]] = None
        # Observability plane (repro.obs.tracing): duck-typed span tracer,
        # installed via ``CompiledApp.install_tracer``.  None in every
        # untraced run — arrivals pay a single attribute test, and the
        # pipeline never imports repro.obs.
        self.tracer = None
        self._xi1 = xi(1)
        self._busy_until = -math.inf
        self._drain_pending = False
        # dst_name -> fixed transit delay, populated only while the
        # scheduler reports a time-invariant network (``transit_is_static``).
        self._transit_memo: Dict[str, float] = {}
        # Event sizes for network modelling: bytes per event leaving this task.
        self.output_event_bytes: float = 2900.0  # paper: 2.9 kB median JPG
        if not hasattr(sim, "tasks") or sim.tasks is Scheduler.tasks:
            sim.tasks = {}
        sim.tasks[name] = self

    # ------------------------------------------------------------------ #
    # Wiring                                                             #
    # ------------------------------------------------------------------ #
    def connect(self, downstream: "Task") -> "Task":
        self.downstream[downstream.name] = downstream
        downstream.upstream.append(self)
        downstream._upstream_cache = None
        return downstream

    def upstream_chain(self) -> List["Task"]:
        """All transitive upstream tasks (fallback when an event carries no
        path); cached, set-deduplicated."""
        if getattr(self, "_upstream_cache", None) is not None:
            return self._upstream_cache
        seen: Dict[int, Task] = {}
        frontier = list(self.upstream)
        while frontier:
            t = frontier.pop()
            if id(t) not in seen:
                seen[id(t)] = t
                frontier.extend(t.upstream)
        self._upstream_cache = list(seen.values())
        return self._upstream_cache

    def _path_tasks(self, path) -> List["Task"]:
        """Tasks along an event's traversed path (its pipeline, §4.2)."""
        if not path:
            return self.upstream_chain()
        reg = getattr(self.sim, "tasks", {})
        return [reg[n] for n in path if n in reg and reg[n] is not self]

    # ------------------------------------------------------------------ #
    # Arrival + drop point 1                                             #
    # ------------------------------------------------------------------ #
    def on_arrival(self, ev: Event) -> None:
        now_local = self.sim.time + self.clock.skew
        self.stats.arrived += 1
        header = ev.header
        if self.tracer is not None:
            self.tracer.on_arrival(self, header, self.sim.time)
        if not self.drops_enabled and (
            self._streaming
            # Budget-less dynamic batching is the paper's bootstrap regime:
            # batch size pinned to 1 (§4.5), i.e. streaming as well.
            or (self._batcher_is_dynamic and not self.batcher._current)
        ):
            # Streaming fast path: the event is immediately its own batch.
            busy = self._busy or now_local < self._busy_until
            if not busy:
                exec_dur = self._xi1
                if self._xi_mult is not None:
                    exec_dur *= self._xi_mult(self.node, self.sim.time)
                if self.fuse_streaming:
                    # Fused: run the logic now, mark the server busy for
                    # xi(1), and schedule the downstream arrival directly at
                    # exec-end + transit — one heap event instead of two.
                    # (Only enabled by callers whose logic may read state at
                    # arrival rather than completion time; identical whenever
                    # control updates are slower than xi(1).)
                    self._busy_until = now_local + exec_dur
                    # depart_at is absolute *simulation* time: durations are
                    # skew-free but now_local carries the device skew.
                    self._finish_streaming(
                        ev, now_local, exec_dur, depart_at=self.sim.time + exec_dur
                    )
                    return
                self._busy = True
                self.sim.schedule(exec_dur, self._finish_streaming_event, ev, now_local, exec_dur)
                return
            self._run_queue.append(
                [PendingEvent(event=ev, arrival=now_local, deadline=math.inf)]
            )
            if not self._busy and not self._drain_pending:
                # Busy via a fused execution that has no completion callback:
                # arrange a drain at its end.
                self._drain_pending = True
                self.sim.schedule(self._busy_until - now_local, self._drain_fused)
            return
        if self.drops_enabled:
            beta = self.budget.min_budget()
            if drop_before_queuing(
                header.source_arrival,
                now_local,
                self.xi(1),
                beta,
                avoid_drop=header.avoid_drop or header.is_probe,
            ):
                self.stats.dropped_dp1 += 1
                u = now_local - header.source_arrival
                self._on_drop(ev, epsilon=u + self.xi(1) - beta, point=1)
                return
            deadline = header.source_arrival + beta
        else:
            beta = math.inf
            deadline = math.inf
        pe = PendingEvent(event=ev, arrival=now_local, deadline=deadline)
        # Bootstrap (§4.5): until a budget is assigned the deadline is
        # unbounded; the paper fixes the batch size at b=1 in that regime so
        # dynamic batches cannot grow without an auto-submit deadline.
        if beta == math.inf and self._batcher_is_dynamic:
            open_batch = self.batcher.take() if self.batcher.current_size else []
            if open_batch:
                self._enqueue_batch(open_batch)
            self._enqueue_batch([pe])
            return
        if self._batcher_is_static:
            # Inline StaticBatcher.offer: append, submit when full.
            batcher = self.batcher
            cur = batcher._current
            cur.append(pe)
            if len(cur) >= batcher.batch_size:
                batcher._current = []
                self._enqueue_batch(cur)
            return
        submitted = self.batcher.offer(pe, now_local)
        if submitted:
            self._enqueue_batch(submitted)
        if self._batcher_is_dynamic:
            self._arm_timer()

    def _arm_timer(self) -> None:
        """Auto-submit the open batch at ``Delta_p - xi(m)`` (§4.4)."""
        if self._timer_pending:
            return
        due = self.batcher.next_due_time()
        if math.isinf(due):
            return
        self._timer_pending = True
        delay = max(due - self.clock.now(self.sim.time), 0.0)
        self.sim.schedule(delay, self._timer_fire)

    def _timer_fire(self) -> None:
        self._timer_pending = False
        batch = self.batcher.flush_if_due(self.clock.now(self.sim.time))
        if batch:
            self._enqueue_batch(batch)
        self._arm_timer()

    # ------------------------------------------------------------------ #
    # Execution: drop point 2, run, drop point 3                         #
    # ------------------------------------------------------------------ #
    def _enqueue_batch(self, batch: List[PendingEvent]) -> None:
        self._run_queue.append(batch)
        self._maybe_run()

    def _maybe_run(self) -> None:
        # Iterative (not mutually recursive with the finish callback): a long
        # run-queue of fully-dropped batches must not hit the recursion limit.
        if self._busy:
            return
        rq = self._run_queue
        while rq:
            batch = rq.popleft()
            now_local = self.sim.time + self.clock.skew
            if self.drops_enabled:
                b = len(batch)
                xi_b = self.xi(b)
                beta = self.budget.min_budget()
                tuples = [
                    (pe.event.header.source_arrival, pe.arrival, now_local - pe.arrival, pe.event)
                    for pe in batch
                ]
                retained_evs, dropped_evs = drop_before_exec(tuples, xi_b, beta)
                if dropped_evs:
                    pe_by_id = {pe.event.header.event_id: pe for pe in batch}
                    for ev in dropped_evs:
                        self.stats.dropped_dp2 += 1
                        pe = pe_by_id[ev.header.event_id]
                        u = pe.arrival - ev.header.source_arrival
                        q = now_local - pe.arrival
                        self._on_drop(ev, epsilon=u + q + xi_b - beta, point=2)
                    if not retained_evs:
                        continue
                    retained_pes = [pe_by_id[ev.header.event_id] for ev in retained_evs]
                else:
                    retained_pes = batch
            else:
                retained_pes = batch
            exec_dur = self.xi(len(retained_pes))
            if self._xi_mult is not None:
                exec_dur *= self._xi_mult(self.node, self.sim.time)
            self._busy = True
            self.sim.schedule(exec_dur, self._finish_and_continue, retained_pes, now_local, exec_dur)
            return

    def _finish_and_continue(
        self, batch: List[PendingEvent], exec_start: float, exec_dur: float
    ) -> None:
        self._finish_batch(batch, exec_start=exec_start, exec_dur=exec_dur)
        self._busy = False
        self._maybe_run()

    def _finish_streaming_event(self, ev: Event, arrival: float, exec_dur: float) -> None:
        self._finish_streaming(ev, arrival, exec_dur)
        self._busy = False
        self._maybe_run()

    def _drain_fused(self) -> None:
        self._drain_pending = False
        self._maybe_run()

    def _deliver_many(self, evs: List[Event]) -> None:
        """Arrival of a grouped same-destination transit (drops-off path)."""
        if (
            self._batcher_is_static
            and not self.drops_enabled
            and not self._streaming
            and self.tracer is None
        ):
            # Bulk arrival: replicate per-event on_arrival + StaticBatcher
            # offer without the per-event call overhead.  A tracer needs the
            # per-event path so every hop is observed.
            now_local = self.sim.time + self.clock.skew
            self.stats.arrived += len(evs)
            batcher = self.batcher
            cur = batcher._current
            size = batcher.batch_size
            inf = math.inf
            for ev in evs:
                cur.append(PendingEvent(event=ev, arrival=now_local, deadline=inf))
                if len(cur) >= size:
                    batcher._current = []
                    self._enqueue_batch(cur)
                    cur = batcher._current
            return
        arrive = self.on_arrival
        for ev in evs:
            arrive(ev)

    def _finish_streaming(
        self, ev: Event, arrival: float, exec_dur: float, depart_at: Optional[float] = None
    ) -> None:
        """Completion for the streaming (b=1, started-immediately) fast path:
        ``exec_start == arrival`` so ``q == 0`` exactly, and the single event
        is trivially its batch's slowest.

        Precondition: only reachable with ``drops_enabled`` False (both call
        sites gate on it), so budget records and path propagation — which
        exist solely for the drop/budget signal machinery — are skipped.
        """
        stats = self.stats
        stats.batches += 1
        stats.batch_sizes.append(1)
        h = ev.header
        outputs = self.logic([ev], self.state)
        u = arrival - h.source_arrival
        pi = 0.0 + exec_dur
        stats.executed += 1
        if len(outputs) == 1 and outputs[0].header is h:
            out = outputs[0]
            h.xi_bar += exec_dur
            out.batch_slowest = True
            self._route(out, u=u, pi=pi, depart_at=depart_at)
        else:
            outs = [o for o in outputs if o.header.event_id == h.event_id]
            sole = len(outs) == 1
            for out in outs:
                if sole and out.header is h:
                    out.header = h.advance_in_place(xi=exec_dur, q=0.0, task="")
                else:
                    out.header = h.advanced(xi=exec_dur, q=0.0, task="")
                out.batch_slowest = True
                self._route(out, u=u, pi=pi, depart_at=depart_at)

    def _finish_batch(
        self, batch: List[PendingEvent], exec_start: float, exec_dur: float
    ) -> None:
        stats = self.stats
        stats.batches += 1
        m = len(batch)
        stats.batch_sizes.append(m)
        if m == 1 and not batch[0].event.header.is_probe:
            # Single-event batch (streaming FCs, b=1 configs): it is trivially
            # the slowest of its batch; skip the generic passes.
            pe = batch[0]
            ev = pe.event
            h = ev.header
            outputs = self.logic([ev], self.state)
            u = pe.arrival - h.source_arrival
            q = exec_start - pe.arrival
            pi = q + exec_dur
            stats.executed += 1
            if self.drops_enabled:
                self.budget.record(
                    h.event_id,
                    EventRecord(departure=u + pi, queuing=q, batch_size=1, xi=exec_dur),
                )
            task = self.name if self.drops_enabled else ""
            if len(outputs) == 1 and outputs[0].header is h:
                out = outputs[0]
                h.xi_bar += exec_dur
                h.q_bar += q
                if task:
                    h.path = h.path + (task,)
                out.batch_slowest = True
                self._route(out, u=u, pi=pi)
            else:
                # Same contract as the general path: only outputs causally
                # tied to the input event (same id) are routed.
                outs = [o for o in outputs if o.header.event_id == h.event_id]
                sole = len(outs) == 1
                for out in outs:
                    if sole and out.header is h:
                        out.header = h.advance_in_place(xi=exec_dur, q=q, task=task)
                    else:
                        out.header = h.advanced(xi=exec_dur, q=q, task=task)
                    out.batch_slowest = True
                    self._route(out, u=u, pi=pi)
            return
        probes: List[Event] = []
        work: List[Event] = []
        for pe in batch:
            (probes if pe.event.header.is_probe else work).append(pe.event)
        outputs = self.logic(work, self.state)
        if probes:
            outputs = list(outputs) + probes
        # Track the slowest event of the batch for the sink's accept logic.
        slowest_id, slowest_d = None, -math.inf
        for pe in batch:
            h = pe.event.header
            u = pe.arrival - h.source_arrival
            q = exec_start - pe.arrival
            pi = q + exec_dur
            d = u + pi
            if d > slowest_d:
                slowest_d, slowest_id = d, h.event_id
        # Fast path: 1:1 selectivity with pass-through headers (the common
        # case — identity logics and per-event transforms that reuse the
        # incoming header object).  Headers advance in place: no allocation.
        paired = not probes and len(outputs) == m
        if paired:
            for out, pe in zip(outputs, batch):
                if out.header is not pe.event.header:
                    paired = False
                    break
        keep_records = self.drops_enabled
        budget_record = self.budget.record
        if paired and not keep_records and self.downstream and self._faults is None:
            # Drops-off fast path: no DP3, no records, and every output to
            # the same destination shares one transit — deliver each
            # destination's events with a single scheduled callback instead
            # of one heap event per event.
            partition = self.partitioner
            groups: Dict[str, List[Event]] = {}
            for out, pe in zip(outputs, batch):
                h = out.header
                q = exec_start - pe.arrival
                stats.executed += 1
                h.xi_bar += exec_dur
                h.q_bar += q
                if h.event_id == slowest_id:
                    out.batch_slowest = True
                dst_name = partition(out)
                g = groups.get(dst_name)
                if g is None:
                    groups[dst_name] = [out]
                else:
                    g.append(out)
            memo = self._transit_memo
            sim = self.sim
            static = getattr(sim, "transit_is_static", False)
            if memo and not static:
                memo.clear()  # network turned dynamic: cached delays are stale
            for dst_name, evs in groups.items():
                dst = self.downstream[dst_name]
                delay = memo.get(dst_name) if static else None
                if delay is None:
                    delay = sim.transit_delay(self.node, dst.node, self.output_event_bytes)
                    if static:
                        memo[dst_name] = delay
                sim.schedule(delay, dst._deliver_many, evs)
            return
        if paired:
            name = self.name if keep_records else ""
            route = self._route
            for out, pe in zip(outputs, batch):
                h = out.header
                u = pe.arrival - h.source_arrival
                q = exec_start - pe.arrival
                pi = q + exec_dur
                stats.executed += 1
                eid = h.event_id
                if keep_records:
                    budget_record(
                        eid, EventRecord(departure=u + pi, queuing=q, batch_size=m, xi=exec_dur)
                    )
                h.xi_bar += exec_dur
                h.q_bar += q
                if name:
                    h.path = h.path + (name,)
                if eid == slowest_id:
                    out.batch_slowest = True
                route(out, u=u, pi=pi)
            return
        out_by_id: Dict[int, List[Event]] = {}
        for out in outputs:
            out_by_id.setdefault(out.header.event_id, []).append(out)
        for pe in batch:
            ev = pe.event
            h = ev.header
            u = pe.arrival - h.source_arrival
            q = exec_start - pe.arrival
            pi = q + exec_dur
            stats.executed += 1
            if keep_records:
                budget_record(
                    h.event_id,
                    EventRecord(departure=u + pi, queuing=q, batch_size=m, xi=exec_dur),
                )
            outs = out_by_id.get(h.event_id, ())
            sole = len(outs) == 1
            task = self.name if keep_records else ""
            for out in outs:
                if sole and out.header is h:
                    out.header = h.advance_in_place(xi=exec_dur, q=q, task=task)
                else:
                    out.header = h.advanced(xi=exec_dur, q=q, task=task)
                if h.event_id == slowest_id:
                    out.batch_slowest = True
                self._route(out, u=u, pi=pi)

    def _route(
        self, ev: Event, u: float, pi: float, depart_at: Optional[float] = None
    ) -> None:
        if not self.downstream:
            return
        dst_name = self.partitioner(ev)
        dst = self.downstream[dst_name]
        if self.drops_enabled:
            # Remember where the event went so a late signal updates the
            # right per-downstream budget (only consulted when drops are on).
            eds = self._event_downstream
            eds[ev.header.event_id] = dst_name
            if len(eds) > self.EVENT_DOWNSTREAM_CAPACITY:
                eds.popitem(last=False)
            beta = self.budget.budget(dst_name)
            # DP3 test is u + pi > beta (§4.3.3); express via
            # drop_before_transmit with arrival reconstructed so that
            # arrival - source_arrival == u.
            if drop_before_transmit(
                0.0,
                u,
                pi,
                beta,
                avoid_drop=ev.header.avoid_drop or ev.header.is_probe,
            ):
                self.stats.dropped_dp3 += 1
                self._on_drop(ev, epsilon=u + pi - beta, downstream=dst_name, point=3)
                return
        if self._faults is not None:
            # Fault plane installed: every inter-task send is fault-checked
            # (src/dst liveness, partition, timeout + retry).  fuse_streaming
            # is never compiled in under faults, so depart_at is None here.
            self._send(dst, ev)
            return
        static = getattr(self.sim, "transit_is_static", False)
        delay = self._transit_memo.get(dst_name) if static else None
        if delay is None:
            if not static and self._transit_memo:
                self._transit_memo.clear()  # network turned dynamic mid-run
            delay = self.sim.transit_delay(self.node, dst.node, self.output_event_bytes)
            if static:
                self._transit_memo[dst_name] = delay
        if depart_at is None:
            self.sim.schedule(delay, dst.on_arrival, ev)
        else:
            # Fused streaming: the event departs at exec-end; the arrival
            # time (depart_at + delay) matches the unfused two-hop float
            # arithmetic exactly.
            self.sim.schedule_at(depart_at + delay, dst.on_arrival, ev)

    # ------------------------------------------------------------------ #
    # Fault-checked transmit (fault plane)                               #
    # ------------------------------------------------------------------ #
    def _send(self, dst: "Task", ev: Event, attempt: int = 0) -> None:
        """Transmit under a fault plane: a dead sender loses its output
        outright; a dead destination or a partitioned link times out and
        retries with seeded capped exponential backoff until
        ``max_retries``, after which the event is charged as ``dp_fault``."""
        fp = self._faults
        sim = self.sim
        now = sim.time
        if fp.host_down(self.node, now):
            # The sending host is inside a crash window: anything it was
            # holding (including a just-finished batch's outputs) is lost.
            self._fault_drop(ev)
            return
        if fp.send_blocked(self.node, dst.node, now):
            if attempt >= fp.retry.max_retries:
                self._fault_drop(ev)
                return
            fp.sends_blocked += 1
            fp.retries += 1
            if self.tracer is not None:
                self.tracer.on_retry(self, ev.header, now, attempt)
            sim.schedule(fp.retry_delay(attempt), self._send, dst, ev, attempt + 1)
            return
        delay = sim.transit_delay(self.node, dst.node, self.output_event_bytes)
        sim.schedule(delay, self._arrive_checked, dst, ev)

    def _arrive_checked(self, dst: "Task", ev: Event) -> None:
        """Delivery completion under a fault plane: a destination that died
        while the event was in transit loses it (in-flight loss)."""
        fp = self._faults
        if fp is not None and fp.host_down(dst.node, self.sim.time):
            dst._fault_drop(ev)
            return
        dst.on_arrival(ev)

    def _fault_drop(self, ev: Event) -> None:
        """Charge an event lost to a fault (crashed host, partition retries
        exhausted) as the ``dp_fault`` class.  Unlike the §4.3 drop points
        this is not a deadline decision: the query-plane hook still fires
        (point ``DP_FAULT``) so per-query books reconcile exactly, but no
        reject signal is sent — a fault says nothing about budgets — and no
        probe is re-injected."""
        header = ev.header
        if header is None:
            return  # already accounted (defensive: double flush)
        self.stats.dropped_fault += 1
        fp = self._faults
        if fp is not None:
            fp.fault_drops += 1
        hook = self.on_drop_hook
        if hook is not None:
            hook(ev, DP_FAULT, 0.0)
        if self.tracer is not None:
            self.tracer.on_drop(self, header, self.sim.time, DP_FAULT, 0.0)
        ev.header = None  # type: ignore[assignment]
        release_header(header)

    # ------------------------------------------------------------------ #
    # Signals (§4.5)                                                     #
    # ------------------------------------------------------------------ #
    def _on_drop(
        self, ev: Event, epsilon: float, downstream: str = "", point: int = 0
    ) -> None:
        self._drop_count += 1
        header = ev.header
        hook = self.on_drop_hook
        if hook is not None:
            # Fire while the event (and its header) is still intact; the
            # hook must not retain either — the header is recycled below.
            hook(ev, point, epsilon)
        if self.tracer is not None:
            # Drop causality as a span event (the span ends here).
            self.tracer.on_drop(self, header, self.sim.time, point, epsilon)
        sig = RejectSignal(
            event_id=header.event_id,
            epsilon=max(epsilon, 0.0),
            q_bar=header.q_bar,
            from_task=self.name,
        )
        for up in self._path_tasks(header.path):
            up.receive_reject(sig)
        # Probe every k-th dropped event: re-inject it as un-droppable so it
        # traverses the NORMAL path (including this task's own executor) —
        # each task along the way then has an event record for the accept
        # signal to act on, which is what lets a collapsed budget recover
        # (§4.5.2).
        if self.probe_every > 0 and self._drop_count % self.probe_every == 0:
            self.stats.probes += 1
            probe = Event(
                header=EventHeader(
                    event_id=header.event_id,
                    source_arrival=header.source_arrival,
                    xi_bar=header.xi_bar,
                    q_bar=header.q_bar,
                    is_probe=True,
                    path=header.path,
                ),
                key=ev.key,
                value=ev.value,
            )
            self.sim.schedule(0.0, self.on_arrival, probe)
        # The event dies here; its header can be recycled (see events.py).
        ev.header = None  # type: ignore[assignment]
        release_header(header)

    def receive_reject(self, sig: RejectSignal) -> None:
        self.stats.rejects_rx += 1
        downstream = self._event_downstream.get(sig.event_id, "")
        self.budget.on_reject(sig, downstream=downstream)

    def receive_accept(self, sig: AcceptSignal) -> None:
        self.stats.accepts_rx += 1
        downstream = self._event_downstream.get(sig.event_id, "")
        self.budget.on_accept(sig, downstream=downstream)


class SinkTask(Task):
    """The pipeline sink (UV): measures end-to-end latency, generates accept
    signals, and feeds detections to the TL callback."""

    def __init__(
        self,
        name: str,
        sim: Scheduler,
        gamma: float,
        *,
        epsilon_max: float = 1.0,
        on_event: Optional[Callable[[Event, float], None]] = None,
        clock: Optional[Clock] = None,
        node: str = "",
        learn_budgets: bool = True,
        recycle_headers: bool = False,
    ) -> None:
        super().__init__(
            name,
            sim,
            xi=lambda b: 0.0,
            batcher=DynamicBatcher(lambda b: 0.0, m_max=1),
            clock=clock,
            drops_enabled=False,
            node=node,
        )
        self.gamma = float(gamma)
        self.epsilon_max = float(epsilon_max)
        self.on_event = on_event
        # Accept signals exist to raise upstream completion budgets; when the
        # whole pipeline runs with drops disabled the budgets are never
        # consulted, so the scenario can turn signal generation off.
        self.learn_budgets = bool(learn_budgets)
        # Header recycling is an opt-in for owners whose ``on_event`` callback
        # provably does not retain the event (or its header): a retained
        # header would be overwritten when the pool reuses it.
        self.recycle_headers = bool(recycle_headers)
        self.latencies: List[Tuple[float, float]] = []  # (t_now, latency)
        self.delayed: int = 0
        self.on_time: int = 0
        #: Probe events that completed the full path to the sink (§4.5.2);
        #: reconciled against the tasks' emitted-probe counters by the
        #: pipeline invariant tests.
        self.probes_seen: int = 0
        self.budget.set_budget(self.gamma)

    def on_arrival(self, ev: Event) -> None:  # overrides Task
        now_local = self.sim.time + self.clock.skew
        self.stats.arrived += 1
        header = ev.header
        u = now_local - header.source_arrival  # kappa_1 == kappa_n (§4.6.2)
        if header.is_probe:
            self.probes_seen += 1
            if u <= self.gamma and self.learn_budgets:
                self._send_accept(ev, epsilon=self.gamma - u)
            return
        self.latencies.append((now_local, u))
        tr = self.tracer
        if tr is not None:
            # Terminal hop + span completion with the end-to-end latency.
            tr.on_arrival(self, header, self.sim.time)
            tr.on_sink(self, header, self.sim.time, u)
        if u <= self.gamma:
            self.on_time += 1
        else:
            self.delayed += 1
        # Accept only on the slowest event of an upstream batch (§4.5.2).
        if ev.batch_slowest and self.learn_budgets:
            epsilon = self.gamma - u
            if epsilon > self.epsilon_max:
                self._send_accept(ev, epsilon=epsilon)
        if self.on_event is not None:
            self.on_event(ev, now_local)
        # Flow ends here.  Recycling is only safe when the sink owner opted
        # in (``recycle_headers``): a user callback may have retained the
        # event, and we cannot detect that here.
        if self.recycle_headers and ev.header is header:
            ev.header = None  # type: ignore[assignment]
            release_header(header)

    def _send_accept(self, ev: Event, epsilon: float) -> None:
        sig = AcceptSignal(
            event_id=ev.header.event_id,
            epsilon=epsilon,
            xi_bar=ev.header.xi_bar,
            from_task=self.name,
        )
        for up in self._path_tasks(ev.header.path):
            up.receive_accept(sig)
