"""Task pipeline runtime (paper §3, §4.2 Fig. 4).

A pipeline is a DAG of :class:`Task` instances.  Each task owns a FIFO input
queue, a batcher (dynamic/static/NOB), a :class:`TaskBudget`, a cost model
``xi(b)``, a user logic callable and a partitioner that routes each output
event to a downstream task instance.  Execution is single-server per task
(one batch at a time), matching one Executor process per module instance in
Anveshak.

The runtime is driven by a discrete-event scheduler (``sim``) that provides
``now`` (true time) and ``schedule(delay, fn)``; each task reads time through
its own skewed :class:`Clock`, so the clock-skew resilience of the drop /
batch / budget logic (§4.6.2) is exercised for real.

Event life-cycle inside a task (Fig. 4):

    arrival --DP1--> queue --batcher--> batch --DP2--> execute --DP3-->
      partition --> transmit(network delay) --> downstream.on_arrival

Reject signals flow to *all upstream* tasks of the pipeline path; accept
signals originate at the sink for the slowest event of a batch arriving more
than ``epsilon_max`` early.  Probe events (every ``probe_every``-th drop) are
forwarded un-droppably to let collapsed budgets recover (§4.5.2).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .batching import DynamicBatcher, PendingEvent, _BatcherBase
from .budget import TaskBudget
from .clock import Clock
from .dropping import drop_before_exec, drop_before_queuing, drop_before_transmit
from .events import (
    AcceptSignal,
    Event,
    EventHeader,
    EventRecord,
    RejectSignal,
)

__all__ = ["Task", "SinkTask", "PipelineStats", "Scheduler"]

UserLogic = Callable[[List[Event], Dict[str, Any]], List[Event]]
Partitioner = Callable[[Event], str]


class Scheduler:
    """Protocol the tasks expect from the discrete-event engine."""

    @property
    def time(self) -> float:  # pragma: no cover - interface
        raise NotImplementedError

    def schedule(self, delay: float, fn: Callable[[], None]) -> None:  # pragma: no cover
        raise NotImplementedError

    def transit_delay(self, src: str, dst: str, size_bytes: float) -> float:
        return 0.0

    # Task registry (name -> Task) for path-based signal delivery (§4.3.4).
    tasks: Dict[str, "Task"] = {}


@dataclass
class PipelineStats:
    """Counters a task accumulates (drives the §5 analyses)."""

    arrived: int = 0
    dropped_dp1: int = 0
    dropped_dp2: int = 0
    dropped_dp3: int = 0
    executed: int = 0
    batches: int = 0
    batch_sizes: List[int] = field(default_factory=list)

    @property
    def dropped(self) -> int:
        return self.dropped_dp1 + self.dropped_dp2 + self.dropped_dp3


class Task:
    """One module instance (Executor) in the dataflow."""

    def __init__(
        self,
        name: str,
        sim: Scheduler,
        xi: Callable[[int], float],
        batcher: _BatcherBase,
        *,
        logic: Optional[UserLogic] = None,
        clock: Optional[Clock] = None,
        budget: Optional[TaskBudget] = None,
        partitioner: Optional[Partitioner] = None,
        drops_enabled: bool = True,
        probe_every: int = 16,
        node: str = "",
    ) -> None:
        self.name = name
        self.sim = sim
        self.xi = xi
        self.batcher = batcher
        self.logic = logic or (lambda events, state: list(events))
        self.clock = clock or Clock()
        self.budget = budget or TaskBudget(name, xi, m_max=getattr(batcher, "m_max", 25))
        self.partitioner = partitioner or (lambda ev: next(iter(self.downstream)))
        self.drops_enabled = drops_enabled
        self.probe_every = int(probe_every)
        self.node = node or name
        self.state: Dict[str, Any] = {}
        self.downstream: Dict[str, "Task"] = {}
        self.upstream: List["Task"] = []
        self.stats = PipelineStats()
        self._drop_count = 0
        self._busy = False
        self._run_queue: List[List[PendingEvent]] = []
        self._event_downstream: Dict[int, str] = {}
        self._timer_pending = False
        self._upstream_cache = None
        # Event sizes for network modelling: bytes per event leaving this task.
        self.output_event_bytes: float = 2900.0  # paper: 2.9 kB median JPG
        if not hasattr(sim, "tasks") or sim.tasks is Scheduler.tasks:
            sim.tasks = {}
        sim.tasks[name] = self

    # ------------------------------------------------------------------ #
    # Wiring                                                             #
    # ------------------------------------------------------------------ #
    def connect(self, downstream: "Task") -> "Task":
        self.downstream[downstream.name] = downstream
        downstream.upstream.append(self)
        downstream._upstream_cache = None
        return downstream

    def upstream_chain(self) -> List["Task"]:
        """All transitive upstream tasks (fallback when an event carries no
        path); cached, set-deduplicated."""
        if getattr(self, "_upstream_cache", None) is not None:
            return self._upstream_cache
        seen: Dict[int, Task] = {}
        frontier = list(self.upstream)
        while frontier:
            t = frontier.pop()
            if id(t) not in seen:
                seen[id(t)] = t
                frontier.extend(t.upstream)
        self._upstream_cache = list(seen.values())
        return self._upstream_cache

    def _path_tasks(self, path) -> List["Task"]:
        """Tasks along an event's traversed path (its pipeline, §4.2)."""
        if not path:
            return self.upstream_chain()
        reg = getattr(self.sim, "tasks", {})
        return [reg[n] for n in path if n in reg and reg[n] is not self]

    # ------------------------------------------------------------------ #
    # Arrival + drop point 1                                             #
    # ------------------------------------------------------------------ #
    def on_arrival(self, ev: Event) -> None:
        now_local = self.clock.now(self.sim.time)
        self.stats.arrived += 1
        beta = self.budget.min_budget() if self.drops_enabled else math.inf
        if self.drops_enabled and drop_before_queuing(
            ev.header.source_arrival,
            now_local,
            self.xi(1),
            beta,
            avoid_drop=ev.header.avoid_drop or ev.header.is_probe,
        ):
            self.stats.dropped_dp1 += 1
            u = now_local - ev.header.source_arrival
            self._on_drop(ev, epsilon=u + self.xi(1) - beta)
            return
        deadline = ev.header.source_arrival + beta
        pe = PendingEvent(event=ev, arrival=now_local, deadline=deadline)
        # Bootstrap (§4.5): until a budget is assigned the deadline is
        # unbounded; the paper fixes the batch size at b=1 in that regime so
        # dynamic batches cannot grow without an auto-submit deadline.
        if math.isinf(beta) and isinstance(self.batcher, DynamicBatcher):
            open_batch = self.batcher.take() if self.batcher.current_size else []
            if open_batch:
                self._enqueue_batch(open_batch)
            self._enqueue_batch([pe])
            return
        submitted = self.batcher.offer(pe, now_local)
        if submitted:
            self._enqueue_batch(submitted)
        self._arm_timer()

    def _arm_timer(self) -> None:
        """Auto-submit the open batch at ``Delta_p - xi(m)`` (§4.4)."""
        due = self.batcher.next_due_time()
        if math.isinf(due) or self._timer_pending:
            return
        self._timer_pending = True
        delay = max(due - self.clock.now(self.sim.time), 0.0)

        def fire() -> None:
            self._timer_pending = False
            batch = self.batcher.flush_if_due(self.clock.now(self.sim.time))
            if batch:
                self._enqueue_batch(batch)
            self._arm_timer()

        self.sim.schedule(delay, fire)

    # ------------------------------------------------------------------ #
    # Execution: drop point 2, run, drop point 3                         #
    # ------------------------------------------------------------------ #
    def _enqueue_batch(self, batch: List[PendingEvent]) -> None:
        self._run_queue.append(batch)
        self._maybe_run()

    def _maybe_run(self) -> None:
        if self._busy or not self._run_queue:
            return
        batch = self._run_queue.pop(0)
        self._busy = True
        now_local = self.clock.now(self.sim.time)
        b = len(batch)
        xi_b = self.xi(b)
        beta = self.budget.min_budget() if self.drops_enabled else math.inf
        tuples = [
            (pe.event.header.source_arrival, pe.arrival, now_local - pe.arrival, pe.event)
            for pe in batch
        ]
        if self.drops_enabled:
            retained_evs, dropped_evs = drop_before_exec(tuples, xi_b, beta)
        else:
            retained_evs, dropped_evs = [t[3] for t in tuples], []
        pe_by_id = {pe.event.event_id: pe for pe in batch}
        for ev in dropped_evs:
            self.stats.dropped_dp2 += 1
            pe = pe_by_id[ev.event_id]
            u = pe.arrival - ev.header.source_arrival
            q = now_local - pe.arrival
            self._on_drop(ev, epsilon=u + q + xi_b - beta)
        if not retained_evs:
            self._busy = False
            self._maybe_run()
            return
        m = len(retained_evs)
        exec_dur = self.xi(m)
        retained_pes = [pe_by_id[ev.event_id] for ev in retained_evs]

        def finish() -> None:
            self._finish_batch(retained_pes, exec_start=now_local, exec_dur=exec_dur)
            self._busy = False
            self._maybe_run()

        self.sim.schedule(exec_dur, finish)

    def _finish_batch(
        self, batch: List[PendingEvent], exec_start: float, exec_dur: float
    ) -> None:
        self.stats.batches += 1
        self.stats.batch_sizes.append(len(batch))
        m = len(batch)
        probes = [pe.event for pe in batch if pe.event.header.is_probe]
        work = [pe.event for pe in batch if not pe.event.header.is_probe]
        outputs = self.logic(work, self.state) + probes
        out_by_id: Dict[int, List[Event]] = {}
        for out in outputs:
            out_by_id.setdefault(out.event_id, []).append(out)
        end_local = exec_start + exec_dur
        # Track the slowest event of the batch for the sink's accept logic.
        slowest_id, slowest_d = None, -math.inf
        for pe in batch:
            u = pe.arrival - pe.event.header.source_arrival
            q = exec_start - pe.arrival
            pi = q + exec_dur
            d = u + pi
            if d > slowest_d:
                slowest_d, slowest_id = d, pe.event.event_id
        for pe in batch:
            ev = pe.event
            u = pe.arrival - ev.header.source_arrival
            q = exec_start - pe.arrival
            pi = q + exec_dur
            self.stats.executed += 1
            self.budget.record(
                ev.event_id,
                EventRecord(departure=u + pi, queuing=q, batch_size=m, xi=exec_dur),
            )
            for out in out_by_id.get(ev.event_id, []):
                out.header = ev.header.advanced(xi=exec_dur, q=q, task=self.name)
                if out.event_id == slowest_id:
                    setattr(out, "batch_slowest", True)
                self._route(out, u=u, pi=pi)

    def _route(self, ev: Event, u: float, pi: float) -> None:
        if not self.downstream:
            return
        dst_name = self.partitioner(ev)
        dst = self.downstream[dst_name]
        self._event_downstream[ev.event_id] = dst_name
        beta = self.budget.budget(dst_name) if self.drops_enabled else math.inf
        # DP3 test is u + pi > beta (§4.3.3); express via drop_before_transmit
        # with arrival reconstructed so that arrival - source_arrival == u.
        if self.drops_enabled and drop_before_transmit(
            0.0,
            u,
            pi,
            beta,
            avoid_drop=ev.header.avoid_drop or ev.header.is_probe,
        ):
            self.stats.dropped_dp3 += 1
            self._on_drop(ev, epsilon=u + pi - beta, downstream=dst_name)
            return
        delay = self.sim.transit_delay(self.node, dst.node, self.output_event_bytes)
        self.sim.schedule(delay, lambda e=ev, d=dst: d.on_arrival(e))

    # ------------------------------------------------------------------ #
    # Signals (§4.5)                                                     #
    # ------------------------------------------------------------------ #
    def _on_drop(self, ev: Event, epsilon: float, downstream: str = "") -> None:
        self._drop_count += 1
        sig = RejectSignal(
            event_id=ev.event_id,
            epsilon=max(epsilon, 0.0),
            q_bar=ev.header.q_bar,
            from_task=self.name,
        )
        for up in self._path_tasks(ev.header.path):
            up.receive_reject(sig)
        # Probe every k-th dropped event: re-inject it as un-droppable so it
        # traverses the NORMAL path (including this task's own executor) —
        # each task along the way then has an event record for the accept
        # signal to act on, which is what lets a collapsed budget recover
        # (§4.5.2).
        if self.probe_every > 0 and self._drop_count % self.probe_every == 0:
            probe = Event(
                header=EventHeader(
                    event_id=ev.header.event_id,
                    source_arrival=ev.header.source_arrival,
                    xi_bar=ev.header.xi_bar,
                    q_bar=ev.header.q_bar,
                    is_probe=True,
                    path=ev.header.path,
                ),
                key=ev.key,
                value=ev.value,
            )
            self.sim.schedule(0.0, lambda: self.on_arrival(probe))

    def receive_reject(self, sig: RejectSignal) -> None:
        downstream = self._event_downstream.get(sig.event_id, "")
        self.budget.on_reject(sig, downstream=downstream)

    def receive_accept(self, sig: AcceptSignal) -> None:
        downstream = self._event_downstream.get(sig.event_id, "")
        self.budget.on_accept(sig, downstream=downstream)


class SinkTask(Task):
    """The pipeline sink (UV): measures end-to-end latency, generates accept
    signals, and feeds detections to the TL callback."""

    def __init__(
        self,
        name: str,
        sim: Scheduler,
        gamma: float,
        *,
        epsilon_max: float = 1.0,
        on_event: Optional[Callable[[Event, float], None]] = None,
        clock: Optional[Clock] = None,
        node: str = "",
    ) -> None:
        super().__init__(
            name,
            sim,
            xi=lambda b: 0.0,
            batcher=DynamicBatcher(lambda b: 0.0, m_max=1),
            clock=clock,
            drops_enabled=False,
            node=node,
        )
        self.gamma = float(gamma)
        self.epsilon_max = float(epsilon_max)
        self.on_event = on_event
        self.latencies: List[Tuple[float, float]] = []  # (t_now, latency)
        self.delayed: int = 0
        self.on_time: int = 0
        self.budget.set_budget(self.gamma)

    def on_arrival(self, ev: Event) -> None:  # overrides Task
        now_local = self.clock.now(self.sim.time)
        self.stats.arrived += 1
        u = now_local - ev.header.source_arrival  # kappa_1 == kappa_n (§4.6.2)
        if ev.header.is_probe:
            if u <= self.gamma:
                self._send_accept(ev, epsilon=self.gamma - u)
            return
        self.latencies.append((now_local, u))
        if u <= self.gamma:
            self.on_time += 1
        else:
            self.delayed += 1
        # Accept only on the slowest event of an upstream batch (§4.5.2).
        if getattr(ev, "batch_slowest", False):
            epsilon = self.gamma - u
            if epsilon > self.epsilon_max:
                self._send_accept(ev, epsilon=epsilon)
        if self.on_event is not None:
            self.on_event(ev, now_local)

    def _send_accept(self, ev: Event, epsilon: float) -> None:
        sig = AcceptSignal(
            event_id=ev.event_id,
            epsilon=epsilon,
            xi_bar=ev.header.xi_bar,
            from_task=self.name,
        )
        for up in self._path_tasks(ev.header.path):
            up.receive_accept(sig)
