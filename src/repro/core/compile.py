"""App compiler: lower a :class:`~repro.core.dataflow.TrackingApp` onto the
pipeline runtime (paper §2.3/§3 — "the platform does the wiring").

The paper's programming model makes the *application spec* the deployable
artifact: the user composes FC/VA/CR/TL/QF logics (plus per-module
:class:`~repro.core.dataflow.ModuleSpec` overrides) and the platform turns
that into a placed, batched, budgeted pipeline.  This module is that
lowering for the discrete-event plane:

    compile_app(app, world, deployment, sim)  ->  CompiledApp

* **Spec resolution** — :func:`resolve_module` merges the app's per-module
  overrides over the :class:`DeploymentSpec` platform defaults (replicas,
  tier, batcher, ``m_max``, cost model), so both hand-written Table-1 apps
  and ``ScenarioConfig.to_app()`` presets flow through one path.
* **Task DAG** — VA/CR replicas are placed round-robin over the compute
  nodes (with per-node clock skews), FC tasks are materialized lazily per
  camera on edge hosts, and the UV sink closes the loop.  When the FC logic
  is the stateless ``fc_is_active`` (and drops are off, the network static,
  and the frame period exceeds ``xi_fc(1)``) the whole FC stage is *fused*
  into the source: the driver asks the compiled app for each frame's entry
  plan instead of paying a per-camera Task hop.
* **DSL adaptation** — user logics speak the keyed DSL signatures
  (``va(camera_id, frames, state) -> [(key, value)]``); Tasks speak
  ``logic(events, state) -> events``.  The adapters preserve event identity
  for 1:1 transforms (keeping the runtime's allocation-free header fast
  paths — and bit-identical trajectories for the scenario presets), group
  contiguous same-camera runs so batched analytics see per-camera frame
  lists without reordering the batch, and support fan-out/fan-in
  selectivity by positional matching.
* **QF feedback edge** (§2.2.5) — positive detections reaching the sink are
  fed to the app's QF logic; a fused query is pushed to every VA/CR task's
  ``state['entity_query']`` after one control-network latency, exactly like
  TL activation control.  Apps without QF compile to the identical DAG the
  scenario always built.

The serving plane shares the same spec resolution:
:func:`repro.serving.scheduler.lower_app_stages` lowers VA/CR onto
jit-compiled :class:`~repro.serving.scheduler.ServedStage`\\ s.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from .batching import DynamicBatcher, NOBBatcher, StaticBatcher
from .budget import TaskBudget
from .clock import Clock
from .dataflow import (
    BATCHING_STRATEGIES,
    CRLogic,
    FCLogic,
    ModuleSpec,
    QFLogic,
    TrackingApp,
    VALogic,
    fc_is_active,
)
from .events import Event
from .pipeline import Scheduler, SinkTask, Task
from .tracking import Detection

__all__ = [
    "DeploymentSpec",
    "ResolvedModule",
    "CompiledApp",
    "compile_app",
    "resolve_module",
    "linear_xi",
    "MODULES",
]

#: The fixed module universe of the dataflow (paper Fig. 2).  TL/UV have no
#: per-replica deployment: TL is the control plane, UV the singleton sink.
MODULES = ("FC", "VA", "CR", "QF", "UV")


def linear_xi(c0: float, c1: float) -> Callable[[int], float]:
    """Affine batch cost model ``xi(b) = c0 + c1 * b`` (monotone, amortizes
    the fixed model-invocation overhead — paper §2.2.2)."""

    def xi(b: int) -> float:
        return c0 + c1 * max(int(b), 0)

    return xi


def _zero_xi(b: int) -> float:
    return 0.0


# --------------------------------------------------------------------- #
# Deployment + spec resolution                                           #
# --------------------------------------------------------------------- #
@dataclass
class DeploymentSpec:
    """Platform-side deployment: everything the operator (not the app
    author) decides.  Absorbs the historical ``num_va`` / ``va_cost`` /
    ``batching`` scatter of ``ScenarioConfig`` into one declarative object.

    ``modules`` holds the platform *defaults* per module type; an app's own
    ``specs`` override them field-by-field (``None`` fields inherit).
    """

    num_nodes: int = 10
    modules: Dict[str, ModuleSpec] = field(default_factory=dict)
    drops_enabled: bool = False
    avoid_drop_positives: bool = False
    epsilon_max: float = 1.0
    node_clock_skews: Optional[Sequence[float]] = None

    def __post_init__(self) -> None:
        if int(self.num_nodes) < 1:
            raise ValueError(f"num_nodes must be >= 1, got {self.num_nodes!r}")
        for name in self.modules:
            if name not in MODULES:
                raise ValueError(f"unknown module {name!r}; expected one of {MODULES}")

    def skews(self) -> List[float]:
        out = list(self.node_clock_skews or [])
        if len(out) < self.num_nodes:
            out += [0.0] * (self.num_nodes - len(out))
        return out


# Global fallbacks applied when neither the app nor the deployment pins a
# field (tier per paper §2.2: FC at the edge, VA on fog nodes, CR in cloud).
_TIER_DEFAULT = {"FC": "edge", "VA": "fog", "CR": "cloud", "QF": "cloud", "UV": "cloud"}


@dataclass(frozen=True)
class ResolvedModule:
    """A fully-resolved module deployment: no ``None`` fields left."""

    name: str
    instances: int
    resource_tier: str
    m_max: int
    batching: str
    static_batch: int
    xi: Callable[[int], float]

    def make_batcher(self):
        if self.batching == "dynamic":
            return DynamicBatcher(self.xi, m_max=self.m_max)
        if self.batching == "static":
            return StaticBatcher(self.xi, batch_size=self.static_batch)
        if self.batching == "nob":
            return NOBBatcher(self.xi, m_max=self.m_max)
        raise ValueError(f"unknown batching {self.batching!r}")  # pragma: no cover


def _pick(*values):
    for v in values:
        if v is not None:
            return v
    return None


def resolve_module(
    app: TrackingApp, deployment: DeploymentSpec, module: str
) -> ResolvedModule:
    """Merge ``app.specs[module]`` over ``deployment.modules[module]`` over
    the global defaults, field by field (``None`` inherits)."""
    a = app.specs.get(module, ModuleSpec())
    d = deployment.modules.get(module, ModuleSpec())
    batching = _pick(a.batching, d.batching, "dynamic")
    if batching not in BATCHING_STRATEGIES:  # pragma: no cover - ModuleSpec validates
        raise ValueError(f"unknown batching {batching!r}")
    return ResolvedModule(
        name=module,
        instances=int(_pick(a.instances, d.instances, 1)),
        resource_tier=_pick(a.resource_tier, d.resource_tier, _TIER_DEFAULT.get(module, "fog")),
        m_max=int(_pick(a.m_max, d.m_max, 25)),
        batching=batching,
        static_batch=int(_pick(a.static_batch, d.static_batch, 1)),
        xi=_pick(a.xi, d.xi, _zero_xi),
    )


# --------------------------------------------------------------------- #
# DSL -> Task logic adapters                                             #
# --------------------------------------------------------------------- #
def _flag_avoid_drop_inputs(events: List[Event]) -> None:
    """Edge-side candidate filter (§4.3.3): ground-truth positives are
    flagged un-droppable when the deployment asks for it."""
    for ev in events:
        if getattr(ev.value, "has_entity", False):
            ev.header.avoid_drop = True


def _apply_keyed(
    logic_fn: Callable[[Any, Sequence[Any], Dict], List[Tuple[Any, Any]]],
    events: List[Event],
    state: Dict[str, Any],
) -> List[Event]:
    """Run a keyed DSL logic over a Task batch.

    Events are chunked into contiguous same-key runs (so the logic sees
    per-camera frame lists, per the VA/CR contract) **without reordering the
    batch** — order determines downstream arrival interleaving and any
    stateful randomness in the logic, and must survive the lowering intact.

    Output attribution is **positional, not causal** (the logic is opaque):
    a 1:1 pair list maps pair *i* onto input event *i*, reusing the event
    object (the runtime's allocation-free header path); when a value is
    *transformed* the upstream ``batch_slowest`` mark is cleared so the
    runtime re-marks this stage's slowest.  To *filter*, a logic emits
    ``None`` in an input's position (the event ends here, its header
    intact) — returning a compacted shorter list instead would silently
    marry the surviving values to the wrong events' headers.  Lists of any
    other length still match positionally: missing tails are filtered,
    surplus pairs are emitted as new events sharing the run's last header
    (the runtime forks headers for multi-output events).
    """
    outputs: List[Event] = []
    i, n = 0, len(events)
    while i < n:
        j = i + 1
        key = events[i].key
        while j < n and events[j].key == key:
            j += 1
        run = events[i:j]
        i = j
        pairs = logic_fn(key, [ev.value for ev in run], state)
        if pairs is None:
            continue
        if len(pairs) == len(run):
            for ev, pair in zip(run, pairs):
                if pair is None:  # filtered: this input's flow ends here
                    continue
                k, v = pair
                if v is not ev.value:
                    ev.batch_slowest = False
                ev.key = k
                ev.value = v
                outputs.append(ev)
        else:
            last = len(run) - 1
            for idx, (k, v) in enumerate(pairs):
                if idx <= last:
                    ev = run[idx]
                    if v is not ev.value:
                        ev.batch_slowest = False
                    ev.key = k
                    ev.value = v
                else:
                    ev = Event(header=run[last].header, key=k, value=v)
                    ev.batch_slowest = False
                outputs.append(ev)
    return outputs


def _adapt_fc(fc: FCLogic, avoid_drop_positives: bool):
    """``fc(frame, state) -> bool`` as Task logic: filter, then flag."""
    inner = _event_level(fc)

    def logic(events: List[Event], state: Dict[str, Any]) -> List[Event]:
        if inner is not None:
            out = inner(events, state)
        else:
            out = [ev for ev in events if fc(ev.value, state)]
        if avoid_drop_positives:
            _flag_avoid_drop_inputs(out)
        return out

    return logic


def _event_level(dsl_logic) -> Optional[Callable[[List[Event], Dict], List[Event]]]:
    """Lowering override: a DSL logic may carry a ``task_logic`` attribute —
    an event-level ``(events, state) -> events`` implementing the same
    transform without the keyed-adapter round trip.  The pipeline runs the
    module logic once per event on the hot path, so performance-critical
    logics (the scenario presets, custom kernels) supply one; everything
    else goes through :func:`_apply_keyed`.  The override owns event
    identity and ``batch_slowest`` hygiene exactly like a transform run
    through the adapter would."""
    return getattr(dsl_logic, "task_logic", None)


def _adapt_va(
    va: VALogic,
    avoid_drop_positives: bool,
    batch_hook: Optional[Callable[[List[Event], Dict], None]] = None,
):
    """``va(camera_id, frames, state)`` as Task logic.  ``batch_hook`` runs
    first over the whole Task batch (e.g. the scenario's bucket-batched
    re-ID instrumentation)."""
    inner = _event_level(va)

    def logic(events: List[Event], state: Dict[str, Any]) -> List[Event]:
        if batch_hook is not None:
            batch_hook(events, state)
        if avoid_drop_positives:
            _flag_avoid_drop_inputs(events)
        if inner is not None:
            return inner(events, state)
        return _apply_keyed(va, events, state)

    return logic


def _adapt_cr(cr: CRLogic, avoid_drop_positives: bool):
    """``cr(camera_id, values, state)`` as Task logic.  Avoid-drop is based
    on the *verdict* (``.positive`` outputs), matching §4.3.3: only frames
    the analytics judged positive are shielded from the drop points."""
    inner = _event_level(cr)

    def logic(events: List[Event], state: Dict[str, Any]) -> List[Event]:
        outputs = (
            inner(events, state)
            if inner is not None
            else _apply_keyed(cr, events, state)
        )
        if avoid_drop_positives:
            for ev in outputs:
                if _verdict_positive(ev.value):
                    ev.header.avoid_drop = True
        return outputs

    return logic


def _verdict_positive(value: Any) -> bool:
    """Is a CR output a positive sighting?  ``Detection`` values carry it
    explicitly; bare verdicts (``bool`` from ``make_cr``) are their own
    truth value — the same interpretation :func:`as_detection` applies at
    the sink, so the avoid-drop shield and the TL/QF planes agree."""
    positive = getattr(value, "positive", None)
    return bool(value) if positive is None else bool(positive)


def as_detection(ev: Event) -> Detection:
    """Coerce a sink event into a :class:`Detection` for the TL/QF planes.

    Scenario presets emit :class:`Detection` values directly; hand-written
    CR logics may emit bare verdicts (e.g. ``bool`` from ``make_cr``), which
    are interpreted against the event's camera key and source time.
    """
    v = ev.value
    if isinstance(v, Detection):
        return v
    return Detection(
        camera_id=ev.key,
        positive=_verdict_positive(v),
        timestamp=ev.header.source_arrival,
    )


# --------------------------------------------------------------------- #
# The compiled artifact                                                  #
# --------------------------------------------------------------------- #
class CompiledApp:
    """A :class:`TrackingApp` lowered onto a Task DAG (built by
    :func:`compile_app`; driven by ``repro.sim.scenario.TrackingScenario``).

    Owns the module instances (``va_tasks`` / ``cr_tasks`` / lazy
    ``fc_tasks`` + the ``sink``), the FC activation mirror (``fc_active``),
    the fused-FC source plane, and the QF feedback edge.  The driver owns
    time: it sources frames, ticks TL, and reads results.
    """

    def __init__(
        self,
        app: TrackingApp,
        deployment: DeploymentSpec,
        sim: Scheduler,
        *,
        fps: float,
        camera_vertices: Dict[int, int],
        on_detection: Optional[Callable[[Event, float], None]] = None,
        va_batch_hook: Optional[Callable[[List[Event], Dict], None]] = None,
        sink_recycle_headers: bool = False,
    ) -> None:
        self.app = app
        self.deployment = deployment
        self.sim = sim
        self.fps = float(fps)
        self.camera_vertices = camera_vertices
        self.on_detection = on_detection
        self._va_batch_hook = va_batch_hook
        self._sink_recycle_headers = sink_recycle_headers

        self.fc_spec = resolve_module(app, deployment, "FC")
        self.va_spec = resolve_module(app, deployment, "VA")
        self.cr_spec = resolve_module(app, deployment, "CR")

        #: Activation mirror: the FC states that are *currently* active
        #: (control latency applied), kept O(active) for the source loop.
        self.fc_active: Set[int] = set()
        self.fc_tasks: Dict[int, Task] = {}
        self.va_tasks: List[Task] = []
        self.cr_tasks: List[Task] = []
        self.sink: Optional[SinkTask] = None

        # QF state (entity query + whatever the QF logic accumulates).
        self.qf_state: Dict[str, Any] = {"entity_query": app.entity_query}
        self.query_pushes = 0
        # Multi-query tenancy (repro.query): one drop observer shared by
        # every task of the DAG — including FCs materialized after
        # install_drop_hook() was called (see make_fc).
        self._drop_hook: Optional[Callable[[Event, int, float], None]] = None
        # Observability plane: one duck-typed span tracer shared by every
        # task (incl. the sink and lazily-built FCs) — see install_tracer.
        self._tracer = None

        self._build()

    # ------------------------------------------------------------------ #
    def _control_latency(self) -> float:
        net = getattr(self.sim, "network", None)
        return getattr(net, "man_latency_s", 0.0) if net is not None else 0.0

    def _build(self) -> None:
        app, deployment, sim = self.app, self.deployment, self.sim
        skews = deployment.skews()
        num_nodes = deployment.num_nodes
        drops = deployment.drops_enabled

        on_event = self._on_sink_event if app.qf is not None else self.on_detection
        self.sink = SinkTask(
            "UV",
            sim,
            gamma=app.gamma,
            epsilon_max=deployment.epsilon_max,
            on_event=on_event,
            clock=Clock(0.0),  # kappa_n == kappa_1 (§4.6.2)
            node="head",
            # Budgets are only consulted by the drop points; skip the accept
            # machinery entirely in no-drop runs.
            learn_budgets=drops,
            # QF only ever sees Detection values (never the event or its
            # header), so recycling stays safe when the driver opted in.
            recycle_headers=self._sink_recycle_headers,
        )
        sim.host_of["UV"] = "head"

        cr_xi = self.cr_spec.xi
        cr_logic = _adapt_cr(app.cr, deployment.avoid_drop_positives)
        transit_static = getattr(sim, "transit_is_static", False)
        # Compute perturbations (dynamism plane) make actual execution
        # durations time-varying; the fused fast paths precompute them, so
        # fusion is only sound when xi is static too.  One predicate for
        # every fusion site, including the lazily-built FCs (make_fc).
        fuse_ok = self._fuse_ok = transit_static and getattr(sim, "xi_is_static", True)
        for i in range(self.cr_spec.instances):
            node = f"node{i % num_nodes}"
            t = Task(
                f"CR-{i}",
                sim,
                cr_xi,
                self.cr_spec.make_batcher(),
                logic=cr_logic,
                clock=Clock(skews[i % num_nodes]),
                budget=TaskBudget(f"CR-{i}", cr_xi, m_max=self.cr_spec.m_max),
                drops_enabled=drops,
                node=node,
            )
            t.module = "CR"
            t.output_event_bytes = 256.0  # metadata only (§2.2.3)
            t.connect(self.sink)
            t.partitioner = _constant_partitioner("UV")
            # CR logic has no completion-time state reads (control updates —
            # TL activation and QF query pushes — land one MAN latency after
            # their trigger, slower than xi(1)): safe to fuse its streaming
            # (b=1) executions with the outbound transit.
            t.fuse_streaming = not drops and fuse_ok
            t.state["entity_query"] = app.entity_query
            self.cr_tasks.append(t)
            sim.host_of[t.name] = node

        va_xi = self.va_spec.xi
        va_logic = _adapt_va(
            app.va, deployment.avoid_drop_positives, self._va_batch_hook
        )
        # Keys are camera ids, a small fixed universe: precompute the
        # routing table instead of formatting a string per event.
        self._cr_route = {
            cam: f"CR-{hash(cam) % self.cr_spec.instances}"
            for cam in self.camera_vertices
        }
        for i in range(self.va_spec.instances):
            node = f"node{i % num_nodes}"
            t = Task(
                f"VA-{i}",
                sim,
                va_xi,
                self.va_spec.make_batcher(),
                logic=va_logic,
                clock=Clock(skews[i % num_nodes]),
                budget=TaskBudget(f"VA-{i}", va_xi, m_max=self.va_spec.m_max),
                drops_enabled=drops,
                node=node,
            )
            t.module = "VA"
            for cr in self.cr_tasks:
                t.connect(cr)
            t.partitioner = _table_partitioner(self._cr_route)
            t.fuse_streaming = not drops and fuse_ok
            t.state["entity_query"] = app.entity_query
            self.va_tasks.append(t)
            sim.host_of[t.name] = node

        # FC tasks are created lazily: a 10k-camera scenario with a spotlight
        # TL only ever activates a small moving subset, so building a Task
        # (+ its budget, batcher, wiring) per camera upfront dominated
        # construction time.  `make_fc` is called on first activation or
        # first sourced frame.
        self._fc_xi = self.fc_spec.xi
        self.fc_xi1 = self._fc_xi(1)
        self._fc_logic = _adapt_fc(app.fc, deployment.avoid_drop_positives)
        # Full FC fusion: with a stateless pass-through FC logic, drops off,
        # a static network and a frame period longer than xi_fc(1), the FC
        # stage reduces exactly to "arrive at the VA at t + xi_fc(1) +
        # transit with xi_bar advanced" — the per-camera Task machinery is
        # bypassed wholesale.  Stateful FC logics (frame-rate subsampling)
        # and drops-enabled or dynamic-bandwidth deployments keep real FCs.
        self.fuse_fc = (
            app.fc is fc_is_active
            and not drops
            and fuse_ok
            and self.fps > 0
            and 1.0 / self.fps > self.fc_xi1
        )
        if self.fuse_fc:
            # All FC->VA transits are edge-host -> compute-node MAN hops with
            # the same payload size: one delay for every camera.
            net = getattr(sim, "network", None)
            if net is None:
                self.fuse_fc = False
            else:
                self.fc_transit = net.transit_delay("edge*", "node*", 2900.0, 0.0)
                self.va_of = {
                    cam: self.va_tasks[hash(cam) % self.va_spec.instances]
                    for cam in self.camera_vertices
                }

    # ------------------------------------------------------------------ #
    # FC plane                                                            #
    # ------------------------------------------------------------------ #
    def make_fc(self, cam: int) -> Task:
        sim = self.sim
        # FC co-located with the camera on an edge host; the downstream VA
        # is fixed by camera id (paper: FCs scheduled round-robin).
        fc_xi = self._fc_xi
        t = Task(
            f"FC-{cam}",
            sim,
            fc_xi,
            StaticBatcher(fc_xi, batch_size=1),  # FC logic is simple/edge
            logic=self._fc_logic,
            clock=Clock(0.0),  # source clock kappa_1
            budget=TaskBudget(f"FC-{cam}", fc_xi, m_max=1),
            drops_enabled=self.deployment.drops_enabled,
            node=f"edge{cam}",
        )
        t.module = "FC"
        for va in self.va_tasks:
            t.connect(va)
        # Each FC has a fixed key (its camera), so its destination VA is
        # a constant.
        t.partitioner = _constant_partitioner(
            f"VA-{hash(cam) % self.va_spec.instances}"
        )
        t.state["isActive"] = cam in self.fc_active
        # FC control updates land >= man_latency after a tick while xi(1) is
        # sub-millisecond, so arrival-time state reads match finish-time
        # reads: safe to fuse the execute+transmit hops (see pipeline.py).
        t.fuse_streaming = not self.deployment.drops_enabled and self._fuse_ok
        t.on_drop_hook = self._drop_hook
        t.tracer = self._tracer
        self.fc_tasks[cam] = t
        sim.host_of[t.name] = f"edge{cam}"
        return t

    def set_fc_active(self, cam: int, want: bool) -> None:
        """Control-event delivery (the driver schedules this one control
        latency after a TL tick)."""
        if self.fuse_fc:
            # Fused FC mode keeps no per-camera tasks; the mirror set is the
            # entire FC state.
            if want:
                self.fc_active.add(cam)
            else:
                self.fc_active.discard(cam)
            return
        if want:
            fc = self.fc_tasks.get(cam)
            if fc is None:
                self.fc_active.add(cam)  # make_fc reads the mirror
                self.make_fc(cam)
            else:
                fc.state["isActive"] = True
                self.fc_active.add(cam)
        else:
            fc = self.fc_tasks.get(cam)
            if fc is not None:
                fc.state["isActive"] = False
            self.fc_active.discard(cam)

    # ------------------------------------------------------------------ #
    # QF feedback edge (§2.2.5): CR -> QF -> VA/CR query update           #
    # ------------------------------------------------------------------ #
    def _on_sink_event(self, ev: Event, now: float) -> None:
        det = as_detection(ev)
        # Coerce once: downstream consumers (the driver's detection
        # bookkeeping, QF) all see the Detection view of the verdict.
        ev.value = det
        if self.on_detection is not None:
            self.on_detection(ev, now)
        if det.positive:
            fused = self.app.qf([det], self.qf_state)
            if fused is not None and fused is not self.qf_state.get("entity_query"):
                # Control push, same plane as TL activation: the new query
                # reaches every VA/CR instance one MAN latency later.
                self.sim.schedule(self._control_latency(), self._apply_query, fused)

    def _apply_query(self, query: Any) -> None:
        self.qf_state["entity_query"] = query
        self.query_pushes += 1
        for t in self.va_tasks:
            t.state["entity_query"] = query
        for t in self.cr_tasks:
            t.state["entity_query"] = query

    # ------------------------------------------------------------------ #
    # Multi-query tenancy: per-query drop charging                        #
    # ------------------------------------------------------------------ #
    def install_drop_hook(
        self, hook: Optional[Callable[[Event, int, float], None]]
    ) -> None:
        """Install ``hook(ev, point, epsilon)`` on every task of the DAG
        (and every FC materialized later), fired once per dropped event at
        each of the three drop points.  The query plane uses it to charge
        drops to each query tagged on the event's ``query_mask`` — per
        query, not globally.  Pass ``None`` to uninstall."""
        self._drop_hook = hook
        for t in self.all_tasks():
            t.on_drop_hook = hook

    # ------------------------------------------------------------------ #
    # Observability plane: span tracing                                   #
    # ------------------------------------------------------------------ #
    def install_tracer(self, tracer) -> None:
        """Install a duck-typed span tracer (``repro.obs.tracing.
        EventTracer``-shaped) on every task of the DAG, the sink, and every
        FC materialized later — same propagation contract as
        ``install_drop_hook``.  Pass ``None`` to uninstall.  Tracing
        samples on the tracer's id stride, so the per-event cost with a
        tracer installed is one attribute test plus the sampled hook; with
        ``None`` (the default) the hot path is unchanged."""
        self._tracer = tracer
        for t in self.all_tasks():
            t.tracer = tracer
        if self.sink is not None:
            self.sink.tracer = tracer

    # ------------------------------------------------------------------ #
    # Telemetry (dynamism plane)                                          #
    # ------------------------------------------------------------------ #
    def sample_telemetry(self, trace) -> None:
        """Append one sample per VA/CR task (and the sink) to a
        ``repro.sim.dynamism.DynamismTrace``-shaped recorder, plus one
        aggregate ``FC*`` row over the lazy FC plane (a per-camera series
        would be 10k columns).  Called by the driver's telemetry tick on a
        fixed cadence — never from the per-event hot path."""
        for t in self.va_tasks:
            trace.sample_task(t)
        for t in self.cr_tasks:
            trace.sample_task(t)
        trace.sample_task(self.sink)
        trace.sample_aggregate("FC*", self.fc_tasks.values())

    # ------------------------------------------------------------------ #
    # Results                                                             #
    # ------------------------------------------------------------------ #
    def all_tasks(self) -> List[Task]:
        return list(self.va_tasks) + list(self.cr_tasks) + list(self.fc_tasks.values())

    # ------------------------------------------------------------------ #
    # Serving-plane durability (repro.serving.journal)                    #
    # ------------------------------------------------------------------ #
    _SNAP_STATS = (
        "arrived",
        "dropped_dp1",
        "dropped_dp2",
        "dropped_dp3",
        "dropped_fault",
        "executed",
        "batches",
        "probes",
        "accepts_rx",
        "rejects_rx",
    )

    def snapshot(self) -> Dict[str, float]:
        """Flat ``str -> float`` frontier of the compiled pipeline: every
        task's cumulative counters + its min completion budget, plus the
        sink's ledger.  Keys are deterministic for a deterministic run —
        lazily-materialized FCs appear exactly when a replay would
        materialize them — so two bit-identical runs produce bit-identical
        snapshots (the journal's restore contract)."""
        snap: Dict[str, float] = {}
        for t in self.all_tasks():
            s, p = t.stats, f"task::{t.name}"
            for name in self._SNAP_STATS:
                snap[f"{p}::{name}"] = float(getattr(s, name))
            snap[f"{p}::beta"] = float(t.budget.min_budget())
        sink = self.sink
        snap["sink::arrived"] = float(sink.stats.arrived)
        snap["sink::on_time"] = float(sink.on_time)
        snap["sink::delayed"] = float(sink.delayed)
        snap["sink::probes_seen"] = float(sink.probes_seen)
        snap["query_pushes"] = float(self.query_pushes)
        return snap

    def restore(self, snap: Dict[str, float]) -> "CompiledApp":
        """Verify this app's replayed state bit-matches ``snap``.

        The simulation is deterministic in (config, spec, seed), so restore
        is replay-based: the scenario rebuilds from inputs and re-runs to
        the snapshot's timestamp — this gate then proves the reconstructed
        frontier equals the journalled one exactly (``RestoreMismatch``
        lists every differing key otherwise) rather than silently trusting
        the replay."""
        from repro.serving.journal import RestoreMismatch, diff_snapshots

        diff = diff_snapshots(snap, self.snapshot())
        if diff:
            raise RestoreMismatch(
                "compiled app does not match snapshot:\n  " + "\n  ".join(diff)
            )
        return self

    def drops_by_task(self) -> Dict[str, int]:
        return {t.name: t.stats.dropped for t in self.all_tasks() if t.stats.dropped}

    def batch_sizes(self) -> Dict[str, List[int]]:
        out: Dict[str, List[int]] = {"VA": [], "CR": []}
        for t in self.va_tasks:
            out["VA"].extend(t.stats.batch_sizes)
        for t in self.cr_tasks:
            out["CR"].extend(t.stats.batch_sizes)
        return out


def _constant_partitioner(name: str) -> Callable[[Event], str]:
    def partition(ev: Event) -> str:
        return name

    return partition


def _table_partitioner(table: Dict) -> Callable[[Event], str]:
    def partition(ev: Event) -> str:
        return table[ev.key]

    return partition


# --------------------------------------------------------------------- #
# Front door                                                             #
# --------------------------------------------------------------------- #
def compile_app(
    app: TrackingApp,
    world: Any,
    deployment: Optional[DeploymentSpec] = None,
    sim: Optional[Scheduler] = None,
    *,
    cameras: Any = None,
    on_detection: Optional[Callable[[Event, float], None]] = None,
    va_batch_hook: Optional[Callable[[List[Event], Dict], None]] = None,
    sink_recycle_headers: bool = False,
    verify: Optional[bool] = None,
) -> CompiledApp:
    """Lower ``app`` onto a pipeline over ``world``'s cameras.

    ``world`` is a ``repro.sim.world.WorldBundle`` (or anything exposing
    ``.cameras.camera_vertices`` and, optionally, ``.key.fps``); ``cameras``
    overrides the world's camera network (scenarios with stateful embedding
    RNGs rebuild theirs).  ``sim`` is the discrete-event scheduler the Tasks
    run on; the driver owning real time must supply it.  ``on_detection``
    receives every sink event; ``va_batch_hook`` runs over each VA batch
    before the app's VA logic (instrumentation, e.g. batched re-ID).
    ``compile_app`` performs no simulation itself — the returned
    :class:`CompiledApp` is driven by ``TrackingScenario`` (or any caller
    that sources frames and ticks TL).

    ``verify=True`` (or ``REPRO_ANALYSIS_VERIFY=1`` in the environment)
    runs the replay-safety graph verifier over the lowered DAG and raises
    :class:`repro.analysis.GraphContractError` on a miswired app — the
    compile-time half of the bit-exactness contract.
    """
    if sim is None:
        raise ValueError(
            "compile_app needs a Scheduler (e.g. repro.sim.DiscreteEventSimulator)"
        )
    deployment = deployment or DeploymentSpec()
    cams = cameras if cameras is not None else getattr(world, "cameras", None)
    if cams is None:
        raise ValueError("world must expose .cameras (or pass cameras=...)")
    key = getattr(world, "key", None)
    fps = float(getattr(key, "fps", 0.0) or getattr(cams, "fps", 0.0) or 0.0)
    compiled = CompiledApp(
        app,
        deployment,
        sim,
        fps=fps,
        camera_vertices=cams.camera_vertices,
        on_detection=on_detection,
        va_batch_hook=va_batch_hook,
        sink_recycle_headers=sink_recycle_headers,
    )
    if verify is None:
        # Cheap env probe (no analysis import unless the hook is on).
        verify = os.environ.get("REPRO_ANALYSIS_VERIFY", "") == "1"
    if verify:
        from ..analysis.graphcheck import check_compiled

        check_compiled(compiled)
    return compiled
