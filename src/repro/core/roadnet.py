"""Road-network model and spotlight search (paper §2.3, §5.1 workload).

The paper extracts a 7 km^2 circular region around IISc Bangalore from
OpenStreetMap: 1,000 vertices, 2,817 edges, average road length 84.5 m.
OSM is not available offline, so :func:`make_road_network` generates a
deterministic random-geometric graph matched to those statistics.  Cameras
are placed on vertices; the *spotlight* is the set of cameras reachable from
the last-seen location within ``speed * elapsed`` metres (weighted BFS =
Dijkstra over road lengths) or within a hop-ball assuming a fixed edge length
(unweighted BFS, the paper's TL-BFS).

Spotlight-search machinery:

* :meth:`RoadNetwork.weighted_ball` / :meth:`RoadNetwork.hop_ball` — the
  from-scratch reference searches.
* :class:`ResumableDijkstra` — incremental ball: the spotlight radius only
  grows while the entity is in a blind spot, so each TL tick resumes the
  previous frontier instead of recomputing from the source.
* :meth:`RoadNetwork.csr` — a CSR (``indptr``/``indices``/``weights``) view
  of the graph for the batched `repro.kernels.spotlight_ball` relaxation
  kernel.

``make_road_network`` computes pairwise geometry in row chunks (never the
full V x V matrix), so 10k+-vertex networks build in seconds while remaining
bit-identical to the original construction for any seed.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

__all__ = [
    "RoadNetwork",
    "ResumableDijkstra",
    "clear_network_cache",
    "make_road_network",
]


@dataclass
class RoadNetwork:
    """Undirected road graph with per-edge lengths in metres."""

    positions: np.ndarray  # (V, 2) coordinates in metres
    adjacency: List[List[Tuple[int, float]]]  # vertex -> [(neighbor, length)]
    _csr_cache: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = field(
        default=None, repr=False, compare=False
    )

    @property
    def num_vertices(self) -> int:
        return len(self.adjacency)

    @property
    def num_edges(self) -> int:
        return sum(len(nbrs) for nbrs in self.adjacency) // 2

    @property
    def mean_edge_length(self) -> float:
        total, count = 0.0, 0
        for u, nbrs in enumerate(self.adjacency):
            for v, w in nbrs:
                if v > u:
                    total += w
                    count += 1
        return total / max(count, 1)

    # ------------------------------------------------------------------ #
    # CSR view (for the Pallas spotlight kernel + vectorized consumers)   #
    # ------------------------------------------------------------------ #
    def csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(indptr, indices, weights)`` in CSR form; built once, cached.

        ``indptr`` is ``(V+1,)`` int32, ``indices`` the flattened neighbor
        ids (both directions of every undirected edge), ``weights`` the
        float64 road lengths; ``lengths`` per row are
        ``indptr[v+1]-indptr[v]``.
        """
        if self._csr_cache is None:
            degrees = np.fromiter(
                (len(nbrs) for nbrs in self.adjacency), dtype=np.int64, count=self.num_vertices
            )
            indptr = np.zeros(self.num_vertices + 1, dtype=np.int32)
            np.cumsum(degrees, out=indptr[1:])
            indices = np.empty(int(indptr[-1]), dtype=np.int32)
            weights = np.empty(int(indptr[-1]), dtype=np.float64)
            k = 0
            for nbrs in self.adjacency:
                for v, w in nbrs:
                    indices[k] = v
                    weights[k] = w
                    k += 1
            self._csr_cache = (indptr, indices, weights)
        return self._csr_cache

    # ------------------------------------------------------------------ #
    # Spotlight searches                                                  #
    # ------------------------------------------------------------------ #
    def weighted_ball(self, source: int, radius: float) -> Dict[int, float]:
        """Dijkstra ball: vertices within ``radius`` metres of ``source``
        along the road network, with their distances (TL-WBFS)."""
        dist: Dict[int, float] = {source: 0.0}
        heap: List[Tuple[float, int]] = [(0.0, source)]
        while heap:
            d, u = heapq.heappop(heap)
            if d > dist.get(u, math.inf):
                continue
            for v, w in self.adjacency[u]:
                nd = d + w
                if nd <= radius and nd < dist.get(v, math.inf):
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
        return dist

    def hop_ball(self, source: int, max_hops: int) -> Dict[int, int]:
        """Unweighted BFS ball: vertices within ``max_hops`` edges (TL-BFS
        assumes a fixed road length for all edges)."""
        seen: Dict[int, int] = {source: 0}
        frontier = [source]
        hops = 0
        while frontier and hops < max_hops:
            hops += 1
            nxt: List[int] = []
            for u in frontier:
                for v, _ in self.adjacency[u]:
                    if v not in seen:
                        seen[v] = hops
                        nxt.append(v)
            frontier = nxt
        return seen

    def nearest_vertex(self, xy: Sequence[float]) -> int:
        d2 = np.sum((self.positions - np.asarray(xy)) ** 2, axis=1)
        return int(np.argmin(d2))


class ResumableDijkstra:
    """Incremental Dijkstra ball from a fixed source.

    During a blind spot the spotlight radius only grows, so each expansion
    resumes the saved frontier: the total work over a whole blind-spot
    episode is one full Dijkstra, not one per TL tick.  ``ball(r)`` returns
    the same mapping as ``RoadNetwork.weighted_ball(source, r)`` — the
    returned dict is *live* (owned by the search); callers must not mutate
    it.
    """

    __slots__ = ("network", "source", "_dist", "_heap", "_settled", "order")

    def __init__(self, network: RoadNetwork, source: int) -> None:
        self.network = network
        self.source = source
        self._dist: Dict[int, float] = {source: 0.0}
        self._heap: List[Tuple[float, int]] = [(0.0, source)]
        self._settled: Dict[int, float] = {}
        #: vertices in settle order (nondecreasing distance); consumers can
        #: keep an index to process only newly settled vertices per tick.
        self.order: List[int] = []

    def ball(self, radius: float) -> Dict[int, float]:
        heap = self._heap
        if heap and heap[0][0] <= radius:
            dist = self._dist
            settled = self._settled
            order = self.order
            adjacency = self.network.adjacency
            pop, push = heapq.heappop, heapq.heappush
            inf = math.inf
            while heap and heap[0][0] <= radius:
                d, u = pop(heap)
                if u in settled:
                    continue
                settled[u] = d
                order.append(u)
                for v, w in adjacency[u]:
                    nd = d + w
                    if nd < dist.get(v, inf):
                        dist[v] = nd
                        push(heap, (nd, v))
        return self._settled


# Construction is deterministic in its arguments and the result is treated
# as immutable everywhere, so identical requests (e.g. every scenario of a
# benchmark sweep at seed 0) share one instance.
_NETWORK_CACHE: Dict[Tuple[int, int, float, int], "RoadNetwork"] = {}
_NETWORK_CACHE_MAX = 8


def clear_network_cache() -> None:
    """Drop memoized road networks (cold-baseline measurement support)."""
    _NETWORK_CACHE.clear()


def make_road_network(
    num_vertices: int = 1000,
    target_edges: int = 2817,
    mean_length_m: float = 84.5,
    seed: int = 0,
) -> RoadNetwork:
    """Deterministic OSM-like graph matched to the paper's §5.1 statistics.

    Vertices are sampled in a disc; each vertex connects to its nearest
    neighbours until the edge budget is met, then positions are rescaled so
    the mean edge length matches ``mean_length_m``.  The construction keeps
    the graph connected (a relative-neighbourhood backbone via a nearest
    -neighbour chain) so BFS/Dijkstra spotlights behave like a road network.

    Pairwise distances are evaluated in row chunks with a top-k partition
    per row, so memory stays O(chunk * V) and time O(V^2) with small
    constants — a 10k-vertex network builds in a few seconds.  Identical
    parameter tuples return a shared cached instance.
    """
    cache_key = (num_vertices, target_edges, mean_length_m, seed)
    cached = _NETWORK_CACHE.get(cache_key)
    if cached is not None:
        return cached
    rng = np.random.default_rng(seed)
    # Disc of area ~7 km^2 -> radius sqrt(7e6/pi) m; exact radius is
    # irrelevant because we rescale to the target mean edge length below.
    radius = math.sqrt(7.0e6 / math.pi)
    r = radius * np.sqrt(rng.uniform(0.0, 1.0, size=num_vertices))
    theta = rng.uniform(0.0, 2.0 * math.pi, size=num_vertices)
    pos = np.stack([r * np.cos(theta), r * np.sin(theta)], axis=1)

    def pair_d2(us: np.ndarray, vs: np.ndarray) -> np.ndarray:
        """Squared distances between row sets, elementwise identical to the
        full (V, V) broadcast the original construction used."""
        return np.sum((pos[us][:, None, :] - pos[vs][None, :, :]) ** 2, axis=-1)

    # k-NN edges, deduplicated, preferring short roads.
    k = max(2, int(math.ceil(2.0 * target_edges / num_vertices)) + 1)
    knn = np.empty((num_vertices, k), dtype=np.int64)
    chunk = max(1, min(num_vertices, int(2**22 // max(num_vertices, 1)) or 1))
    all_idx = np.arange(num_vertices)
    for s in range(0, num_vertices, chunk):
        e = min(s + chunk, num_vertices)
        d2c = pair_d2(all_idx[s:e], all_idx)
        d2c[np.arange(e - s), np.arange(s, e)] = np.inf  # no self edges
        # Top-k by distance: partition then order the k candidates by value
        # (no ties occur for continuous random geometry, so this matches a
        # full argsort of the row).
        part = np.argpartition(d2c, k - 1, axis=1)[:, :k]
        row_order = np.argsort(np.take_along_axis(d2c, part, axis=1), axis=1, kind="stable")
        knn[s:e] = np.take_along_axis(part, row_order, axis=1)

    edges: Set[Tuple[int, int]] = set()
    # Backbone: chain each vertex to its nearest neighbour (keeps components
    # few), then add increasing-rank kNN edges until the budget is met.
    for u in range(num_vertices):
        v = int(knn[u, 0])
        edges.add((min(u, v), max(u, v)))
    for rank in range(1, k):
        if len(edges) >= target_edges:
            break
        for u in range(num_vertices):
            if len(edges) >= target_edges:
                break
            v = int(knn[u, rank])
            edges.add((min(u, v), max(u, v)))

    # Connect stray components through nearest cross-component pairs.
    parent = list(range(num_vertices))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a: int, b: int) -> None:
        parent[find(a)] = find(b)

    for u, v in edges:
        union(u, v)
    roots = {find(u) for u in range(num_vertices)}
    while len(roots) > 1:
        comp: Dict[int, List[int]] = {}
        for u in range(num_vertices):
            comp.setdefault(find(u), []).append(u)
        comps = list(comp.values())
        base = np.asarray(comps[0])
        best = (math.inf, -1, -1)
        for other in comps[1:]:
            other_arr = np.asarray(other)
            block = pair_d2(base, other_arr)
            flat = int(np.argmin(block))
            bi, oi = divmod(flat, len(other))
            val = float(block[bi, oi])
            if val < best[0]:
                best = (val, int(base[bi]), int(other_arr[oi]))
        _, u, v = best
        edges.add((min(u, v), max(u, v)))
        union(u, v)
        roots = {find(x) for x in range(num_vertices)}

    def edge_d2(u: int, v: int) -> float:
        # Elementwise identical to an entry of the full (V, V) broadcast.
        diff0 = pos[u, 0] - pos[v, 0]
        diff1 = pos[u, 1] - pos[v, 1]
        return diff0 * diff0 + diff1 * diff1

    # Rescale so the mean edge length matches the paper (weights use the
    # unscaled geometry times `scale`, like the original full-matrix code).
    lengths = [math.sqrt(edge_d2(u, v)) for u, v in edges]
    scale = mean_length_m / (sum(lengths) / len(lengths))

    adjacency: List[List[Tuple[int, float]]] = [[] for _ in range(num_vertices)]
    for u, v in sorted(edges):
        w = math.sqrt(edge_d2(u, v)) * scale
        adjacency[u].append((v, w))
        adjacency[v].append((u, w))
    network = RoadNetwork(positions=pos * scale, adjacency=adjacency)
    if len(_NETWORK_CACHE) >= _NETWORK_CACHE_MAX:
        _NETWORK_CACHE.pop(next(iter(_NETWORK_CACHE)))
    _NETWORK_CACHE[cache_key] = network
    return network
