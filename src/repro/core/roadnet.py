"""Road-network model and spotlight search (paper §2.3, §5.1 workload).

The paper extracts a 7 km^2 circular region around IISc Bangalore from
OpenStreetMap: 1,000 vertices, 2,817 edges, average road length 84.5 m.
OSM is not available offline, so :func:`make_road_network` generates a
deterministic random-geometric graph matched to those statistics.  Cameras
are placed on vertices; the *spotlight* is the set of cameras reachable from
the last-seen location within ``speed * elapsed`` metres (weighted BFS =
Dijkstra over road lengths) or within a hop-ball assuming a fixed edge length
(unweighted BFS, the paper's TL-BFS).
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Set, Tuple

import numpy as np

__all__ = ["RoadNetwork", "make_road_network"]


@dataclass
class RoadNetwork:
    """Undirected road graph with per-edge lengths in metres."""

    positions: np.ndarray  # (V, 2) coordinates in metres
    adjacency: List[List[Tuple[int, float]]]  # vertex -> [(neighbor, length)]

    @property
    def num_vertices(self) -> int:
        return len(self.adjacency)

    @property
    def num_edges(self) -> int:
        return sum(len(nbrs) for nbrs in self.adjacency) // 2

    @property
    def mean_edge_length(self) -> float:
        total, count = 0.0, 0
        for u, nbrs in enumerate(self.adjacency):
            for v, w in nbrs:
                if v > u:
                    total += w
                    count += 1
        return total / max(count, 1)

    # ------------------------------------------------------------------ #
    # Spotlight searches                                                  #
    # ------------------------------------------------------------------ #
    def weighted_ball(self, source: int, radius: float) -> Dict[int, float]:
        """Dijkstra ball: vertices within ``radius`` metres of ``source``
        along the road network, with their distances (TL-WBFS)."""
        dist: Dict[int, float] = {source: 0.0}
        heap: List[Tuple[float, int]] = [(0.0, source)]
        while heap:
            d, u = heapq.heappop(heap)
            if d > dist.get(u, math.inf):
                continue
            for v, w in self.adjacency[u]:
                nd = d + w
                if nd <= radius and nd < dist.get(v, math.inf):
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
        return dist

    def hop_ball(self, source: int, max_hops: int) -> Dict[int, int]:
        """Unweighted BFS ball: vertices within ``max_hops`` edges (TL-BFS
        assumes a fixed road length for all edges)."""
        seen: Dict[int, int] = {source: 0}
        frontier = [source]
        hops = 0
        while frontier and hops < max_hops:
            hops += 1
            nxt: List[int] = []
            for u in frontier:
                for v, _ in self.adjacency[u]:
                    if v not in seen:
                        seen[v] = hops
                        nxt.append(v)
            frontier = nxt
        return seen

    def nearest_vertex(self, xy: Sequence[float]) -> int:
        d2 = np.sum((self.positions - np.asarray(xy)) ** 2, axis=1)
        return int(np.argmin(d2))


def make_road_network(
    num_vertices: int = 1000,
    target_edges: int = 2817,
    mean_length_m: float = 84.5,
    seed: int = 0,
) -> RoadNetwork:
    """Deterministic OSM-like graph matched to the paper's §5.1 statistics.

    Vertices are sampled in a disc; each vertex connects to its nearest
    neighbours until the edge budget is met, then positions are rescaled so
    the mean edge length matches ``mean_length_m``.  The construction keeps
    the graph connected (a relative-neighbourhood backbone via a nearest
    -neighbour chain) so BFS/Dijkstra spotlights behave like a road network.
    """
    rng = np.random.default_rng(seed)
    # Disc of area ~7 km^2 -> radius sqrt(7e6/pi) m; exact radius is
    # irrelevant because we rescale to the target mean edge length below.
    radius = math.sqrt(7.0e6 / math.pi)
    r = radius * np.sqrt(rng.uniform(0.0, 1.0, size=num_vertices))
    theta = rng.uniform(0.0, 2.0 * math.pi, size=num_vertices)
    pos = np.stack([r * np.cos(theta), r * np.sin(theta)], axis=1)

    # k-NN edges, deduplicated, preferring short roads.
    d2 = np.sum((pos[:, None, :] - pos[None, :, :]) ** 2, axis=-1)
    np.fill_diagonal(d2, np.inf)
    k = max(2, int(math.ceil(2.0 * target_edges / num_vertices)) + 1)
    knn = np.argsort(d2, axis=1)[:, :k]

    edges: Set[Tuple[int, int]] = set()
    # Backbone: chain each vertex to its nearest neighbour (keeps components
    # few), then add increasing-rank kNN edges until the budget is met.
    for u in range(num_vertices):
        v = int(knn[u, 0])
        edges.add((min(u, v), max(u, v)))
    for rank in range(1, k):
        if len(edges) >= target_edges:
            break
        for u in range(num_vertices):
            if len(edges) >= target_edges:
                break
            v = int(knn[u, rank])
            edges.add((min(u, v), max(u, v)))

    # Connect stray components through nearest cross-component pairs.
    parent = list(range(num_vertices))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a: int, b: int) -> None:
        parent[find(a)] = find(b)

    for u, v in edges:
        union(u, v)
    roots = {find(u) for u in range(num_vertices)}
    while len(roots) > 1:
        comp = {}
        for u in range(num_vertices):
            comp.setdefault(find(u), []).append(u)
        comps = list(comp.values())
        base = comps[0]
        best = (math.inf, -1, -1)
        for other in comps[1:]:
            for u in base:
                for v in other:
                    if d2[u, v] < best[0]:
                        best = (d2[u, v], u, v)
        _, u, v = best
        edges.add((min(u, v), max(u, v)))
        union(u, v)
        roots = {find(x) for x in range(num_vertices)}

    # Rescale so the mean edge length matches the paper.
    lengths = [math.sqrt(d2[u, v]) for u, v in edges]
    scale = mean_length_m / (sum(lengths) / len(lengths))
    pos = pos * scale

    adjacency: List[List[Tuple[int, float]]] = [[] for _ in range(num_vertices)]
    for u, v in sorted(edges):
        w = math.sqrt(d2[u, v]) * scale
        adjacency[u].append((v, w))
        adjacency[v].append((u, w))
    return RoadNetwork(positions=pos, adjacency=adjacency)
