"""Batching strategies (paper §4.4 + §5.1 baselines).

* :class:`DynamicBatcher` — Anveshak's deadline-driven batcher.  The event at
  the head of the queue joins the current batch ``B_p`` (size ``m``) iff

      t + xi(m+1) <= min(Delta_p, delta_x)

  where ``delta_x = a_x^1 + beta`` is the event deadline and
  ``Delta_p = min(delta_1..delta_m)`` the batch deadline.  Otherwise the
  current batch is submitted and the event seeds a new batch.  Even with an
  empty queue, the batch auto-submits when the local clock reaches
  ``Delta_p - xi(m)``.

* :class:`StaticBatcher` — fixed batch size ``b`` (``b=1`` is streaming).
  There is no bound on the wait for the batch to fill (the paper's §5.2.1
  critique of static batching).

* :class:`NOBBatcher` — the Near-Optimal Baseline (§5.1): a lookup table from
  input rate to the smallest batch size sustaining that rate, built by prior
  benchmarking on the *stable* system; at runtime picks the entry closest to
  the currently observed rate.  Near-optimal under static conditions, brittle
  under variability (the paper's Fig. 7c/9b result).
"""

from __future__ import annotations

import bisect
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional, Sequence, Tuple

from .events import Event

__all__ = ["PendingEvent", "DynamicBatcher", "StaticBatcher", "NOBBatcher", "build_nob_table"]

CostModel = Callable[[int], float]


@dataclass(slots=True)
class PendingEvent:
    """A queued event together with the timestamps the batcher needs."""

    event: Event
    arrival: float        # a_k^i on the local clock
    deadline: float       # delta_k^i = a_k^1 + beta_i (local-effective)


class _BatcherBase:
    def __init__(self, xi: CostModel, m_max: int) -> None:
        self.xi = xi
        self.m_max = int(m_max)
        self._current: List[PendingEvent] = []

    # -- introspection -------------------------------------------------- #
    @property
    def current_size(self) -> int:
        return len(self._current)

    def take(self) -> List[PendingEvent]:
        batch, self._current = self._current, []
        return batch

    def next_due_time(self) -> float:
        return math.inf

    def offer(self, pe: PendingEvent, t_now: float) -> Optional[List[PendingEvent]]:
        raise NotImplementedError

    # Tolerance for the auto-submit comparison: without it, a sub-ulp gap
    # between the due time and the clock can make the timer re-arm with a
    # delay too small to advance float time — an infinite loop (surfaced by
    # the clock-skew property tests).  Submitting <=1us early is harmless.
    _DUE_EPS = 1e-6

    def flush_if_due(self, t_now: float) -> Optional[List[PendingEvent]]:
        if self._current and t_now >= self.next_due_time() - self._DUE_EPS:
            return self.take()
        return None


class DynamicBatcher(_BatcherBase):
    """Anveshak's dynamic deadline-driven batcher (§4.4)."""

    def __init__(self, xi: CostModel, m_max: int = 25) -> None:
        super().__init__(xi, m_max)
        self._batch_deadline = math.inf  # Delta_p

    def take(self) -> List[PendingEvent]:
        batch = super().take()
        self._batch_deadline = math.inf
        return batch

    def next_due_time(self) -> float:
        """Auto-submit time ``Delta_p - xi(m)`` for the current batch."""
        if not self._current:
            return math.inf
        return self._batch_deadline - self.xi(len(self._current))

    def offer(self, pe: PendingEvent, t_now: float) -> Optional[List[PendingEvent]]:
        """Consider the head-of-queue event for the current batch.

        Returns a batch to submit for execution if the event could not join
        (or the batch hit ``m_max``); the event always ends up in a batch
        (possibly the freshly started one).
        """
        m = len(self._current)
        fits = t_now + self.xi(m + 1) <= min(self._batch_deadline, pe.deadline)
        submitted: Optional[List[PendingEvent]] = None
        if m > 0 and not fits:
            submitted = self.take()
        self._current.append(pe)
        self._batch_deadline = min(self._batch_deadline, pe.deadline)
        if len(self._current) >= self.m_max:
            full = self.take()
            if submitted is None:
                submitted = full
            else:  # both: flush the earlier batch first, keep order
                submitted = submitted + full
        return submitted


class StaticBatcher(_BatcherBase):
    """Fixed batch size; ``b=1`` is the streaming configuration (SB-1)."""

    def __init__(self, xi: CostModel, batch_size: int) -> None:
        super().__init__(xi, m_max=batch_size)
        self.batch_size = int(batch_size)

    def offer(self, pe: PendingEvent, t_now: float) -> Optional[List[PendingEvent]]:
        self._current.append(pe)
        if len(self._current) >= self.batch_size:
            return self.take()
        return None


def build_nob_table(
    xi: CostModel,
    m_max: int,
    rates: Sequence[float] = tuple(range(1, 1001, 10)),
) -> List[Tuple[float, int]]:
    """Prior benchmarking for NOB (§5.1): for each input rate ``omega`` the
    smallest batch size whose steady-state service rate ``b / xi(b)`` sustains
    it.  Falls back to ``m_max`` when no size suffices."""
    table: List[Tuple[float, int]] = []
    for omega in rates:
        chosen = m_max
        for b in range(1, m_max + 1):
            if b / max(xi(b), 1e-12) >= omega:
                chosen = b
                break
        table.append((float(omega), chosen))
    return table


class NOBBatcher(_BatcherBase):
    """Near-Optimal Baseline batcher driven by an input-rate lookup table."""

    def __init__(
        self,
        xi: CostModel,
        m_max: int = 25,
        table: Optional[List[Tuple[float, int]]] = None,
        rate_window: int = 32,
    ) -> None:
        super().__init__(xi, m_max)
        self.table = table if table is not None else build_nob_table(xi, m_max)
        self._arrivals: Deque[float] = deque(maxlen=rate_window)
        # The lookup runs once per arrival; for the (usual) strictly
        # increasing rate grid a bisect replaces the O(|table|) scan.  Tie
        # handling matches ``min()``'s first-minimum semantics exactly.
        self._rates: List[float] = [kv[0] for kv in self.table]
        self._batches: List[int] = [kv[1] for kv in self.table]
        self._rates_increasing = all(
            a < b for a, b in zip(self._rates, self._rates[1:])
        )

    def observed_rate(self) -> float:
        if len(self._arrivals) < 2:
            return 1.0
        span = self._arrivals[-1] - self._arrivals[0]
        if span <= 0:
            return float(len(self._arrivals))
        return (len(self._arrivals) - 1) / span

    def target_batch(self) -> int:
        rate = self.observed_rate()
        if not self._rates_increasing:
            best = min(self.table, key=lambda kv: abs(kv[0] - rate))
            return best[1]
        rates = self._rates
        i = bisect.bisect_left(rates, rate)
        if i == 0:
            return self._batches[0]
        if i == len(rates):
            return self._batches[-1]
        # rates[i-1] < rate <= rates[i]; on an exact tie min() keeps the
        # earlier (lower-rate) entry, hence <=.
        if rate - rates[i - 1] <= rates[i] - rate:
            return self._batches[i - 1]
        return self._batches[i]

    def offer(self, pe: PendingEvent, t_now: float) -> Optional[List[PendingEvent]]:
        self._arrivals.append(pe.arrival)
        self._current.append(pe)
        if len(self._current) >= self.target_batch():
            return self.take()
        return None
