"""Formal bounds under fixed conditions (paper §4.6.1).

Assumptions: constant input rate ``omega``, 1:1 selectivity, no pipelining,
``xi`` exact, static network/compute, temporally ordered events.

* **Stable batch size** ``m_i``: largest integer such that

      (m - 1) / omega + xi(m) <= beta - u          (fits the deadline)
      xi(m) <= (beta - u) / 2                      (stability: exec <= queue)

* **Max sustainable rate** ``omega_max`` and associated batch size when no
  ``m`` exists for the offered ``omega``; the **drop rate** is
  ``omega - omega_max``.

* **Batching latency overhead** vs streaming:
  ``(m - 1) / (2 omega) + xi(m) - xi(1)``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

__all__ = [
    "stable_batch_size",
    "max_sustainable_rate",
    "drop_rate",
    "batching_latency_overhead",
]

CostModel = Callable[[int], float]


def stable_batch_size(
    xi: CostModel,
    omega: float,
    budget_headroom: float,
    m_max: int = 1 << 16,
) -> Optional[int]:
    """Largest stable ``m`` for input rate ``omega`` given
    ``budget_headroom = beta_i - u_1^i``; None if the rate is unsustainable."""
    if omega <= 0 or budget_headroom <= 0:
        return None
    best: Optional[int] = None
    m = 1
    while m <= m_max:
        queue_time = (m - 1) / omega
        fits = queue_time + xi(m) <= budget_headroom and xi(m) <= budget_headroom / 2.0
        # Throughput sustainability: while a batch of m executes for xi(m),
        # omega * xi(m) new events arrive; boundedness needs m >= omega*xi(m).
        # (Strengthens the paper's two inequalities, which admit rates the
        # single-server queue cannot actually sustain.)
        sustainable = m >= omega * xi(m)
        if fits:
            if sustainable:
                best = m
            m += 1
        else:
            # xi is monotone and queue_time grows with m: once the deadline
            # constraint fails it fails for all larger m.
            break
    return best


def max_sustainable_rate(
    xi: CostModel,
    budget_headroom: float,
    m_max: int = 4096,
) -> Tuple[float, int]:
    """Maximize ``omega_max`` (and report the batch size achieving it) such
    that a stable ``m`` exists (§4.6.1 Drop Rate).

    For a fixed ``m`` satisfying the stability constraint, the rate constraint
    gives ``omega >= (m - 1) / (headroom - xi(m))``; the largest sustainable
    rate for that ``m`` is the *service* rate ``m / max(xi(m), queue window)``.
    We search m in [1, m_max] for the best steady-state throughput whose
    queueing fits the headroom.
    """
    best_rate, best_m = 0.0, 1
    if budget_headroom <= 0:
        return best_rate, best_m
    for m in range(1, m_max + 1):
        ex = xi(m)
        if ex > budget_headroom / 2.0:
            break
        window = budget_headroom - ex  # time available to queue m events
        if window <= 0:
            continue
        # (m-1)/omega <= window  =>  omega can be as high as service allows;
        # steady state requires omega <= m / xi(m) (service rate) and
        # omega >= (m-1)/window is satisfiable for any omega above it.
        rate = min(m / max(ex, 1e-12), (m - 1) / window if m > 1 else math.inf)
        rate = m / max(ex, 1e-12) if m > 1 else 1.0 / max(ex, 1e-12)
        # The batch must be accumulable within the window:
        if m > 1 and (m - 1) / rate > window:
            rate = (m - 1) / window
        if rate > best_rate:
            best_rate, best_m = rate, m
    return best_rate, best_m


def drop_rate(
    xi: CostModel,
    omega: float,
    budget_headroom: float,
    m_max: int = 4096,
) -> Tuple[float, float, int]:
    """Returns ``(drops_per_sec, omega_max, m)`` for an offered rate ``omega``
    (0 drops if the rate is sustainable)."""
    if stable_batch_size(xi, omega, budget_headroom, m_max) is not None:
        m = stable_batch_size(xi, omega, budget_headroom, m_max)
        return 0.0, omega, int(m)
    omega_max, m = max_sustainable_rate(xi, budget_headroom, m_max)
    return max(omega - omega_max, 0.0), omega_max, m


def batching_latency_overhead(xi: CostModel, omega: float, m: int) -> float:
    """Average per-event latency added by batching vs streaming (§4.6.1)."""
    if omega <= 0:
        return 0.0
    return (m - 1) / (2.0 * omega) + xi(m) - xi(1)
