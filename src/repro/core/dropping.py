"""The three drop points (paper §4.3).

An event ``e_k^i`` is *stale* at task ``tau_i`` once ``u_k^i + pi_k^i``
exceeds the completion budget ``beta_i``.  Since the processing duration
``pi = q + xi(b)`` is only fully known after execution, the staleness test is
applied three times with progressively better information:

1. **Before queuing** — optimistic: assumes zero queuing and streaming
   execution ``xi(1)``.  Drops only events that cannot possibly make it.
2. **Before execution** — the batch is formed: queuing time ``q`` and batch
   execution estimate ``xi(b)`` are known.
3. **Before transmit** — the actual processing time ``pi`` has been spent;
   also the point where the partitioner has fixed the *destination* task, so
   the per-downstream budget (§4.3.4) applies.

Events flagged ``avoid_drop`` (positive detections) and probes always pass.
All comparisons use the upstream time ``u = a_i - a_1`` and are clock-skew
resilient (§4.6.2): a device skew ``sigma_i`` enters both sides and cancels.
"""

from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

from .events import Event

__all__ = [
    "drop_before_queuing",
    "drop_before_exec",
    "drop_before_transmit",
]


def drop_before_queuing(
    source_arrival: float,
    arrival: float,
    xi1: float,
    beta: float,
    *,
    avoid_drop: bool = False,
) -> bool:
    """Drop point 1 (§4.3.1).  True => drop.

    Parameters mirror the paper: ``u = arrival - source_arrival`` and the
    event is dropped iff ``u + xi_i(1) > beta_i``.
    """
    if avoid_drop:
        return False
    u = arrival - source_arrival
    return u + xi1 > beta


def drop_before_exec(
    batch: Sequence[Tuple[float, float, float, Event]],
    xi_b: float,
    beta: float,
) -> Tuple[List[Event], List[Event]]:
    """Drop point 2 (§4.3.2), applied to a formed batch.

    ``batch`` holds ``(a_k^1, a_k^i, q_k^i, event)`` tuples; ``xi_b`` is the
    execution estimate for the *current* batch size.  Returns
    ``(retained, dropped)``.  Note the paper keeps ``xi_i(b)`` for the full
    batch even while filtering — the drop decision is per-event but the batch
    estimate is not re-shrunk mid-test (conservative).
    """
    retained: List[Event] = []
    dropped: List[Event] = []
    for a1, ai, q, ev in batch:
        if ev.header.avoid_drop or ev.header.is_probe:
            retained.append(ev)
            continue
        u = ai - a1
        if u + q + xi_b <= beta:
            retained.append(ev)
        else:
            dropped.append(ev)
    return retained, dropped


def drop_before_transmit(
    source_arrival: float,
    arrival: float,
    pi: float,
    beta: float,
    *,
    avoid_drop: bool = False,
) -> bool:
    """Drop point 3 (§4.3.3).  True => drop.

    ``pi = q + xi(b)`` is the realized processing duration; ``beta`` is the
    budget *for the destination chosen by the partitioner* (§4.3.4).
    """
    if avoid_drop:
        return False
    u = arrival - source_arrival
    return u + pi > beta
