"""Tracking Logic (TL) strategies (paper §2.2.4, Alg. 1, §5.2.2).

TL receives per-frame detections from CR.  On a *negative* detection (entity
lost) it **expands** the search space — the spotlight — and activates the
cameras inside it; on a *positive* detection it **contracts** the spotlight
to the detecting camera.  Strategies:

* :class:`TLBase`  — all cameras always active (contemporary systems).
* :class:`TLBFS`   — hop-ball spotlight assuming a fixed road length.
* :class:`TLWBFS`  — Dijkstra-ball spotlight using true road lengths (Alg. 1).
* :class:`TLProbabilistic` — App 4: a naive-Bayes-style likelihood over paths;
  activates the smallest camera set covering ``coverage`` probability mass.
  Also exposes a *multi-entity* path (:meth:`TLProbabilistic.track` /
  :meth:`TLProbabilistic.spotlight_multi`) that searches all tracked
  entities' balls at once — optionally through the batched
  ``repro.kernels.spotlight_ball`` CSR relaxation kernel.

All spotlight strategies are configured with the entity's expected peak speed
``es`` (m/s): the spotlight radius grows as ``es * (now - last_seen_time)``
while the entity is in a blind-spot (Rate of Expansion, §5.2.1).

The weighted-ball strategies are *incremental*: the radius only grows during
a blind spot, so each TL tick resumes the previous Dijkstra frontier
(:class:`repro.core.roadnet.ResumableDijkstra`) instead of recomputing the
ball from scratch — O(newly reached road) per tick instead of O(ball).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .roadnet import ResumableDijkstra, RoadNetwork

__all__ = [
    "Detection",
    "TrackingLogic",
    "TLBase",
    "TLBFS",
    "TLWBFS",
    "TLProbabilistic",
    "multi_source_spotlight",
]


def multi_source_spotlight(
    network: RoadNetwork,
    camera_vertices: Dict[int, int],
    sources: Sequence[int],
    radii: Sequence[float],
    *,
    coverage: Optional[float] = None,
) -> List[Set[int]]:
    """Per-source spotlight camera sets via **one** batched multi-source
    ``spotlight_ball`` relaxation (bucket-padded through
    ``repro.kernels.dispatch``, so the dense min-plus adjacency stays
    device-resident and one jit compile serves every bucket shape).

    ``sources``/``radii`` give each query's ball (source vertex, radius in
    metres).  With ``coverage=None`` each set is *every* camera inside the
    ball — bitwise equal to a per-source Dijkstra ball, which is what makes
    the fused multi-query path bit-exact against per-query serial runs.
    With ``coverage=c`` each set is the smallest likelihood-mass cover
    (:class:`TLProbabilistic`'s activation rule), vectorized per source.

    This is the single multi-source ball implementation shared by
    :meth:`TLProbabilistic.spotlight_multi` and the multi-query tenancy
    plane's union spotlight (``repro.query``).
    """
    import numpy as np

    from repro.kernels import dispatch

    if len(sources) == 0:
        return []
    indptr, indices, weights = network.csr()
    src = np.asarray(sources, dtype=np.int32)
    rad = np.asarray(radii, dtype=np.float32)
    # Dedupe (source, radius) pairs before dispatch: queries sharing a
    # blind-spot camera would otherwise pad duplicate rows into the kernel
    # call (inflating the bucket).  Rows are independent under min-plus
    # relaxation, so collapsing duplicates is result-invariant.
    row_of_pair: Dict[Tuple[int, float], int] = {}
    row_of = np.empty(len(src), dtype=np.int64)
    for qi, pair in enumerate(zip(src.tolist(), rad.tolist())):
        row = row_of_pair.get(pair)
        if row is None:
            row = row_of_pair[pair] = len(row_of_pair)
        row_of[qi] = row
    uniq_src = np.fromiter(
        (p[0] for p in row_of_pair), dtype=np.int32, count=len(row_of_pair)
    )
    uniq_rad = np.fromiter(
        (p[1] for p in row_of_pair), dtype=np.float32, count=len(row_of_pair)
    )
    dists = np.asarray(
        dispatch.spotlight_ball(indptr, indices, weights, uniq_src, uniq_rad)
    )  # (unique rows, V); inf outside each ball
    cam_ids = np.fromiter(camera_vertices.keys(), dtype=np.int64)
    cam_verts = np.fromiter(camera_vertices.values(), dtype=np.int64)
    degrees = np.diff(indptr).astype(np.float64)
    row_sets: Dict[int, Set[int]] = {}
    out: List[Set[int]] = []
    for qi in range(len(src)):
        row = int(row_of[qi])
        cached = row_sets.get(row)
        if cached is not None:
            out.append(set(cached))
            continue
        d = dists[row, cam_verts]
        inside = np.isfinite(d)
        if not inside.any():
            chosen: Set[int] = set()
        elif coverage is None:
            chosen = {int(c) for c in cam_ids[inside]}
        else:
            radius = float(rad[qi])
            scale = max(radius, 1.0)
            deg = np.maximum(degrees[cam_verts[inside]], 1.0)
            mass = np.exp(-2.0 * d[inside].astype(np.float64) / scale) / deg
            order = np.argsort(-mass, kind="stable")
            csum = np.cumsum(mass[order])
            cut = int(np.searchsorted(csum, coverage * csum[-1])) + 1
            chosen = {int(c) for c in cam_ids[inside][order[:cut]]}
        row_sets[row] = chosen
        out.append(set(chosen))
    return out


@dataclass(slots=True)
class Detection:
    """A CR verdict for one frame: which camera, was the entity present."""

    camera_id: int
    positive: bool
    timestamp: float


class TrackingLogic:
    """Base class: maintains last-seen state and the active camera set."""

    def __init__(
        self,
        network: RoadNetwork,
        camera_vertices: Dict[int, int],
        entity_speed: float = 4.0,
        min_radius_m: float = 0.0,
    ) -> None:
        self.network = network
        self.camera_vertices = dict(camera_vertices)  # camera_id -> vertex
        self._vertex_cameras: Dict[int, List[int]] = {}
        for cam, v in self.camera_vertices.items():
            self._vertex_cameras.setdefault(v, []).append(cam)
        self.entity_speed = float(entity_speed)
        self.min_radius_m = float(min_radius_m)
        self.last_seen_camera: Optional[int] = None
        self.last_seen_time: Optional[float] = None
        self.active: Set[int] = set(self.camera_vertices)  # all on at start

    # ------------------------------------------------------------------ #
    def cameras_in_vertices(self, vertices: Iterable[int]) -> Set[int]:
        out: Set[int] = set()
        vc = self._vertex_cameras
        for v in vertices:
            cams = vc.get(v)
            if cams:
                out.update(cams)
        return out

    def spotlight(self, now: float) -> Set[int]:
        """Camera set for the current blind-spot duration.  Subclasses
        override; the default keeps everything active."""
        return set(self.camera_vertices)

    # ------------------------------------------------------------------ #
    def update(self, detections: Sequence[Detection], now: float) -> Set[int]:
        """Process a batch of CR detections; returns the new active set.

        Positive detection => contract to the detecting camera (§2.2.4);
        none => expand the spotlight from the last-seen location.
        """
        positives = [d for d in detections if d.positive]
        if positives:
            latest = max(positives, key=lambda d: d.timestamp)
            self.last_seen_camera = latest.camera_id
            self.last_seen_time = latest.timestamp
            self.active = {latest.camera_id}
        else:
            self.active = self.spotlight(now)
        return set(self.active)


class TLBase(TrackingLogic):
    """Keep every camera active (the paper's baseline; does not scale)."""

    def spotlight(self, now: float) -> Set[int]:
        return set(self.camera_vertices)

    def update(self, detections: Sequence[Detection], now: float) -> Set[int]:
        for d in detections:
            if d.positive:
                self.last_seen_camera = d.camera_id
                self.last_seen_time = d.timestamp
        self.active = set(self.camera_vertices)
        return set(self.active)


class _SpotlightTL(TrackingLogic):
    def __init__(self, *args, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        # Incremental-ball state: valid while the source stays fixed and the
        # radius keeps growing (one blind-spot episode).
        self._search: Optional[ResumableDijkstra] = None
        self._search_radius: float = -math.inf
        self._ball_cams: Set[int] = set()
        self._consumed: int = 0

    def _radius_m(self, now: float) -> float:
        if self.last_seen_time is None:
            return math.inf  # never seen: search everywhere
        elapsed = max(now - self.last_seen_time, 0.0)
        return self.min_radius_m + self.entity_speed * elapsed

    def _source_vertex(self) -> Optional[int]:
        if self.last_seen_camera is None:
            return None
        return self.camera_vertices.get(self.last_seen_camera)

    def _incremental_ball(self, src: int, radius: float) -> Dict[int, float]:
        """Resume (or restart) the Dijkstra ball; returns the live settled
        map, identical to ``weighted_ball(src, radius)``."""
        search = self._search
        if search is None or search.source != src or radius < self._search_radius:
            search = self._search = ResumableDijkstra(self.network, src)
            self._ball_cams = set()
            self._consumed = 0
        self._search_radius = radius
        return search.ball(radius)

    def _incremental_ball_cams(self, src: int, radius: float) -> Set[int]:
        """Cameras inside the incremental ball; folds only *newly settled*
        vertices into the cached camera set."""
        self._incremental_ball(src, radius)
        search = self._search
        order = search.order
        if self._consumed < len(order):
            vc = self._vertex_cameras
            cams = self._ball_cams
            for v in order[self._consumed :]:
                found = vc.get(v)
                if found:
                    cams.update(found)
            self._consumed = len(order)
        return self._ball_cams


class TLBFS(_SpotlightTL):
    """Spotlight via unweighted BFS with an assumed fixed road length."""

    def __init__(self, *args, fixed_edge_length_m: float = 84.5, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        self.fixed_edge_length_m = float(fixed_edge_length_m)

    def spotlight(self, now: float) -> Set[int]:
        src = self._source_vertex()
        radius = self._radius_m(now)
        if src is None or math.isinf(radius):
            return set(self.camera_vertices)
        hops = int(math.ceil(radius / self.fixed_edge_length_m))
        ball = self.network.hop_ball(src, hops)
        return self.cameras_in_vertices(ball)


class TLWBFS(_SpotlightTL):
    """Spotlight via weighted BFS (Dijkstra) over true road lengths (Alg. 1).

    Aware of exact segment lengths, its spotlight grows in finer steps and
    stays smaller than TL-BFS for the same blind-spot duration (§5.2.2).
    The ball is expanded incrementally across ticks."""

    def spotlight(self, now: float) -> Set[int]:
        src = self._source_vertex()
        radius = self._radius_m(now)
        if src is None or math.isinf(radius):
            return set(self.camera_vertices)
        return set(self._incremental_ball_cams(src, radius))


class TLProbabilistic(_SpotlightTL):
    """App 4: likelihood-weighted activation.

    Assigns each reachable camera a likelihood that the entity's path reaches
    it — a naive-Bayes combination of (a) road-distance decay from the last
    seen location and (b) a learned/uniform prior over turns (vertex degree).
    Activates the smallest set covering ``coverage`` of the probability mass,
    so it can keep the active set tighter than pure reachability.

    Multi-entity mode: :meth:`track` registers additional entity queries
    (each with its own last-seen state); :meth:`spotlight_multi` unions the
    per-entity coverage sets, evaluating all Dijkstra balls either
    incrementally in Python or as one batched CSR relaxation via the
    ``spotlight_ball`` kernel (``use_kernel=True``).
    """

    def __init__(self, *args, coverage: float = 0.9, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        self.coverage = float(coverage)
        # entity id -> (last seen vertex, last seen time)
        self.entities: Dict[Any, Tuple[int, float]] = {}
        self._entity_searches: Dict[Any, ResumableDijkstra] = {}

    # -- single-entity (paper App 4) ----------------------------------- #
    def spotlight(self, now: float) -> Set[int]:
        src = self._source_vertex()
        radius = self._radius_m(now)
        if src is None or math.isinf(radius):
            return set(self.camera_vertices)
        ball = self._incremental_ball(src, radius)
        cams = self._incremental_ball_cams(src, radius)
        if not cams:
            return set()
        return self._coverage_set(ball, cams, radius)

    def _coverage_set(
        self, ball: Dict[int, float], cams: Iterable[int], radius: float
    ) -> Set[int]:
        # Likelihood: exponential decay with distance, normalized.
        scores: List[Tuple[float, int]] = []
        scale = max(radius, 1.0)
        adjacency = self.network.adjacency
        camera_vertices = self.camera_vertices
        for cam in cams:
            v = camera_vertices[cam]
            d = ball.get(v, radius)
            deg = max(len(adjacency[v]), 1)
            # Random-walk heuristic: mass dilutes with distance and branching.
            scores.append((math.exp(-2.0 * d / scale) / deg, cam))
        total = sum(s for s, _ in scores)
        scores.sort(reverse=True)
        chosen: Set[int] = set()
        acc = 0.0
        threshold = self.coverage * total
        for s, cam in scores:
            chosen.add(cam)
            acc += s
            if acc >= threshold:
                break
        return chosen

    # -- multi-entity -------------------------------------------------- #
    def track(self, entity: Any, camera_id: int, timestamp: float) -> None:
        """Register (or refresh) an entity query's last positive sighting."""
        vertex = self.camera_vertices[camera_id]
        self.entities[entity] = (vertex, timestamp)
        self._entity_searches.pop(entity, None)  # contraction: restart ball

    def untrack(self, entity: Any) -> None:
        self.entities.pop(entity, None)
        self._entity_searches.pop(entity, None)

    def _entity_radius(self, last_time: float, now: float) -> float:
        return self.min_radius_m + self.entity_speed * max(now - last_time, 0.0)

    def spotlight_multi(self, now: float, use_kernel: bool = False) -> Set[int]:
        """Union of per-entity coverage sets for all tracked entities."""
        if not self.entities:
            return set()
        if use_kernel:
            return self._spotlight_multi_kernel(now)
        chosen: Set[int] = set()
        for entity, (vertex, last_time) in self.entities.items():
            radius = self._entity_radius(last_time, now)
            search = self._entity_searches.get(entity)
            if search is None or search.source != vertex:
                search = ResumableDijkstra(self.network, vertex)
                self._entity_searches[entity] = search
            ball = search.ball(radius)
            cams = self.cameras_in_vertices(ball)
            if cams:
                chosen |= self._coverage_set(ball, cams, radius)
        return chosen

    def _spotlight_multi_kernel(self, now: float) -> Set[int]:
        """Batched path: delegate to the shared multi-source ball
        implementation (:func:`multi_source_spotlight`) — one bucket-padded
        ``spotlight_ball`` relaxation for all entities' balls, then
        vectorized per-entity coverage selection, unioned."""
        items = list(self.entities.items())
        per_entity = multi_source_spotlight(
            self.network,
            self.camera_vertices,
            [v for _, (v, _) in items],
            [self._entity_radius(t, now) for _, (_, t) in items],
            coverage=self.coverage,
        )
        chosen: Set[int] = set()
        for cams in per_entity:
            chosen |= cams
        return chosen
