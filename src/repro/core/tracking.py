"""Tracking Logic (TL) strategies (paper §2.2.4, Alg. 1, §5.2.2).

TL receives per-frame detections from CR.  On a *negative* detection (entity
lost) it **expands** the search space — the spotlight — and activates the
cameras inside it; on a *positive* detection it **contracts** the spotlight
to the detecting camera.  Strategies:

* :class:`TLBase`  — all cameras always active (contemporary systems).
* :class:`TLBFS`   — hop-ball spotlight assuming a fixed road length.
* :class:`TLWBFS`  — Dijkstra-ball spotlight using true road lengths (Alg. 1).
* :class:`TLProbabilistic` — App 4: a naive-Bayes-style likelihood over paths;
  activates the smallest camera set covering ``coverage`` probability mass.

All spotlight strategies are configured with the entity's expected peak speed
``es`` (m/s): the spotlight radius grows as ``es * (now - last_seen_time)``
while the entity is in a blind-spot (Rate of Expansion, §5.2.1).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .roadnet import RoadNetwork

__all__ = [
    "Detection",
    "TrackingLogic",
    "TLBase",
    "TLBFS",
    "TLWBFS",
    "TLProbabilistic",
]


@dataclass
class Detection:
    """A CR verdict for one frame: which camera, was the entity present."""

    camera_id: int
    positive: bool
    timestamp: float


class TrackingLogic:
    """Base class: maintains last-seen state and the active camera set."""

    def __init__(
        self,
        network: RoadNetwork,
        camera_vertices: Dict[int, int],
        entity_speed: float = 4.0,
        min_radius_m: float = 0.0,
    ) -> None:
        self.network = network
        self.camera_vertices = dict(camera_vertices)  # camera_id -> vertex
        self._vertex_cameras: Dict[int, List[int]] = {}
        for cam, v in self.camera_vertices.items():
            self._vertex_cameras.setdefault(v, []).append(cam)
        self.entity_speed = float(entity_speed)
        self.min_radius_m = float(min_radius_m)
        self.last_seen_camera: Optional[int] = None
        self.last_seen_time: Optional[float] = None
        self.active: Set[int] = set(self.camera_vertices)  # all on at start

    # ------------------------------------------------------------------ #
    def cameras_in_vertices(self, vertices: Iterable[int]) -> Set[int]:
        out: Set[int] = set()
        for v in vertices:
            out.update(self._vertex_cameras.get(v, ()))
        return out

    def spotlight(self, now: float) -> Set[int]:
        """Camera set for the current blind-spot duration.  Subclasses
        override; the default keeps everything active."""
        return set(self.camera_vertices)

    # ------------------------------------------------------------------ #
    def update(self, detections: Sequence[Detection], now: float) -> Set[int]:
        """Process a batch of CR detections; returns the new active set.

        Positive detection => contract to the detecting camera (§2.2.4);
        none => expand the spotlight from the last-seen location.
        """
        positives = [d for d in detections if d.positive]
        if positives:
            latest = max(positives, key=lambda d: d.timestamp)
            self.last_seen_camera = latest.camera_id
            self.last_seen_time = latest.timestamp
            self.active = {latest.camera_id}
        else:
            self.active = self.spotlight(now)
        return set(self.active)


class TLBase(TrackingLogic):
    """Keep every camera active (the paper's baseline; does not scale)."""

    def spotlight(self, now: float) -> Set[int]:
        return set(self.camera_vertices)

    def update(self, detections: Sequence[Detection], now: float) -> Set[int]:
        for d in detections:
            if d.positive:
                self.last_seen_camera = d.camera_id
                self.last_seen_time = d.timestamp
        self.active = set(self.camera_vertices)
        return set(self.active)


class _SpotlightTL(TrackingLogic):
    def _radius_m(self, now: float) -> float:
        if self.last_seen_time is None:
            return math.inf  # never seen: search everywhere
        elapsed = max(now - self.last_seen_time, 0.0)
        return self.min_radius_m + self.entity_speed * elapsed

    def _source_vertex(self) -> Optional[int]:
        if self.last_seen_camera is None:
            return None
        return self.camera_vertices.get(self.last_seen_camera)


class TLBFS(_SpotlightTL):
    """Spotlight via unweighted BFS with an assumed fixed road length."""

    def __init__(self, *args, fixed_edge_length_m: float = 84.5, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        self.fixed_edge_length_m = float(fixed_edge_length_m)

    def spotlight(self, now: float) -> Set[int]:
        src = self._source_vertex()
        radius = self._radius_m(now)
        if src is None or math.isinf(radius):
            return set(self.camera_vertices)
        hops = int(math.ceil(radius / self.fixed_edge_length_m))
        ball = self.network.hop_ball(src, hops)
        return self.cameras_in_vertices(ball)


class TLWBFS(_SpotlightTL):
    """Spotlight via weighted BFS (Dijkstra) over true road lengths (Alg. 1).

    Aware of exact segment lengths, its spotlight grows in finer steps and
    stays smaller than TL-BFS for the same blind-spot duration (§5.2.2)."""

    def spotlight(self, now: float) -> Set[int]:
        src = self._source_vertex()
        radius = self._radius_m(now)
        if src is None or math.isinf(radius):
            return set(self.camera_vertices)
        ball = self.network.weighted_ball(src, radius)
        return self.cameras_in_vertices(ball)


class TLProbabilistic(_SpotlightTL):
    """App 4: likelihood-weighted activation.

    Assigns each reachable camera a likelihood that the entity's path reaches
    it — a naive-Bayes combination of (a) road-distance decay from the last
    seen location and (b) a learned/uniform prior over turns (vertex degree).
    Activates the smallest set covering ``coverage`` of the probability mass,
    so it can keep the active set tighter than pure reachability.
    """

    def __init__(self, *args, coverage: float = 0.9, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        self.coverage = float(coverage)

    def spotlight(self, now: float) -> Set[int]:
        src = self._source_vertex()
        radius = self._radius_m(now)
        if src is None or math.isinf(radius):
            return set(self.camera_vertices)
        ball = self.network.weighted_ball(src, radius)
        cams = self.cameras_in_vertices(ball)
        if not cams:
            return cams
        # Likelihood: exponential decay with distance, normalized.
        scores: List[Tuple[float, int]] = []
        scale = max(radius, 1.0)
        for cam in cams:
            v = self.camera_vertices[cam]
            d = ball.get(v, radius)
            deg = max(len(self.network.adjacency[v]), 1)
            # Random-walk heuristic: mass dilutes with distance and branching.
            scores.append((math.exp(-2.0 * d / scale) / deg, cam))
        total = sum(s for s, _ in scores)
        scores.sort(reverse=True)
        chosen: Set[int] = set()
        acc = 0.0
        for s, cam in scores:
            chosen.add(cam)
            acc += s
            if acc >= self.coverage * total:
                break
        return chosen
