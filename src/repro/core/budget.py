"""Completion-budget maintenance (paper §4.5).

The completion budget ``beta_i`` of task ``tau_i`` is the duration allowed for
an arriving event to finish processing at this task, *including* its upstream
time since the source.  It is the single quantity that drives both the drop
points (§4.3) and the dynamic batcher (§4.4).

Updates
-------
* **Reject** (§4.5.1): event ``e_k`` dropped at ``tau_j`` with excess
  ``epsilon = d_k^j - beta_j``.  Every upstream task ``tau_i`` reduces:

      lam = min(epsilon * q_k^i / qbar_k^j,   xi_i(m_k^i) - xi_i(1))
      beta_i = min(d_k^i - lam, beta_i_old)

* **Accept** (§4.5.2): the slowest event of a batch reaches the sink
  ``epsilon = gamma - u_k^n`` early, with ``epsilon > epsilon_max``.  Every
  upstream task increases:

      lam = min(epsilon * xi_i(m_k^i) / xibar_k^{n-1},
                (m_max - m_k^i) * q_k^i / m_k^i + xi_i(m_max) - xi_i(m_k^i))
      beta_i = max(d_k^i + lam, beta_i_old)

* **Bootstrap**: no budget assigned (=> no drops, batch size 1) until the
  first signal, which sets the budget directly, ignoring ``beta_old``.

* **Probes**: for every ``probe_every``-th dropped event a probe is forwarded
  downstream un-droppably; reaching the sink within gamma triggers an accept
  so collapsed budgets recover.

The min/max against ``beta_old`` makes updates resilient to out-of-order
signals; using durations (not absolute times) plus the ``kappa_1 == kappa_n``
requirement makes them resilient to clock skew (§4.6.2).
"""

from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from .events import AcceptSignal, EventRecord, RejectSignal

__all__ = ["BudgetState", "TaskBudget"]

# Cost model type: xi(b) -> expected execution duration for a batch of size b.
CostModel = Callable[[int], float]


@dataclass
class BudgetState:
    """Budget for one (task, downstream) pair (§4.3.4: one per downstream)."""

    value: Optional[float] = None  # None => unassigned (bootstrap: no drops)
    initialized: bool = False

    @property
    def effective(self) -> float:
        return math.inf if self.value is None else self.value


class TaskBudget:
    """Per-task budget bookkeeping: event records + signal handling.

    Parameters
    ----------
    xi:
        The task's batch cost model ``xi_i(b)``.
    m_max:
        The user-configured maximum batch size ``m^max``.
    record_capacity:
        Bounded LRU of per-event 3-tuples ``<d, q, m>`` (paper §4.5); old
        records are evicted — a late signal for an evicted event is ignored,
        which is safe because updates are clamped against ``beta_old``.
    """

    def __init__(
        self,
        name: str,
        xi: CostModel,
        m_max: int = 25,
        record_capacity: int = 4096,
    ) -> None:
        self.name = name
        self.xi = xi
        self.m_max = int(m_max)
        self._records: "OrderedDict[int, EventRecord]" = OrderedDict()
        self._capacity = int(record_capacity)
        self._budgets: Dict[str, BudgetState] = {}
        # Cached min over per-downstream budgets: ``min_budget`` is consulted
        # once per arriving event, so recomputing the min there is hot.
        self._min_cache: Optional[float] = None

    # ------------------------------------------------------------------ #
    # Records                                                            #
    # ------------------------------------------------------------------ #
    def record(self, event_id: int, rec: EventRecord) -> None:
        records = self._records
        if event_id in records:
            records.move_to_end(event_id)
        records[event_id] = rec
        if len(records) > self._capacity:
            records.popitem(last=False)

    def get_record(self, event_id: int) -> Optional[EventRecord]:
        return self._records.get(event_id)

    # ------------------------------------------------------------------ #
    # Budget access                                                      #
    # ------------------------------------------------------------------ #
    def state(self, downstream: str = "") -> BudgetState:
        if downstream not in self._budgets:
            self._budgets[downstream] = BudgetState()
        return self._budgets[downstream]

    def budget(self, downstream: str = "") -> float:
        """Effective budget (inf while unassigned — bootstrap semantics)."""
        return self.state(downstream).effective

    def min_budget(self) -> float:
        """Most conservative budget across downstream paths (used at drop
        points before the destination of an event is known)."""
        cached = self._min_cache
        if cached is not None:
            return cached
        if not self._budgets:
            value = math.inf
        else:
            value = min(s.effective for s in self._budgets.values())
        self._min_cache = value
        return value

    def set_budget(self, value: float, downstream: str = "") -> None:
        st = self.state(downstream)
        st.value = value
        st.initialized = True
        self._min_cache = None

    # ------------------------------------------------------------------ #
    # Signal handling (paper §4.5)                                       #
    # ------------------------------------------------------------------ #
    def on_reject(self, sig: RejectSignal, downstream: str = "") -> Optional[float]:
        """Reduce the budget toward ``downstream`` after a drop there.

        Returns the new budget, or None if the event record is unknown.
        """
        rec = self.get_record(sig.event_id)
        if rec is None:
            return None
        if sig.q_bar <= 0.0:
            # No queuing upstream => nothing attributable to this task.
            lam = 0.0
        else:
            lam = min(
                sig.epsilon * (rec.queuing / sig.q_bar),
                max(self.xi(rec.batch_size) - self.xi(1), 0.0),
            )
        st = self.state(downstream)
        candidate = rec.departure - lam
        if not st.initialized:
            st.value = candidate  # bootstrap: ignore beta_old
        else:
            st.value = min(candidate, st.effective)
        st.initialized = True
        self._min_cache = None
        return st.value

    def on_accept(self, sig: AcceptSignal, downstream: str = "") -> Optional[float]:
        """Increase the budget toward ``downstream`` after an early arrival."""
        rec = self.get_record(sig.event_id)
        if rec is None:
            return None
        if sig.xi_bar <= 0.0:
            share = 0.0
        else:
            share = sig.epsilon * (rec.xi / sig.xi_bar)
        m = max(rec.batch_size, 1)
        headroom = (self.m_max - m) * (rec.queuing / m) + self.xi(self.m_max) - self.xi(m)
        lam = min(share, max(headroom, 0.0))
        st = self.state(downstream)
        candidate = rec.departure + lam
        if not st.initialized:
            st.value = candidate  # bootstrap: ignore beta_old
        else:
            st.value = max(candidate, st.value if st.value is not None else -math.inf)
        st.initialized = True
        self._min_cache = None
        return st.value
