"""Device clock model with skew (paper §4.6.2).

Devices on a MAN/WAN have unsynchronized clocks.  Anveshak's decisions are
designed so that, as long as the *source* and *sink* clocks agree
(kappa_1 == kappa_n), a constant per-device skew ``sigma_i = kappa_i - kappa_1``
cancels out of every drop and batch comparison.  We model that skew explicitly
so the property tests can verify the cancellation.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["Clock"]


@dataclass(slots=True)
class Clock:
    """A device clock: reads true (simulation) time plus a fixed skew.

    ``now(t_true)`` is what this device's clock shows when the global
    simulation time is ``t_true``.  Durations measured on a single device are
    skew-free; only absolute timestamps carry the skew.
    """

    skew: float = 0.0

    def now(self, t_true: float) -> float:
        return t_true + self.skew
