"""Device clock model with skew (paper §4.6.2).

Devices on a MAN/WAN have unsynchronized clocks.  Anveshak's decisions are
designed so that, as long as the *source* and *sink* clocks agree
(kappa_1 == kappa_n), a constant per-device skew ``sigma_i = kappa_i - kappa_1``
cancels out of every drop and batch comparison.  We model that skew explicitly
so the property tests can verify the cancellation.
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass

__all__ = ["Clock", "monotonic"]


def monotonic() -> float:
    """Process-local monotonic clock for *measuring* wall time (benchmark
    and log timings).

    Every host-side timing read in the tree routes through here: simulation
    time comes from the DES, and raw ``time.time()`` reads are flagged by
    the replay-safety analyzer (DET002) because a wall-clock read inside
    decision logic is a determinism leak.  ``perf_counter`` is monotonic
    and unaffected by NTP steps, so elapsed-time deltas are also more
    honest than ``time.time()`` differences.
    """
    return _time.perf_counter()


@dataclass(slots=True)
class Clock:
    """A device clock: reads true (simulation) time plus a fixed skew.

    ``now(t_true)`` is what this device's clock shows when the global
    simulation time is ``t_true``.  Durations measured on a single device are
    skew-free; only absolute timestamps carry the skew.
    """

    skew: float = 0.0

    def now(self, t_true: float) -> float:
        return t_true + self.skew
