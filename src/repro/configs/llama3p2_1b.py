"""llama3.2-1b — small llama3 dense GQA [hf:meta-llama/Llama-3.2-1B]."""

from repro.config.base import ModelConfig, register_config


@register_config("llama3.2-1b")
def llama3p2_1b() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        arch_type="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        head_dim=64,
        rope_theta=500_000.0,
        tie_embeddings=True,
        citation="Llama-3.2-1B model card [hf:meta-llama/Llama-3.2-1B].",
    )
