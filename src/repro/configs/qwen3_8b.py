"""qwen3-8b — dense GQA with qk_norm [hf:Qwen/Qwen3-8B]."""

from repro.config.base import ModelConfig, register_config


@register_config("qwen3-8b")
def qwen3_8b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        arch_type="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12288,
        vocab_size=151936,
        head_dim=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
        citation="Qwen3-8B model card [hf:Qwen/Qwen3-8B]: GQA 32/8, qk_norm.",
    )
