"""minicpm-2b — llama-like dense, trained with WSD schedule [arXiv:2404.06395]."""

from repro.config.base import ModelConfig, register_config


@register_config("minicpm-2b")
def minicpm_2b() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        arch_type="dense",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,          # MHA (kv=36)
        d_ff=5760,
        vocab_size=122753,
        head_dim=64,
        tie_embeddings=True,
        lr_schedule="wsd",      # Warmup-Stable-Decay (paper §4)
        citation="MiniCPM [arXiv:2404.06395]: WSD schedule; llama-like blocks.",
    )
