"""mamba2-1.3b — SSD state-space model, attention-free [arXiv:2405.21060]."""

from repro.config.base import ModelConfig, SSMConfig, register_config


@register_config("mamba2-1.3b")
def mamba2_1p3b() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        arch_type="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,  # no FFN: pure Mamba blocks
        vocab_size=50280,
        head_dim=64,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk_size=256),
        tie_embeddings=True,
        citation="SSD / Mamba2 [arXiv:2405.21060]; GPT-NeoX vocab 50280.",
    )
