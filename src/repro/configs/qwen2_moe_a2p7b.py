"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""

from repro.config.base import ModelConfig, MoEConfig, register_config


@register_config("qwen2-moe-a2.7b")
def qwen2_moe_a2p7b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        arch_type="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5632,              # shared-expert/dense hidden
        vocab_size=151936,
        head_dim=128,
        qkv_bias=True,
        moe=MoEConfig(
            num_experts=60,
            top_k=4,
            num_shared_experts=4,
            d_ff_expert=1408,
            d_ff_shared=5632,   # 4 shared experts x 1408
            normalize_top_k=False,
        ),
        citation="Qwen1.5-MoE-A2.7B model card [hf:Qwen/Qwen1.5-MoE-A2.7B]: 60 routed top-4 + 4 shared.",
    )
