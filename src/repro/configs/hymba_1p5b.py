"""hymba-1.5b — hybrid parallel attention+Mamba heads [arXiv:2411.13676]."""

from repro.config.base import ModelConfig, SSMConfig, register_config


@register_config("hymba-1.5b")
def hymba_1p5b() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        arch_type="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,           # GQA kv=5
        d_ff=5504,
        vocab_size=32001,
        head_dim=64,
        sliding_window=1024,    # SWA everywhere except 3 global layers
        global_attn_layers=(0, 15, 31),
        meta_tokens=128,        # learnable prefix (paper §2.2)
        # chunk 64: the SSD intra-chunk quadratic is O(L*chunk) bytes when
        # lowered to jnp (the dry-run path); 64 keeps it HBM-light while the
        # Pallas kernel holds the (Q,Q) tile in VMEM regardless (§Perf H1).
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk_size=64),
        tie_embeddings=True,
        citation="Hymba [arXiv:2411.13676]: parallel attn+SSM heads, meta tokens, SWA+3 global.",
    )
