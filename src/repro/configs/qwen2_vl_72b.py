"""qwen2-vl-72b — VLM backbone with M-RoPE; ViT frontend stubbed [arXiv:2409.12191]."""

from repro.config.base import ModelConfig, register_config


@register_config("qwen2-vl-72b")
def qwen2_vl_72b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        arch_type="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab_size=152064,
        head_dim=128,
        qkv_bias=True,
        mrope_sections=(16, 24, 24),  # (t, h, w) frequency bands
        rope_theta=1_000_000.0,
        frontend_stub=True,     # input_specs() provides patch embeddings
        citation="Qwen2-VL [arXiv:2409.12191]: M-RoPE, dynamic resolution (ViT stubbed).",
    )
