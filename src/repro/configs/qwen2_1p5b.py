"""qwen2-1.5b — dense GQA kv=2, QKV bias [arXiv:2407.10671]."""

from repro.config.base import ModelConfig, register_config


@register_config("qwen2-1.5b")
def qwen2_1p5b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        arch_type="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        citation="Qwen2 [arXiv:2407.10671]: GQA 12/2 with QKV bias.",
    )
