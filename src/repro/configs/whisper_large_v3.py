"""whisper-large-v3 — audio enc-dec; conv/mel frontend stubbed [arXiv:2212.04356]."""

from repro.config.base import ModelConfig, register_config


@register_config("whisper-large-v3")
def whisper_large_v3() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        arch_type="encdec",
        n_layers=32,            # decoder layers
        n_encoder_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        head_dim=64,
        qkv_bias=True,          # whisper: bias on q/v (k-bias dropped upstream; kept uniform here)
        learned_pos_emb=True,
        act="gelu",
        norm_eps=1e-5,
        tie_embeddings=True,
        encoder_seq=1500,       # 30 s audio @ 50 frames/s after conv stride 2
        frontend_stub=True,     # input_specs() provides conv-feature embeddings
        citation="Whisper [arXiv:2212.04356]; large-v3 model card (vocab 51866).",
    )
