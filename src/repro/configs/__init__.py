"""Assigned architecture configs (one module per arch, each citing its source).

Importing this package populates the registry used by
``repro.config.base.get_config`` / ``list_configs``.
"""

from . import (  # noqa: F401
    deepseek_v2_lite,
    hymba_1p5b,
    llama3p2_1b,
    mamba2_1p3b,
    minicpm_2b,
    qwen2_1p5b,
    qwen2_moe_a2p7b,
    qwen2_vl_72b,
    qwen3_8b,
    whisper_large_v3,
)

ASSIGNED_ARCHS = (
    "mamba2-1.3b",
    "whisper-large-v3",
    "hymba-1.5b",
    "qwen3-8b",
    "minicpm-2b",
    "deepseek-v2-lite-16b",
    "qwen2-1.5b",
    "llama3.2-1b",
    "qwen2-moe-a2.7b",
    "qwen2-vl-72b",
)
