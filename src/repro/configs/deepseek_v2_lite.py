"""deepseek-v2-lite-16b — MLA + MoE (2 shared + 64 routed top-6) [arXiv:2405.04434]."""

from repro.config.base import ModelConfig, MoEConfig, register_config


@register_config("deepseek-v2-lite-16b")
def deepseek_v2_lite() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        arch_type="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=10944,             # dense-FFN layers (layer 0)
        vocab_size=102400,
        kv_lora_rank=512,       # MLA latent cache
        qk_rope_head_dim=64,
        qk_nope_head_dim=128,
        v_head_dim=128,
        first_k_dense_layers=1,
        moe=MoEConfig(
            num_experts=64,
            top_k=6,
            num_shared_experts=2,
            d_ff_expert=1408,
            d_ff_shared=2816,   # 2 shared experts x 1408
            router_aux_coef=0.003,
        ),
        citation="DeepSeek-V2(-Lite) [arXiv:2405.04434]: MLA kv_lora=512, 2 shared + 64 routed top-6.",
    )
