"""Benchmark harness: one function per paper table/figure + kernel timings
+ the roofline table.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run                    # everything
    PYTHONPATH=src python -m benchmarks.run --only fig567
    PYTHONPATH=src python -m benchmarks.run --only pipeline --json BENCH_pipeline.json

``--json PATH`` writes the machine-readable records
``{bench, case, us_per_event, derived}`` accumulated by the selected
benchmarks, so future PRs can track the perf trajectory (the checked-in
``BENCH_pipeline.json`` is the output of the ``pipeline`` bench).
"""

from __future__ import annotations

import argparse
import glob
import json
import time

import numpy as np

from .scenarios import RECORDS, record, row, run_scenario

SEP = "-" * 78


# --------------------------------------------------------------------- #
# Pipeline hot-path benchmark (PERF.md): wall-clock per source event on   #
# the two reference scenarios, against the frozen seed-commit baseline.   #
# --------------------------------------------------------------------- #

# Measured at the seed commit (9931f3f, pure-Python per-event runtime)
# on the same container this harness runs in; see PERF.md for methodology.
SEED_US_PER_EVENT = {
    "Base_SB-20_200c": 107.5,
    "BFS_DB-25_1000c": 284.1,
}

PIPELINE_CASES = [
    ("Base_SB-20_200c", dict(tl="base", num_cameras=200, batching="static", static_batch=20)),
    ("BFS_DB-25_1000c", dict(tl="bfs", batching="dynamic", m_max=25)),
]


def bench_pipeline(reps: int = 3) -> None:
    print(f"{SEP}\n# Pipeline hot path — us per source event vs seed baseline (best of {reps})")
    for name, kw in PIPELINE_CASES:
        wall = float("inf")
        for _ in range(reps):
            t0 = time.time()
            res = run_scenario(tl_peak_speed=4.0, **kw)
            wall = min(wall, time.time() - t0)
        us = wall * 1e6 / max(res.source_events, 1)
        seed_us = SEED_US_PER_EVENT.get(name)
        speedup = f"{seed_us / us:.2f}" if seed_us else "n/a"
        s = res.summary()
        record(
            "pipeline",
            name,
            us,
            f"seed_us_per_event={seed_us};speedup_x={speedup};"
            f"events={s['source_events']};median_lat_s={s['median_latency_s']};"
            f"delayed={s['delayed']};dropped={s['dropped']};peak_active={s['peak_active']}",
        )
        print(f"pipeline_{name},{us:.1f},seed={seed_us};speedup={speedup}x")


# --------------------------------------------------------------------- #
# Fig. 13 (new): scale sweep — 1k/5k/10k cameras x 1/5 fps               #
# --------------------------------------------------------------------- #
def bench_scale_fig13() -> None:
    print(f"{SEP}\n# Fig 13 — scale sweep (spotlight TL, dynamic batching)")
    for num_cameras in (1000, 5000, 10000):
        for fps in (1.0, 5.0):
            name = f"scale_{num_cameras}c_{fps:g}fps"
            t0 = time.time()
            res = run_scenario(
                tl="bfs",
                tl_peak_speed=4.0,
                batching="dynamic",
                m_max=25,
                num_cameras=num_cameras,
                fps=fps,
                duration_s=60.0,
            )
            print(row(name, res, time.time() - t0, bench="fig13"))
    # Multi-entity probabilistic spotlight: batched CSR relaxation kernel
    # vs the incremental python path.
    from repro.core.roadnet import make_road_network
    from repro.core.tracking import TLProbabilistic

    net = make_road_network(seed=0)
    cams = {c: c for c in range(net.num_vertices)}
    tl = TLProbabilistic(net, cams, entity_speed=4.0, coverage=0.9)
    for i in range(8):
        tl.track(f"entity{i}", camera_id=(i * 97) % net.num_vertices, timestamp=float(i))
    for label, use_kernel in (("python", False), ("kernel", True)):
        tl._entity_searches.clear()
        t0 = time.perf_counter()
        active = tl.spotlight_multi(60.0, use_kernel=use_kernel)
        us = (time.perf_counter() - t0) * 1e6
        record("fig13", f"multi_entity_{label}", us / 8.0, f"entities=8;active={len(active)}")
        print(f"multi_entity_{label},{us/8.0:.1f},entities=8;active={len(active)}")


# --------------------------------------------------------------------- #
# Fig. 5/6/7: batching strategies (streaming / static / dynamic / NOB)   #
# --------------------------------------------------------------------- #
def bench_batching_fig567() -> None:
    print(f"{SEP}\n# Fig 5/6/7 — batching strategies, TL-BFS, 1000 cameras")
    cases = [
        ("SB-1_es4", dict(batching="static", static_batch=1, tl_peak_speed=4.0)),
        ("SB-20_es4", dict(batching="static", static_batch=20, tl_peak_speed=4.0)),
        ("DB-25_es4", dict(batching="dynamic", m_max=25, tl_peak_speed=4.0)),
        ("NOB-25_es4", dict(batching="nob", m_max=25, tl_peak_speed=4.0)),
        ("SB-1_es6", dict(batching="static", static_batch=1, tl_peak_speed=6.0)),
        ("SB-20_es6", dict(batching="static", static_batch=20, tl_peak_speed=6.0)),
        ("DB-25_es6", dict(batching="dynamic", m_max=25, tl_peak_speed=6.0)),
    ]
    for name, kw in cases:
        t0 = time.time()
        res = run_scenario(tl="bfs", **kw)
        print(row(name, res, time.time() - t0, bench="fig567"))


# --------------------------------------------------------------------- #
# Fig. 10: tracking-logic knob (Base / BFS / WBFS)                       #
# --------------------------------------------------------------------- #
def bench_tracking_fig10() -> None:
    print(f"{SEP}\n# Fig 10 — tracking logic: active-set scalability")
    cases = [
        ("Base_SB-20_100c", dict(tl="base", num_cameras=100, batching="static", static_batch=20)),
        ("Base_SB-20_200c", dict(tl="base", num_cameras=200, batching="static", static_batch=20)),
        ("BFS_SB-1_1000c", dict(tl="bfs", batching="static", static_batch=1)),
        ("WBFS_SB-1_1000c", dict(tl="wbfs", batching="static", static_batch=1)),
        ("BFS_DB-25_1000c", dict(tl="bfs", batching="dynamic", m_max=25)),
        ("WBFS_DB-25_1000c", dict(tl="wbfs", batching="dynamic", m_max=25)),
        ("Prob_DB-25_1000c", dict(tl="prob", batching="dynamic", m_max=25)),
    ]
    for name, kw in cases:
        t0 = time.time()
        res = run_scenario(tl_peak_speed=4.0, **kw)
        print(row(name, res, time.time() - t0, bench="fig10"))


# --------------------------------------------------------------------- #
# Fig. 11: dropping under overload (es = 7 m/s)                          #
# --------------------------------------------------------------------- #
def bench_dropping_fig11() -> None:
    print(f"{SEP}\n# Fig 11 — drops under overload (es=7, constrained 5 VA + 5 CR)")
    overload = dict(
        tl="bfs", tl_peak_speed=7.0, batching="dynamic", m_max=25, num_va=5, num_cr=5
    )
    for name, kw in [
        ("es7_nodrop", dict(drops_enabled=False)),
        ("es7_drops", dict(drops_enabled=True, avoid_drop_positives=True)),
    ]:
        t0 = time.time()
        res = run_scenario(**overload, **kw)
        print(row(name, res, time.time() - t0, bench="fig11"))


# --------------------------------------------------------------------- #
# Fig. 9: bandwidth drop 1 Gbps -> 30 Mbps at t = 300 s                  #
# --------------------------------------------------------------------- #
def bench_network_fig9() -> None:
    print(f"{SEP}\n# Fig 9 — adapting to a 1Gbps->30Mbps bandwidth drop at t=300s")
    schedule = lambda t: 1.0 if t < 300.0 else 0.03
    for name, kw in [
        ("DB-25_bwdrop", dict(batching="dynamic", m_max=25)),
        ("NOB-25_bwdrop", dict(batching="nob", m_max=25)),
    ]:
        t0 = time.time()
        res = run_scenario(tl="bfs", tl_peak_speed=4.0, bandwidth_schedule=schedule, **kw)
        print(row(name, res, time.time() - t0, bench="fig9"))


# --------------------------------------------------------------------- #
# Fig. 12: App 2 (63% costlier CR DNN)                                   #
# --------------------------------------------------------------------- #
def bench_app2_fig12() -> None:
    print(f"{SEP}\n# Fig 12 — App 2 (CR ~63% slower per frame)")
    cr2 = (0.067 * 1.63, 0.053 * 1.63)
    cases = [
        ("app2_SB-20_es4", dict(batching="static", static_batch=20, tl_peak_speed=4.0)),
        ("app2_DB-25_es4", dict(batching="dynamic", m_max=25, tl_peak_speed=4.0)),
        ("app2_DB-25_es6", dict(batching="dynamic", m_max=25, tl_peak_speed=6.0)),
        (
            "app2_DB-25_es6_drops",
            dict(batching="dynamic", m_max=25, tl_peak_speed=6.0,
                 drops_enabled=True, avoid_drop_positives=True),
        ),
        ("app2_WBFS_SB-20_es4", dict(tl="wbfs", batching="static", static_batch=20,
                                     tl_peak_speed=4.0)),
    ]
    for name, kw in cases:
        t0 = time.time()
        res = run_scenario(tl=kw.pop("tl", "bfs"), cr_cost=cr2, **kw)
        print(row(name, res, time.time() - t0, bench="fig12"))


# --------------------------------------------------------------------- #
# Kernel micro-benchmarks (CPU: oracle path; TPU would hit Pallas)       #
# --------------------------------------------------------------------- #
def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.reid_match.ops import reid_match
    from repro.kernels.spotlight_ball.ops import spotlight_ball
    from repro.kernels.ssd_scan.ops import ssd_scan

    print(f"{SEP}\n# Kernel micro-benchmarks (CPU reference path)")
    key = jax.random.PRNGKey(0)

    def timeit(name, fn, *args, reps=5, derived=""):
        fn(*args)  # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        us = (time.perf_counter() - t0) / reps * 1e6
        record("kernels", name, us, derived)
        print(f"{name},{us:.1f},{derived}")

    B, S, H, Hkv, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    timeit("flash_attention_1k", flash_attention, q, k, v,
           derived=f"flops={2*2*B*S*S*H*D:.2e}")

    qd = jax.random.normal(key, (8, H, D))
    # head-major cache layout (B, Hkv, T, D)
    kc = jax.random.normal(key, (8, Hkv, 4096, D))
    vc = jax.random.normal(key, (8, Hkv, 4096, D))
    ln = jnp.full((8,), 4096, jnp.int32)
    timeit("decode_attention_4k", decode_attention, qd, kc, vc, ln,
           derived=f"kv_bytes={8*4096*Hkv*D*2*4:.2e}")

    x = jax.random.normal(key, (1, 1024, 8, 64)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(key, (1, 1024, 8)))
    A = -jnp.exp(jax.random.normal(key, (8,)) * 0.3)
    Bm = jax.random.normal(key, (1, 1024, 1, 64)) * 0.3
    Cm = jax.random.normal(key, (1, 1024, 1, 64)) * 0.3
    timeit("ssd_scan_1k", lambda *a: ssd_scan(*a)[0], x, dt, A, Bm, Cm,
           derived="chunked state-space scan")

    g = jax.random.normal(key, (4096, 128))
    qq = jax.random.normal(key, (4, 128))
    timeit("reid_match_4k", lambda *a: reid_match(*a)[0], g, qq,
           derived="gallery=4096x128")

    from repro.core.roadnet import make_road_network

    net = make_road_network(num_vertices=512, target_edges=1442, seed=0)
    indptr, indices, weights = net.csr()
    rng = np.random.default_rng(0)
    sources = rng.integers(0, 512, size=16).astype(np.int32)
    radii = rng.uniform(100, 1500, size=16).astype(np.float32)
    timeit(
        "spotlight_ball_512v_16q",
        lambda: spotlight_ball(indptr, indices, weights.astype(np.float32), sources, radii),
        derived="V=512;Q=16;dense min-plus relaxation",
    )


# --------------------------------------------------------------------- #
# Roofline table from the dry-run records (§Roofline source of truth)    #
# --------------------------------------------------------------------- #
def bench_roofline(out_dir: str = "experiments/dryrun") -> None:
    print(f"{SEP}\n# Roofline table (from {out_dir}/*.json; see EXPERIMENTS.md)")
    recs = []
    for path in sorted(glob.glob(f"{out_dir}/*.json")):
        with open(path) as f:
            recs.append(json.load(f))
    if not recs:
        print("roofline,0,missing (run: python -m repro.launch.dryrun --mesh both)")
        return
    print(
        "arch,shape,mesh,compute_ms,memory_ms,collective_ms,dominant,"
        "useful_ratio,peak_dev_GiB,compile_s"
    )
    for r in recs:
        t = r["roofline"]
        print(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{t['compute_s']*1e3:.3f},{t['memory_s']*1e3:.3f},"
            f"{t['collective_s']*1e3:.3f},{t['dominant']},"
            f"{t['useful_ratio']:.3f},{r['peak_device_bytes']/2**30:.2f},"
            f"{r['compile_s']}"
        )


# --------------------------------------------------------------------- #
# Anveshak-scheduled LM serving stage                                    #
# --------------------------------------------------------------------- #
def bench_serving() -> None:
    import jax
    import jax.numpy as jnp

    from repro.serving import ServedStage, StageRequest, calibrate_xi, embed_frames, init_reid_tower

    print(f"{SEP}\n# Anveshak-scheduled serving stage (budgeted dynamic batching)")
    tower = init_reid_tower(jax.random.PRNGKey(0), d_in=128, d_embed=64)
    step = lambda x: embed_frames(tower, jnp.asarray(x))
    xi = calibrate_xi(step, (128,), buckets=(1, 4, 16, 64))
    for rate_hz in (50, 200, 1000):
        stage = ServedStage("CR", step, xi, gamma=0.5, m_max=64, buckets=(1, 4, 16, 64))
        n, done, dropped = 200, 0, 0
        t0 = time.perf_counter()
        for i in range(n):
            target = t0 + i / rate_hz
            while time.perf_counter() < target:
                pass
            res = stage.submit(StageRequest(np.zeros(128, np.float32), source_time=target))
            for r in res or []:
                done += 0 if r.dropped else 1
                dropped += 1 if r.dropped else 0
        for r in stage.flush() or []:
            done += 0 if r.dropped else 1
            dropped += 1 if r.dropped else 0
        wall = time.perf_counter() - t0
        sizes = stage.stats["executed"] / max(stage.stats["batches"], 1)
        record("serving", f"serving_rate{rate_hz}", wall / n * 1e6,
               f"done={done};dropped={dropped};mean_batch={sizes:.1f}")
        print(
            f"serving_rate{rate_hz},{wall/n*1e6:.1f},"
            f"done={done};dropped={dropped};mean_batch={sizes:.1f};"
            f"throughput_hz={done/wall:.0f}"
        )


BENCHES = {
    "pipeline": bench_pipeline,
    "fig567": bench_batching_fig567,
    "fig10": bench_tracking_fig10,
    "fig11": bench_dropping_fig11,
    "fig9": bench_network_fig9,
    "fig12": bench_app2_fig12,
    "fig13": bench_scale_fig13,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
    "serving": bench_serving,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="write machine-readable {bench, case, us_per_event, derived} records",
    )
    args = ap.parse_args()
    t0 = time.time()
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        fn()
    print(f"{SEP}\nTotal benchmark wall time: {time.time()-t0:.1f}s")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"harness": "benchmarks.run", "records": RECORDS}, f, indent=2)
            f.write("\n")
        print(f"wrote {len(RECORDS)} records to {args.json}")


if __name__ == "__main__":
    main()
