"""Benchmark harness: every paper figure grid runs as ONE sweep through the
shared-world :class:`repro.sim.SweepRunner` (worlds built once per key,
configs executed concurrently via a fork pool where available), plus kernel
timings and the roofline table.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run                    # everything
    PYTHONPATH=src python -m benchmarks.run --only fig567
    PYTHONPATH=src python -m benchmarks.run --only fig567 --mode serial
    PYTHONPATH=src python -m benchmarks.run --only pipeline --json BENCH_pipeline.json
    PYTHONPATH=src python -m benchmarks.run --only pipeline --smoke          # CI-fast
    PYTHONPATH=src python -m benchmarks.run --only pipeline --smoke \\
        --compare BENCH_pipeline.json                          # regression gate

``--json PATH`` writes the machine-readable records ``{bench, case,
us_per_event, derived, run_s, build_s, xfer_s, mode}`` accumulated by the
selected benchmarks (the checked-in ``BENCH_pipeline.json`` holds the
``pipeline`` records in both full and smoke modes).  ``us_per_event`` is
computed from ``run()`` wall-time only; construction is reported separately
as ``build_s``, and device engines split the host<->device transfer wall
out of ``run_s`` into ``xfer_s`` (``null`` for families that do no device
transfer, and backfilled as ``null`` when comparing against baselines
recorded before the column existed).

``--compare PATH`` re-times the comparable benchmark families recorded in
PATH (pipeline, the fused multi-query cases, the mega-step engine runs,
and the journaled fault-crash runs, matching the current ``--smoke`` mode)
and exits non-zero when any ``us_per_event`` regressed by more than
``--compare-tolerance`` (default 35%).  Families absent from a
frozen baseline are tolerated, so old baselines keep gating after new
benchmark families land.

``--mode`` selects the sweep execution: ``auto`` (fork pool when available),
``fork``, ``serial`` (shared worlds, one case at a time), or ``cold``
(serial AND world/road caches cleared before every case — the faithful
"rebuild everything per config" sequential baseline).
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.clock import monotonic
from repro.sim import (
    BandwidthCollapse,
    ComputeSlowdown,
    DynamismSpec,
    ScenarioConfig,
    SweepResult,
    SweepRunner,
)

from .scenarios import RECORDS, record, record_case

SEP = "-" * 78

# --------------------------------------------------------------------- #
# Frozen baselines                                                       #
# --------------------------------------------------------------------- #

# Per-event cost measured at the seed commit (9931f3f, pure-Python per-event
# runtime) on the same container; see PERF.md for methodology.
SEED_US_PER_EVENT = {
    "Base_SB-20_200c": 107.5,
    "BFS_DB-25_1000c": 284.1,
}

# Whole-grid sequential wall-clock measured at commit 26d2c35 (the PR-1
# harness: one scenario at a time, construction+run timed together) on the
# same container.  The sweep records report their speedup against these.
SEED_SEQ_WALL_S = {
    "fig567": 2.6,
    "fig9": 1.1,
    "fig10": 3.1,
    "fig11": 4.2,
    "fig12": 2.5,
    "fig13": 11.3,
}

# --------------------------------------------------------------------- #
# Paper-figure grids (each runs as one sweep)                            #
# --------------------------------------------------------------------- #

_CR2 = (0.067 * 1.63, 0.053 * 1.63)  # App 2: CR ~63% slower per frame


def _fig9_bandwidth(t: float) -> float:
    """Fig. 9: 1 Gbps -> 30 Mbps at t = 300 s."""
    return 1.0 if t < 300.0 else 0.03


GRIDS: Dict[str, Dict] = {
    "pipeline": dict(
        title="Pipeline hot path — reference scenarios",
        base=dict(tl_peak_speed=4.0),
        cases=[
            ("Base_SB-20_200c", dict(tl="base", num_cameras=200, batching="static", static_batch=20)),
            ("BFS_DB-25_1000c", dict(tl="bfs", batching="dynamic", m_max=25)),
        ],
    ),
    "fig567": dict(
        title="Fig 5/6/7 — batching strategies, TL-BFS, 1000 cameras",
        base=dict(tl="bfs"),
        cases=[
            ("SB-1_es4", dict(batching="static", static_batch=1, tl_peak_speed=4.0)),
            ("SB-20_es4", dict(batching="static", static_batch=20, tl_peak_speed=4.0)),
            ("DB-25_es4", dict(batching="dynamic", m_max=25, tl_peak_speed=4.0)),
            ("NOB-25_es4", dict(batching="nob", m_max=25, tl_peak_speed=4.0)),
            ("SB-1_es6", dict(batching="static", static_batch=1, tl_peak_speed=6.0)),
            ("SB-20_es6", dict(batching="static", static_batch=20, tl_peak_speed=6.0)),
            ("DB-25_es6", dict(batching="dynamic", m_max=25, tl_peak_speed=6.0)),
        ],
    ),
    "fig10": dict(
        title="Fig 10 — tracking logic: active-set scalability",
        base=dict(tl_peak_speed=4.0),
        cases=[
            ("Base_SB-20_100c", dict(tl="base", num_cameras=100, batching="static", static_batch=20)),
            ("Base_SB-20_200c", dict(tl="base", num_cameras=200, batching="static", static_batch=20)),
            ("BFS_SB-1_1000c", dict(tl="bfs", batching="static", static_batch=1)),
            ("WBFS_SB-1_1000c", dict(tl="wbfs", batching="static", static_batch=1)),
            ("BFS_DB-25_1000c", dict(tl="bfs", batching="dynamic", m_max=25)),
            ("WBFS_DB-25_1000c", dict(tl="wbfs", batching="dynamic", m_max=25)),
            ("Prob_DB-25_1000c", dict(tl="prob", batching="dynamic", m_max=25)),
        ],
    ),
    "fig11": dict(
        title="Fig 11 — drops under overload (es=7, constrained 5 VA + 5 CR)",
        base=dict(tl="bfs", tl_peak_speed=7.0, batching="dynamic", m_max=25, num_va=5, num_cr=5),
        cases=[
            ("es7_nodrop", dict(drops_enabled=False)),
            ("es7_drops", dict(drops_enabled=True, avoid_drop_positives=True)),
        ],
    ),
    "fig9": dict(
        title="Fig 9 — adapting to a 1Gbps->30Mbps bandwidth drop at t=300s",
        base=dict(tl="bfs", tl_peak_speed=4.0, bandwidth_schedule=_fig9_bandwidth),
        cases=[
            ("DB-25_bwdrop", dict(batching="dynamic", m_max=25)),
            ("NOB-25_bwdrop", dict(batching="nob", m_max=25)),
        ],
    ),
    "fig12": dict(
        title="Fig 12 — App 2 (CR ~63% slower per frame)",
        base=dict(tl="bfs", cr_cost=_CR2),
        cases=[
            ("app2_SB-20_es4", dict(batching="static", static_batch=20, tl_peak_speed=4.0)),
            ("app2_DB-25_es4", dict(batching="dynamic", m_max=25, tl_peak_speed=4.0)),
            ("app2_DB-25_es6", dict(batching="dynamic", m_max=25, tl_peak_speed=6.0)),
            (
                "app2_DB-25_es6_drops",
                dict(batching="dynamic", m_max=25, tl_peak_speed=6.0,
                     drops_enabled=True, avoid_drop_positives=True),
            ),
            ("app2_WBFS_SB-20_es4", dict(tl="wbfs", batching="static", static_batch=20,
                                         tl_peak_speed=4.0)),
        ],
    ),
    "fig13": dict(
        title="Fig 13 — scale sweep (spotlight TL, dynamic batching)",
        base=dict(tl="bfs", tl_peak_speed=4.0, batching="dynamic", m_max=25, duration_s=60.0),
        cases=[
            (f"scale_{n}c_{fps:g}fps", dict(num_cameras=n, fps=fps))
            for n in (1000, 5000, 10000)
            for fps in (1.0, 5.0)
        ],
    ),
}


# The pipeline cases double as the --compare gate's case universe.
PIPELINE_CASES = GRIDS["pipeline"]["cases"]


def _make_grid(bench: str, smoke: bool) -> List[Tuple[str, ScenarioConfig]]:
    info = GRIDS[bench]
    grid = []
    for name, kw in info["cases"]:
        cfg = dict(num_cameras=1000, duration_s=600.0, seed=0)
        cfg.update(info.get("base", {}))
        cfg.update(kw)
        if smoke:
            cfg["duration_s"] = min(cfg["duration_s"], 60.0)
        grid.append((name, ScenarioConfig(**cfg)))
    return grid


def _runner(ctx) -> SweepRunner:
    if ctx.mode == "cold":
        return SweepRunner(mode="serial", share_worlds=False)
    return SweepRunner(mode=ctx.mode, max_workers=ctx.workers)


def _mode_label(ctx) -> str:
    return "smoke" if ctx.smoke else "full"


def _sweep_record(bench: str, res: SweepResult, ctx) -> None:
    total_events = sum(r.summary["source_events"] for r in res.records)
    seed_wall = SEED_SEQ_WALL_S.get(bench)
    speedup = f"{seed_wall / res.wall_s:.2f}" if (seed_wall and not ctx.smoke) else "n/a"
    derived = (
        f"wall_s={res.wall_s:.3f};mode={res.mode};workers={res.workers};"
        f"configs={len(res.records)};worlds_built={res.worlds_built};"
        f"world_build_s={res.world_build_s:.3f};"
        f"seed_seq_wall_s={seed_wall};speedup_vs_seed_seq={speedup}"
    )
    record(
        bench, "sweep", res.wall_s * 1e6 / max(total_events, 1), derived,
        run_s=round(res.wall_s, 4), build_s=round(res.world_build_s, 4),
        mode=_mode_label(ctx),
    )
    print(f"{bench}_sweep,{res.wall_s * 1e6 / max(total_events, 1):.1f},{derived}")


def _run_grid(bench: str, ctx) -> SweepResult:
    print(f"{SEP}\n# {GRIDS[bench]['title']}")
    res = _runner(ctx).run(_make_grid(bench, ctx.smoke))
    for rec in res.records:
        print(record_case(bench, rec, mode=_mode_label(ctx)))
    _sweep_record(bench, res, ctx)
    return res


# --------------------------------------------------------------------- #
# Pipeline hot-path benchmark (PERF.md): per-event wall-clock on the two  #
# reference scenarios vs the frozen seed-commit baseline (best of reps).  #
# --------------------------------------------------------------------- #
def _time_pipeline_cases(ctx, reps: int) -> Dict[str, "object"]:
    # Per-event timing is always taken serially (worlds still shared):
    # concurrent execution would measure CPU contention, and the --compare
    # gate must see numbers produced the same way as the recorded baseline
    # regardless of the --mode used for the throughput sweeps.
    grid = _make_grid("pipeline", ctx.smoke)
    runner = SweepRunner(mode="serial")
    best: Dict[str, object] = {}
    for _ in range(reps):
        res = runner.run(grid)
        for rec in res.records:
            prev = best.get(rec.name)
            if prev is None or rec.run_s < prev.run_s:
                best[rec.name] = rec
    return best


def bench_pipeline(ctx) -> None:
    reps = 2 if ctx.smoke else 3
    print(f"{SEP}\n# Pipeline hot path — us per source event vs seed baseline (best of {reps})")
    best = _time_pipeline_cases(ctx, reps)
    for name, _ in PIPELINE_CASES:
        rec = best[name]
        us = rec.us_per_event
        seed_us = SEED_US_PER_EVENT.get(name)
        speedup = f"{seed_us / us:.2f}" if (seed_us and not ctx.smoke) else "n/a"
        s = rec.summary
        record(
            "pipeline",
            name,
            us,
            f"seed_us_per_event={seed_us};speedup_x={speedup};"
            f"events={s['source_events']};median_lat_s={s['median_latency_s']};"
            f"delayed={s['delayed']};dropped={s['dropped']};peak_active={s['peak_active']};"
            f"build_s={rec.build_s:.3f}",
            run_s=round(rec.run_s, 4),
            build_s=round(rec.build_s, 4),
            mode=_mode_label(ctx),
        )
        print(f"pipeline_{name},{us:.1f},seed={seed_us};speedup={speedup}x")


# --------------------------------------------------------------------- #
# Regression gate: --compare BENCH_pipeline.json                          #
# --------------------------------------------------------------------- #
def _retime_pipeline(ctx, cases) -> Dict[str, Tuple[float, float, float]]:
    """case -> (us_per_event, run_s, build_s) for the pipeline family."""
    reps = 2 if ctx.smoke else 3
    best = _time_pipeline_cases(ctx, reps)
    return {
        name: (rec.us_per_event, rec.run_s, rec.build_s)
        for name, rec in best.items()
        if name in cases
    }


def _retime_queries(ctx, cases) -> Dict[str, Tuple[float, float, float]]:
    """Re-time the fused multi-query cases present in the baseline.

    Same timing discipline as the recording side (bench_queries): the world
    cache is warmed before the timed window — the baselines were recorded
    warm, so a cold first build would read as a spurious regression — and
    each case takes the best of two runs (the walls are small enough for
    container noise to matter)."""
    from repro.query import MultiQueryScenario
    from repro.sim import WorldKey, get_world

    cams, dur, ns = _queries_shape(ctx.smoke)
    cfg = _queries_cfg(cams, dur)
    get_world(WorldKey.from_config(cfg))
    out: Dict[str, Tuple[float, float, float]] = {}
    for n in ns:
        name = f"fused_N{n}"
        if name not in cases:
            continue
        for _ in range(2):
            t0 = monotonic()
            scenario = MultiQueryScenario(cfg, n)
            res = scenario.run()
            wall = monotonic() - t0
            events = max(res.result.source_events, 1)
            prev = out.get(name)
            if prev is None or wall < prev[1]:
                out[name] = (wall * 1e6 / events, wall, scenario.build_seconds)
    return out


def _faults_shape(smoke: bool) -> Tuple[int, float, float, float, float, float]:
    """(cams, duration_s, crash_t0, outage_s, t_kill, snapshot_period_s).

    The crash window closes well before the horizon so post-heal budget
    recovery is measurable, and the driver is killed after at least one
    snapshot past the heal so the replay covers the whole fault."""
    if smoke:
        return 300, 150.0, 50.0, 40.0, 120.0, 30.0
    return 1000, 600.0, 300.0, 120.0, 500.0, 60.0


def _faults_cfg(cams: int, dur: float, crash_t0: float, outage_s: float,
                batcher_kw: Dict) -> ScenarioConfig:
    from repro.sim import HostCrash

    return ScenarioConfig(
        num_cameras=cams, duration_s=dur, seed=0, tl="bfs",
        drops_enabled=True, avoid_drop_positives=True,
        dynamism=DynamismSpec((HostCrash(("node0",), t_start=crash_t0,
                                         outage_s=outage_s),)),
        **batcher_kw,
    )


def _retime_faults(ctx, cases) -> Dict[str, Tuple[float, float, float]]:
    """Re-time the uninterrupted journaled crash runs (the recorded
    ``us_per_event`` basis); the kill/restore cycle is derived-only."""
    from repro.query import MultiQueryScenario
    from repro.serving.journal import Journal
    from repro.sim import WorldKey, get_world

    cams, dur, crash_t0, outage_s, _t_kill, period = _faults_shape(ctx.smoke)
    out: Dict[str, Tuple[float, float, float]] = {}
    for bname, bkw in DYNAMISM_BATCHERS[:2]:
        name = f"crash_{bname}"
        if name not in cases:
            continue
        cfg = _faults_cfg(cams, dur, crash_t0, outage_s, bkw)
        get_world(WorldKey.from_config(cfg))
        for _ in range(2 if ctx.smoke else 1):
            t0 = monotonic()
            scenario = MultiQueryScenario(cfg, 2, journal=Journal(period))
            res = scenario.run()
            wall = monotonic() - t0
            events = max(res.result.source_events, 1)
            prev = out.get(name)
            if prev is None or wall < prev[1]:
                out[name] = (wall * 1e6 / events, wall, scenario.build_seconds)
    return out


#: Benchmark families the --compare gate knows how to re-time.  Families
#: present in the baseline but unknown here — or known here but absent from
#: a frozen baseline recorded before the family existed — are skipped with
#: a notice instead of failing the gate.
COMPARABLE_FAMILIES = {
    "pipeline": _retime_pipeline,
    "queries": _retime_queries,
    "faults": _retime_faults,
}


def compare_against(path: str, ctx) -> int:
    """Re-time the comparable benchmark families recorded in ``path`` (same
    mode) and return non-zero when any us_per_event regressed past the
    tolerance.  Families absent from the baseline are tolerated (a frozen
    baseline recorded before a benchmark family existed must not fail the
    gate); the gate only errors (status 2) when *nothing* was comparable."""
    with open(path) as f:
        data = json.load(f)
    mode = _mode_label(ctx)
    records = data.get("records", [])
    for r in records:
        # Baselines recorded before the run_s/xfer_s split (and before the
        # observability columns): backfill as null (unknown) rather than
        # zero (measured).
        r.setdefault("xfer_s", None)
        r.setdefault("jit_compiles", None)
        r.setdefault("metrics_overhead_s", None)
    failed = False
    compared_any = False
    print(f"{SEP}\n# Regression gate vs {path} (mode={mode}, tol={ctx.compare_tolerance:.0%})")
    for bench, retimer in COMPARABLE_FAMILIES.items():
        baselines = {
            r["case"]: float(r["us_per_event"])
            for r in records
            if r.get("bench") == bench and r.get("mode", "full") == mode
        }
        if not baselines:
            print(f"compare: no {bench!r} records for mode={mode!r} in {path} "
                  "(family absent from baseline - tolerated)")
            continue
        current = retimer(ctx, set(baselines))
        for name, base_us in sorted(baselines.items()):
            cur = current.get(name)
            if cur is None:
                # Baseline case this harness does not re-time (renamed, or a
                # derived-only record like the admission demos): skip.
                print(f"compare_{name},n/a,not retimed by this harness - skipped")
                continue
            us, run_s, build_s = cur
            ratio = us / base_us
            verdict = "OK" if ratio <= 1.0 + ctx.compare_tolerance else "REGRESSED"
            failed |= verdict != "OK"
            compared_any = True
            derived = f"baseline={base_us:.1f};ratio={ratio:.2f};{verdict}"
            record(f"{bench}_compare", name, us, derived,
                   run_s=round(run_s, 4), build_s=round(build_s, 4), mode=mode)
            print(f"compare_{name},{us:.1f},{derived}")
    if not compared_any:
        print(f"compare: nothing comparable for mode={mode!r} in {path}")
        return 2
    return 1 if failed else 0


# --------------------------------------------------------------------- #
# Figure sweeps                                                          #
# --------------------------------------------------------------------- #
def bench_batching_fig567(ctx) -> None:
    _run_grid("fig567", ctx)


def bench_tracking_fig10(ctx) -> None:
    _run_grid("fig10", ctx)


def bench_dropping_fig11(ctx) -> None:
    _run_grid("fig11", ctx)


def bench_network_fig9(ctx) -> None:
    _run_grid("fig9", ctx)


def bench_app2_fig12(ctx) -> None:
    _run_grid("fig12", ctx)


def bench_apps(ctx) -> None:
    """Table-1 apps through the app compiler: all four apps x {dynamic, nob}
    batching as one (app, deployment) sweep.  Smoke-sized by construction
    (the examples' 300-camera / 60 s workload) so app-level perf is tracked
    on every run; App 4 keeps the grid off auto-fork (JAX in workers)."""
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from examples.apps import table1_grid

    print(f"{SEP}\n# Table-1 apps via compile_app — four apps x dynamic/nob batching")
    grid = []
    for batching in ("dynamic", "nob"):
        grid.extend(
            (f"{name}_{batching}", case) for name, case in table1_grid(batching)
        )
    res = _runner(ctx).run(grid)
    for rec in res.records:
        print(record_case("apps", rec, mode=_mode_label(ctx)))
    _sweep_record("apps", res, ctx)


# --------------------------------------------------------------------- #
# Dynamism grid (§4.3-§4.5, Figs. 7/9): DB vs SB vs NOB under transient   #
# perturbations, with per-task telemetry + budget-recovery analysis.      #
# --------------------------------------------------------------------- #

#: Batcher knobs compared under every perturbation (the paper's §5.1 set).
DYNAMISM_BATCHERS = (
    ("DB-25", dict(batching="dynamic", m_max=25)),
    ("SB-20", dict(batching="static", static_batch=20)),
    ("NOB-25", dict(batching="nob", m_max=25)),
)


def dynamism_grid(smoke: bool) -> List[Tuple[str, ScenarioConfig]]:
    """DB/SB/NOB under a transient bandwidth collapse and a transient
    compute slowdown, drops enabled, telemetry + ground-truth quality on.

    The collapse factor is far below Fig. 9's 0.03 because the network
    model charges transits independently (no shared-link queueing): the
    per-event serialization delay must itself become comparable to the
    budgets for the perturbation to bite.  Windows close before the run
    ends so budget *recovery* (§4.5.2 probes + accepts) is measurable.
    """
    if smoke:
        cams, dur, w0, w1 = 300, 150.0, 50.0, 90.0
    else:
        cams, dur, w0, w1 = 1000, 600.0, 300.0, 420.0
    perturbs = [
        ("bwcollapse", DynamismSpec((BandwidthCollapse(w0, w1, 2e-5),))),
        ("cpuslow", DynamismSpec((ComputeSlowdown(w0, w1, 6.0, hosts=("node",)),))),
    ]
    grid = []
    for pname, spec in perturbs:
        for bname, bkw in DYNAMISM_BATCHERS:
            cfg = ScenarioConfig(
                num_cameras=cams, duration_s=dur, seed=0, tl="bfs",
                drops_enabled=True, avoid_drop_positives=True,
                dynamism=spec, **bkw,
            )
            grid.append((f"{pname}_{bname}", cfg))
    return grid


def bench_dynamism(ctx) -> None:
    print(f"{SEP}\n# Dynamism grid — DB vs SB vs NOB under transient perturbations")
    res = _runner(ctx).run(dynamism_grid(ctx.smoke))
    nan = float("nan")
    for rec in res.records:
        s = rec.summary
        # Absent budget fields (a case whose budgets never initialized)
        # print as nan — float()-parsable by the smoke gate, which then
        # fails its recovery assertion with a readable value.
        derived = (
            f"beta_pre={s.get('beta_pre', nan)};beta_post={s.get('beta_post', nan)};"
            f"beta_recovery={s.get('beta_recovery', nan)};recall={s.get('track_recall')};"
            f"precision={s.get('track_precision')};dropped_frac={s['dropped_frac']};"
            f"median_lat_s={s['median_latency_s']};p99_s={s['p99_latency_s']};"
            f"probes={s.get('probes')};events={s['source_events']}"
        )
        record(
            "dynamism", rec.name, rec.us_per_event, derived,
            run_s=round(rec.run_s, 4), build_s=round(rec.build_s, 4),
            mode=_mode_label(ctx),
        )
        print(f"{rec.name},{rec.us_per_event:.1f},{derived}")
    _sweep_record("dynamism", res, ctx)


# --------------------------------------------------------------------- #
# Multi-query tenancy grid: N concurrent queries fused over ONE pipeline  #
# vs the per-query-serial baseline, plus the admission-control demo.      #
# --------------------------------------------------------------------- #
def _queries_shape(smoke: bool) -> Tuple[int, float, Tuple[int, ...]]:
    """(num_cameras, duration_s, N sweep) for the scaling part."""
    if smoke:
        return 300, 60.0, (1, 4, 16)
    return 1000, 600.0, (1, 4, 16, 64)


def _queries_cfg(cams: int, dur: float) -> ScenarioConfig:
    return ScenarioConfig(
        num_cameras=cams, duration_s=dur, seed=0, tl="bfs",
        batching="dynamic", m_max=25,
    )


def _admission_queries(cams: int, w0: float):
    """64 submitted queries: 2 well-behaved baselines at t=0 plus a
    62-query storm starting 10 s before the perturbation window, seeded at
    scattered last-seen hints (growing spotlights = genuine load)."""
    from repro.query import QuerySpec

    specs = [QuerySpec(submit_at=0.0), QuerySpec(submit_at=0.0, tl_peak_speed=5.0)]
    specs += [
        QuerySpec(
            submit_at=w0 - 10.0 + 1.0 * i,
            last_seen_camera=(i * 37) % cams,
            tl_peak_speed=4.0 + (i % 3),
        )
        for i in range(62)
    ]
    return specs


def bench_queries(ctx) -> None:
    from repro.query import AdmissionPolicy, MultiQueryScenario, run_queries_serial
    from repro.sim import ComputeSlowdown, DynamismSpec, WorldKey, get_world

    print(f"{SEP}\n# Multi-query tenancy — fused N-query runs vs per-query serial")
    cams, dur, ns = _queries_shape(ctx.smoke)
    cfg = _queries_cfg(cams, dur)
    get_world(WorldKey.from_config(cfg))  # warm the world cache for both sides
    # Best-of-2 on both sides: the smoke-scale walls are tens of ms, where
    # a single scheduler hiccup on a shared CI container flips the ratio.
    reps = 2 if ctx.smoke else 1
    for n in ns:
        fused_wall = math.inf
        for _ in range(reps):
            t0 = monotonic()
            res = MultiQueryScenario(cfg, n).run()
            fused_wall = min(fused_wall, monotonic() - t0)
        serial_wall = math.inf
        for _ in range(reps):
            serial_results, wall = run_queries_serial(cfg, n)
            serial_wall = min(serial_wall, wall)
        bit_identical = all(
            res.per_query_summary(qid) == serial_results[i].summary()
            for i, qid in enumerate(sorted(res.per_query))
        )
        s = res.summary()
        events = max(s["source_events"], 1)
        derived = (
            f"n_queries={n};wall_s={fused_wall:.3f};serial_wall_s={serial_wall:.3f};"
            f"speedup_x={serial_wall / fused_wall:.2f};bit_identical={bit_identical};"
            f"union_peak={s['union_peak_active']};union_mean={s['union_mean_active']};"
            f"events={s['source_events']};per_query_sourced={s['per_query_sourced_sum']}"
        )
        record("queries", f"fused_N{n}", fused_wall * 1e6 / events, derived,
               run_s=round(fused_wall, 4), mode=_mode_label(ctx))
        print(f"fused_N{n},{fused_wall * 1e6 / events:.1f},{derived}")

    # Admission-control demo: a 64-query storm under a ComputeSlowdown
    # window; with admission ON the CR-tier budget (held at VA, one per CR
    # downstream - paper §4.3.4) recovers while serving, with it OFF it
    # does not.  `until=duration` bounds the recovery metric to the serving
    # window: once sourcing stops, the drain always re-inflates budgets.
    a_cams, a_dur, w0, w1 = (300, 150.0, 50.0, 90.0)
    spec = DynamismSpec((ComputeSlowdown(w0, w1, 6.0, hosts=("node",)),))
    policies = (
        ("admission_off", None),
        ("admission_on", AdmissionPolicy(beta_floor=0.75, max_live=8)),
    )
    for name, policy in policies:
        a_cfg = ScenarioConfig(
            num_cameras=a_cams, duration_s=a_dur, seed=0, tl="bfs",
            batching="dynamic", m_max=25, drops_enabled=True,
            avoid_drop_positives=True, dynamism=spec,
        )
        t0 = monotonic()
        res = MultiQueryScenario(
            a_cfg, _admission_queries(a_cams, w0), admission=policy
        ).run()
        wall = monotonic() - t0
        s = res.summary()
        rec = res.result.trace.budget_recovery("VA", until=a_dur)
        derived = (
            f"beta_pre={rec['pre']:.3f};beta_post={rec['post']:.3f};"
            f"beta_recovery={rec['recovery']:.3f};live_end={s['queries_live_end']};"
            f"found={s['queries_found']};union_peak={s['union_peak_active']};"
            f"dropped_frac={s['dropped_frac']};"
            f"admitted={s.get('adm_admitted', 64)};queued={s.get('adm_queued', 0)}"
        )
        record("queries", name, wall * 1e6 / max(s["source_events"], 1), derived,
               run_s=round(wall, 4), mode=_mode_label(ctx))
        print(f"{name},{wall * 1e6 / max(s['source_events'], 1):.1f},{derived}")


# --------------------------------------------------------------------- #
# Mega-step engine — the fused device scan vs the interpreted hot loop    #
# --------------------------------------------------------------------- #
def _megastep_shape(smoke: bool) -> Tuple[int, float, Tuple[int, ...]]:
    """(num_cameras, duration_s, N sweep) for the engine comparison."""
    if smoke:
        return 300, 60.0, (1, 4, 16)
    return 10_000, 600.0, (1, 16, 64)


def _megastep_specs(n: int, cams: int):
    """N weighted-ball queries tracking the entity (warm-started from the
    walk, mixed peak speeds).  This is the paper's steady-tracking regime:
    detections keep resetting each spotlight, so the union stays bounded
    and the run sits inside the 10-lane service capacity (~83 events/tick
    at the default 120 ms CR cost) — the operating point where the fused
    scan stays device-resident instead of overflowing to the host mirror.
    Scattering seeds across 10k cameras instead makes every ball grow
    unbounded (no detections), overloads the lanes within seconds, and
    every engine degenerates to measuring the backlog."""
    from repro.query import QuerySpec

    return [QuerySpec(tl="wbfs", tl_peak_speed=3.0 + (i % 3))
            for i in range(n)]


def _time_megastep_fused(cfg, specs_of, reps: int):
    """Best-of-``reps`` fused run (the first rep eats the scan compile);
    returns (wall, xfer, engine, result)."""
    import copy

    from repro.query import MultiQueryScenario

    best = (math.inf, 0.0, "?", None)
    m_cfg = copy.deepcopy(cfg)
    m_cfg.engine = "megastep"
    for _ in range(reps):
        t0 = monotonic()
        scn = MultiQueryScenario(m_cfg, specs_of())
        res = scn.run()
        wall = monotonic() - t0
        if wall < best[0]:
            best = (wall, scn.engine_xfer_s, scn.engine_used, res)
    return best


def bench_megastep(ctx) -> None:
    from repro.query import MultiQueryScenario
    from repro.sim import WorldKey, get_world

    print(f"{SEP}\n# Mega-step — fused device scan vs per-op spotlight vs interpreted")
    cams, dur, ns = _megastep_shape(ctx.smoke)
    cfg = _queries_cfg(cams, dur)
    get_world(WorldKey.from_config(cfg))
    reps = 2 if ctx.smoke else 1
    for n in ns:
        specs_of = lambda: _megastep_specs(n, cams)
        interp_wall = math.inf
        for _ in range(reps):
            t0 = monotonic()
            ref = MultiQueryScenario(cfg, specs_of()).run()
            interp_wall = min(interp_wall, monotonic() - t0)
        # The per-op column (kernel spotlight mode: one device ball
        # dispatch per TL tick) shows what per-op offload costs vs the
        # fused scan.  It only runs at the smallest N of the smoke shape:
        # per-tick dense relaxation over a 10k-camera graph is infeasible
        # by orders of magnitude (that cliff is the point — see PERF.md),
        # and repeating it per N would dominate the CI step for a number
        # that barely varies with N.
        perop_wall = math.inf
        if ctx.smoke and n == ns[0]:
            t0 = monotonic()
            MultiQueryScenario(cfg, specs_of(), spotlight_mode="kernel").run()
            perop_wall = monotonic() - t0
        # Two fused reps minimum: the first pays the one-off scan compile,
        # the steady-state rate is what the engine claims.
        wall, xfer, engine, res = _time_megastep_fused(
            cfg, specs_of, max(reps, 2)
        )
        bit_identical = res.result.summary() == ref.result.summary() and all(
            res.per_query_summary(q) == ref.per_query_summary(q)
            for q in res.per_query
        )
        events = max(res.result.source_events, 1)
        us = wall * 1e6 / events
        perop_us = (
            f"{perop_wall * 1e6 / events:.1f}"
            if math.isfinite(perop_wall) else "n/a"
        )
        derived = (
            f"n_queries={n};engine={engine};bit_identical={bit_identical};"
            f"interp_us={interp_wall * 1e6 / events:.1f};"
            f"perop_us={perop_us};"
            f"speedup_x={interp_wall / wall:.2f};events={events};"
            f"union_peak={res.summary()['union_peak_active']}"
        )
        record("megastep", f"engine_N{n}", us, derived,
               run_s=round(wall - xfer, 4), xfer_s=xfer,
               mode=_mode_label(ctx))
        print(f"megastep_engine_N{n},{us:.1f},{derived}")


def _retime_megastep(ctx, cases) -> Dict[str, Tuple[float, float, float]]:
    """Re-time the fused side only (the recorded us_per_event basis)."""
    from repro.sim import WorldKey, get_world

    cams, dur, ns = _megastep_shape(ctx.smoke)
    cfg = _queries_cfg(cams, dur)
    get_world(WorldKey.from_config(cfg))
    out: Dict[str, Tuple[float, float, float]] = {}
    for n in ns:
        name = f"engine_N{n}"
        if name not in cases:
            continue
        wall, _xfer, _engine, res = _time_megastep_fused(
            cfg, lambda: _megastep_specs(n, cams), 2
        )
        events = max(res.result.source_events, 1)
        out[name] = (wall * 1e6 / events, wall, 0.0)
    return out


# (registered post-definition: COMPARABLE_FAMILIES is declared with the
# early retimers, before this family exists in the file)
COMPARABLE_FAMILIES["megastep"] = _retime_megastep


# --------------------------------------------------------------------- #
# Sharded mega-step — the fused scan over a camera mesh (shard scaling)   #
# --------------------------------------------------------------------- #
def _sharded_shape(smoke: bool) -> Tuple[int, float, Tuple[int, ...]]:
    """(num_cameras, duration_s, N sweep) for the shard-scaling sweep.
    Smaller full shape than the unsharded family: the sweep multiplies by
    the shard counts, and emulated host devices share one CPU."""
    if smoke:
        return 300, 60.0, (1, 4)
    return 1000, 300.0, (1, 16, 64)


def _shard_counts() -> Tuple[int, ...]:
    """Mesh widths to sweep: the divisors of the visible device count in
    {1, 2, 4, 8}.  Under CI this runs with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; with a single
    visible device only the single-shard baseline records."""
    try:
        import jax

        ndev = len(jax.devices())
    except ImportError:
        return (1,)
    return tuple(d for d in (1, 2, 4, 8) if d <= ndev)


def _time_sharded(cfg, specs_of, reps: int, shards: int):
    """Best-of-``reps`` sharded run (first rep eats the per-mesh compile);
    returns (wall, xfer, scn, result)."""
    import copy

    from repro.query import MultiQueryScenario

    mesh = None
    if shards > 1:
        import jax

        from repro.distributed import camera_mesh

        mesh = camera_mesh(jax.devices()[:shards])
    best = (math.inf, 0.0, None, None)
    m_cfg = copy.deepcopy(cfg)
    m_cfg.engine = "megastep"
    for _ in range(reps):
        t0 = monotonic()
        scn = MultiQueryScenario(m_cfg, specs_of(), mesh=mesh)
        res = scn.run()
        wall = monotonic() - t0
        if wall < best[0]:
            best = (wall, scn.engine_xfer_s, scn, res)
    return best


def bench_sharded(ctx) -> None:
    from repro.sim import WorldKey, get_world

    print(f"{SEP}\n# Sharded mega-step — per-event wall vs camera-mesh width")
    cams, dur, ns = _sharded_shape(ctx.smoke)
    shard_counts = _shard_counts()
    cfg = _queries_cfg(cams, dur)
    get_world(WorldKey.from_config(cfg))
    for n in ns:
        specs_of = lambda: _megastep_specs(n, cams)
        base_res = None
        for d in shard_counts:
            wall, xfer, scn, res = _time_sharded(cfg, specs_of, 2, d)
            if d == shard_counts[0]:
                base_res = res
            # Sharding is only allowed to change the wall clock: per-query
            # and global books must match the single-shard run exactly.
            bit_identical = (
                res.result.summary() == base_res.result.summary()
                and all(
                    res.per_query_summary(q) == base_res.per_query_summary(q)
                    for q in res.per_query
                )
            )
            events = max(res.result.source_events, 1)
            us = wall * 1e6 / events
            derived = (
                f"n_queries={n};shards={scn.shards_used};"
                f"engine={scn.engine_used};bit_identical={bit_identical};"
                f"collective_bytes_per_tick={scn.collective_bytes_per_tick:.0f};"
                f"shard_fallback={scn.shard_fallback_reason or 'none'};"
                f"events={events}"
            )
            record("sharded", f"N{n}_D{d}", us, derived,
                   run_s=round(wall - xfer, 4), xfer_s=xfer,
                   mode=_mode_label(ctx))
            print(f"sharded_N{n}_D{d},{us:.1f},{derived}")


def _retime_sharded(ctx, cases) -> Dict[str, Tuple[float, float, float]]:
    cams, dur, ns = _sharded_shape(ctx.smoke)
    cfg = _queries_cfg(cams, dur)
    from repro.sim import WorldKey, get_world

    get_world(WorldKey.from_config(cfg))
    out: Dict[str, Tuple[float, float, float]] = {}
    for n in ns:
        for d in _shard_counts():
            name = f"N{n}_D{d}"
            if name not in cases:
                continue
            wall, _xfer, _scn, res = _time_sharded(
                cfg, lambda: _megastep_specs(n, cams), 2, d
            )
            events = max(res.result.source_events, 1)
            out[name] = (wall * 1e6 / events, wall, 0.0)
    return out


COMPARABLE_FAMILIES["sharded"] = _retime_sharded


# --------------------------------------------------------------------- #
# Fault tolerance — mid-run host crash under DB vs SB: journaled          #
# kill/restore/replay cycle (recovery time, bit-identity) + post-heal     #
# budget recovery.                                                        #
# --------------------------------------------------------------------- #
def bench_faults(ctx) -> None:
    from repro.query import MultiQueryScenario
    from repro.serving.journal import Journal
    from repro.sim import WorldKey, get_world

    print(f"{SEP}\n# Fault tolerance — host crash, journaled restore, DB vs SB")
    cams, dur, crash_t0, outage_s, t_kill, period = _faults_shape(ctx.smoke)
    heal = crash_t0 + outage_s
    for bname, bkw in DYNAMISM_BATCHERS[:2]:  # DB vs SB (the ISSUE pairing)
        cfg = _faults_cfg(cams, dur, crash_t0, outage_s, bkw)
        get_world(WorldKey.from_config(cfg))  # warm: baselines are warm too

        # Reference: the uninterrupted journaled run (us_per_event basis).
        t0 = monotonic()
        ref = MultiQueryScenario(cfg, 2, journal=Journal(period))
        ref_res = ref.run()
        wall = monotonic() - t0

        # Kill the driver at t_kill; only its journal (WAL) survives.
        crashed = MultiQueryScenario(cfg, 2, journal=Journal(period))
        crashed.run_until(t_kill)
        wal = crashed.journal
        restore_to = wal.last_snapshot()["time"]

        # Recovery = build a fresh scenario + replay to the last snapshot
        # (bit-verified against the WAL's frontier), then serve to the end.
        t0 = monotonic()
        recovered = MultiQueryScenario(cfg, 2, journal=Journal(period))
        recovered.restore(wal)
        recovery_s = monotonic() - t0
        rec_res = recovered.run()

        bit_identical = (
            all(rec_res.per_query_summary(q) == ref_res.per_query_summary(q)
                for q in ref_res.per_query)
            and recovered.journal.digest() == ref.journal.digest()
        )
        s = ref_res.summary()
        events = max(s["source_events"], 1)
        brec = ref_res.result.trace.budget_recovery("VA", until=dur)
        fault_drops = ref.sim.faults.fault_drops
        derived = (
            f"crash=node0@[{crash_t0:g},{heal:g});t_kill={t_kill:g};"
            f"snap_period_s={period:g};restore_to={restore_to:g};"
            f"recovery_s={recovery_s:.3f};bit_identical={bit_identical};"
            f"dp_fault={fault_drops};retries={ref.sim.faults.retries};"
            f"beta_pre={brec['pre']:.3f};beta_post={brec['post']:.3f};"
            f"beta_recovery={brec['recovery']:.3f};"
            f"dropped_frac={s['dropped_frac']};events={s['source_events']}"
        )
        record("faults", f"crash_{bname}", wall * 1e6 / events, derived,
               run_s=round(wall, 4), mode=_mode_label(ctx))
        print(f"crash_{bname},{wall * 1e6 / events:.1f},{derived}")


def bench_scale_fig13(ctx) -> None:
    _run_grid("fig13", ctx)
    # Multi-entity probabilistic spotlight: bucket-batched CSR relaxation
    # kernel (via repro.kernels.dispatch) vs the incremental python path.
    from repro.core.roadnet import make_road_network
    from repro.core.tracking import TLProbabilistic

    net = make_road_network(seed=0)
    cams = {c: c for c in range(net.num_vertices)}
    tl = TLProbabilistic(net, cams, entity_speed=4.0, coverage=0.9)
    for i in range(8):
        tl.track(f"entity{i}", camera_id=(i * 97) % net.num_vertices, timestamp=float(i))
    for label, use_kernel in (("python", False), ("kernel", True)):
        tl._entity_searches.clear()
        t0 = monotonic()
        active = tl.spotlight_multi(60.0, use_kernel=use_kernel)
        us = (monotonic() - t0) * 1e6
        record("fig13", f"multi_entity_{label}", us / 8.0,
               f"entities=8;active={len(active)}", mode=_mode_label(ctx))
        print(f"multi_entity_{label},{us/8.0:.1f},entities=8;active={len(active)}")


# --------------------------------------------------------------------- #
# Kernel micro-benchmarks (CPU: oracle path; TPU would hit Pallas)       #
# --------------------------------------------------------------------- #
def bench_kernels(ctx=None) -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.reid_match.ops import reid_match
    from repro.kernels.spotlight_ball.ops import spotlight_ball
    from repro.kernels.ssd_scan.ops import ssd_scan

    print(f"{SEP}\n# Kernel micro-benchmarks (CPU reference path)")
    key = jax.random.PRNGKey(0)

    def timeit(name, fn, *args, reps=5, derived=""):
        fn(*args)  # compile
        t0 = monotonic()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        us = (monotonic() - t0) / reps * 1e6
        record("kernels", name, us, derived)
        print(f"{name},{us:.1f},{derived}")

    B, S, H, Hkv, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    timeit("flash_attention_1k", flash_attention, q, k, v,
           derived=f"flops={2*2*B*S*S*H*D:.2e}")

    qd = jax.random.normal(key, (8, H, D))
    # head-major cache layout (B, Hkv, T, D)
    kc = jax.random.normal(key, (8, Hkv, 4096, D))
    vc = jax.random.normal(key, (8, Hkv, 4096, D))
    ln = jnp.full((8,), 4096, jnp.int32)
    timeit("decode_attention_4k", decode_attention, qd, kc, vc, ln,
           derived=f"kv_bytes={8*4096*Hkv*D*2*4:.2e}")

    x = jax.random.normal(key, (1, 1024, 8, 64)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(key, (1, 1024, 8)))
    A = -jnp.exp(jax.random.normal(key, (8,)) * 0.3)
    Bm = jax.random.normal(key, (1, 1024, 1, 64)) * 0.3
    Cm = jax.random.normal(key, (1, 1024, 1, 64)) * 0.3
    timeit("ssd_scan_1k", lambda *a: ssd_scan(*a)[0], x, dt, A, Bm, Cm,
           derived="chunked state-space scan")

    g = jax.random.normal(key, (4096, 128))
    qq = jax.random.normal(key, (4, 128))
    timeit("reid_match_4k", lambda *a: reid_match(*a)[0], g, qq,
           derived="gallery=4096x128")

    from repro.core.roadnet import make_road_network

    net = make_road_network(num_vertices=512, target_edges=1442, seed=0)
    indptr, indices, weights = net.csr()
    rng = np.random.default_rng(0)
    sources = rng.integers(0, 512, size=16).astype(np.int32)
    radii = rng.uniform(100, 1500, size=16).astype(np.float32)
    timeit(
        "spotlight_ball_512v_16q",
        lambda: spotlight_ball(indptr, indices, weights.astype(np.float32), sources, radii),
        derived="V=512;Q=16;dense min-plus relaxation",
    )


# --------------------------------------------------------------------- #
# Roofline table from the dry-run records (§Roofline source of truth)    #
# --------------------------------------------------------------------- #
def bench_roofline(ctx=None, out_dir: str = "experiments/dryrun") -> None:
    print(f"{SEP}\n# Roofline table (from {out_dir}/*.json; see EXPERIMENTS.md)")
    recs = []
    for path in sorted(glob.glob(f"{out_dir}/*.json")):
        with open(path) as f:
            recs.append(json.load(f))
    if not recs:
        print("roofline,0,missing (run: python -m repro.launch.dryrun --mesh both)")
        return
    print(
        "arch,shape,mesh,compute_ms,memory_ms,collective_ms,dominant,"
        "useful_ratio,peak_dev_GiB,compile_s"
    )
    for r in recs:
        t = r["roofline"]
        print(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{t['compute_s']*1e3:.3f},{t['memory_s']*1e3:.3f},"
            f"{t['collective_s']*1e3:.3f},{t['dominant']},"
            f"{t['useful_ratio']:.3f},{r['peak_device_bytes']/2**30:.2f},"
            f"{r['compile_s']}"
        )


# --------------------------------------------------------------------- #
# Anveshak-scheduled LM serving stage                                    #
# --------------------------------------------------------------------- #
def bench_serving(ctx=None) -> None:
    import jax
    import jax.numpy as jnp

    from repro.serving import ServedStage, StageRequest, calibrate_xi, embed_frames, init_reid_tower

    print(f"{SEP}\n# Anveshak-scheduled serving stage (budgeted dynamic batching)")
    tower = init_reid_tower(jax.random.PRNGKey(0), d_in=128, d_embed=64)
    step = lambda x: embed_frames(tower, jnp.asarray(x))
    xi = calibrate_xi(step, (128,), buckets=(1, 4, 16, 64))
    for rate_hz in (50, 200, 1000):
        stage = ServedStage("CR", step, xi, gamma=0.5, m_max=64, buckets=(1, 4, 16, 64))
        n, done, dropped = 200, 0, 0
        t0 = monotonic()
        for i in range(n):
            target = t0 + i / rate_hz
            while monotonic() < target:
                pass
            res = stage.submit(StageRequest(np.zeros(128, np.float32), source_time=target))
            for r in res or []:
                done += 0 if r.dropped else 1
                dropped += 1 if r.dropped else 0
        for r in stage.flush() or []:
            done += 0 if r.dropped else 1
            dropped += 1 if r.dropped else 0
        wall = monotonic() - t0
        sizes = stage.stats["executed"] / max(stage.stats["batches"], 1)
        record("serving", f"serving_rate{rate_hz}", wall / n * 1e6,
               f"done={done};dropped={dropped};mean_batch={sizes:.1f}")
        print(
            f"serving_rate{rate_hz},{wall/n*1e6:.1f},"
            f"done={done};dropped={dropped};mean_batch={sizes:.1f};"
            f"throughput_hz={done/wall:.0f}"
        )


# --------------------------------------------------------------------- #
# Observability plane: exporter overhead, on vs off                       #
# --------------------------------------------------------------------- #
def _obs_shape(smoke: bool) -> Tuple[int, float]:
    return (300, 60.0) if smoke else (1000, 300.0)


def _obs_case(ctx, case: str) -> Tuple[float, float, float, float, int]:
    """One obs-family measurement on a warm world.

    Cases: ``export_off`` runs the bare pipeline; ``export_on`` adds metric
    collection + Prometheus exposition after the run (the exporter price —
    the hot loop is untouched); ``traced_on`` additionally installs the
    sampled span tracer, which disables the bulk static-delivery fast path
    so every hop is observed (the full-fidelity price).

    Returns ``(us_per_event, run_s, build_s, overhead_s, jit_compiles)``
    where ``overhead_s`` is the wall spent *outside* the run in collection
    and export (0.0 when off) and ``jit_compiles`` is the kernel-plane
    compile count consumed during the case."""
    from repro.kernels import dispatch
    from repro.obs import EventTracer, MetricsRegistry, prometheus_exposition
    from repro.sim import TrackingScenario, WorldKey, get_world

    cams, dur = _obs_shape(ctx.smoke)
    tracer = EventTracer(stride=64) if case == "traced_on" else None
    cfg = ScenarioConfig(num_cameras=cams, duration_s=dur, seed=0, tracer=tracer)
    get_world(WorldKey.from_config(cfg))
    compiles0 = sum(dispatch.profile()["compiles"].values())
    t0 = monotonic()
    scenario = TrackingScenario(cfg)
    res = scenario.run()
    run_s = monotonic() - t0
    overhead_s = 0.0
    if case != "export_off":
        m0 = monotonic()
        reg = MetricsRegistry()
        scenario.publish_metrics(reg, res)
        prometheus_exposition(reg)
        overhead_s = monotonic() - m0
    compiles = sum(dispatch.profile()["compiles"].values()) - compiles0
    events = max(res.source_events, 1)
    us = (run_s + overhead_s) * 1e6 / events
    return us, run_s, scenario.build_seconds, overhead_s, compiles


OBS_CASES = ("export_off", "export_on", "traced_on")


def bench_obs(ctx) -> None:
    """Exporter overhead: the pipeline workload with the obs plane off,
    with metrics collection + exposition (exporters), and with the sampled
    span tracer on top.  The on-case ``us_per_event`` includes collection/
    export wall so the recorded ratio *is* the user-visible overhead."""
    reps = 2
    print(f"{SEP}\n# Observability overhead — obs plane off vs on (best of {reps})")
    best: Dict[str, Tuple[float, float, float, float, int]] = {}
    for case in OBS_CASES:
        for _ in range(reps):
            cur = _obs_case(ctx, case)
            prev = best.get(case)
            if prev is None or cur[0] < prev[0]:
                best[case] = cur
    off_us = best["export_off"][0]
    cams, dur = _obs_shape(ctx.smoke)
    for case in OBS_CASES:
        us, run_s, build_s, overhead_s, compiles = best[case]
        ratio = us / max(off_us, 1e-9)
        derived = (
            f"cams={cams};dur_s={dur:g};overhead_s={overhead_s:.4f};"
            f"vs_off_x={ratio:.3f};build_s={build_s:.3f}"
        )
        record(
            "obs", case, us, derived,
            run_s=round(run_s, 4), build_s=round(build_s, 4),
            mode=_mode_label(ctx),
            jit_compiles=compiles,
            metrics_overhead_s=overhead_s,
        )
        print(f"obs_{case},{us:.1f},{derived}")


def _retime_obs(ctx, cases) -> Dict[str, Tuple[float, float, float]]:
    out: Dict[str, Tuple[float, float, float]] = {}
    for case in OBS_CASES:
        if case not in cases:
            continue
        for _ in range(2):
            us, run_s, build_s, _ovh, _jc = _obs_case(ctx, case)
            prev = out.get(case)
            if prev is None or us < prev[0]:
                out[case] = (us, run_s, build_s)
    return out


COMPARABLE_FAMILIES["obs"] = _retime_obs


BENCHES = {
    "pipeline": bench_pipeline,
    "apps": bench_apps,
    "dynamism": bench_dynamism,
    "queries": bench_queries,
    "megastep": bench_megastep,
    "sharded": bench_sharded,
    "faults": bench_faults,
    "fig567": bench_batching_fig567,
    "fig10": bench_tracking_fig10,
    "fig11": bench_dropping_fig11,
    "fig9": bench_network_fig9,
    "fig12": bench_app2_fig12,
    "fig13": bench_scale_fig13,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
    "serving": bench_serving,
    "obs": bench_obs,
}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="write machine-readable {bench, case, us_per_event, derived, "
        "run_s, build_s, mode} records",
    )
    ap.add_argument(
        "--mode",
        default="auto",
        choices=("auto", "fork", "serial", "cold"),
        help="sweep execution: auto/fork/serial share worlds; cold rebuilds "
        "every config's world (sequential baseline)",
    )
    ap.add_argument("--workers", type=int, default=None, help="sweep pool size")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="short scenario durations (<=60s) so CI machines finish in seconds",
    )
    ap.add_argument(
        "--compare",
        default=None,
        metavar="PATH",
        help="regression gate: re-time the pipeline cases recorded in PATH "
        "and exit non-zero on regression",
    )
    ap.add_argument("--compare-tolerance", type=float, default=0.35)
    args = ap.parse_args(argv)
    # Benchmarks default to the on-disk world cache so repeated invocations
    # skip the one-off builds; opt out with REPRO_WORLD_CACHE=0.
    os.environ.setdefault("REPRO_WORLD_CACHE", "1")

    status = 0
    compare_only = args.compare is not None and args.only is None
    if args.compare is not None:
        status = compare_against(args.compare, args)
    if not compare_only:
        t0 = monotonic()
        for name, fn in BENCHES.items():
            if args.only and name != args.only:
                continue
            fn(args)
        print(f"{SEP}\nTotal benchmark wall time: {monotonic()-t0:.1f}s")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"harness": "benchmarks.run", "records": RECORDS}, f, indent=2)
            f.write("\n")
        print(f"wrote {len(RECORDS)} records to {args.json}")
    return status


if __name__ == "__main__":
    raise SystemExit(main())
