"""Shared scenario runners for the paper-figure benchmarks."""

from __future__ import annotations

import time
from typing import Dict, Optional

from repro.sim import ScenarioConfig, ScenarioResult, TrackingScenario

__all__ = ["run_scenario", "row"]


def run_scenario(**kw) -> ScenarioResult:
    base = dict(num_cameras=1000, duration_s=600.0, seed=0)
    base.update(kw)
    return TrackingScenario(ScenarioConfig(**base)).run()


def row(name: str, res: ScenarioResult, wall_s: float) -> str:
    s = res.summary()
    return (
        f"{name},{wall_s*1e6/max(s['source_events'],1):.1f},"
        f"median_lat_s={s['median_latency_s']};p99_s={s['p99_latency_s']};"
        f"delayed={s['delayed']};delayed_frac={s['delayed_frac']};"
        f"dropped={s['dropped']};dropped_frac={s['dropped_frac']};"
        f"peak_active={s['peak_active']};events={s['source_events']}"
    )
