"""Shared scenario runners + record helpers for the paper-figure benchmarks."""

from __future__ import annotations

from typing import Dict, List

from repro.sim import CaseRecord, ScenarioConfig, ScenarioResult, TrackingScenario

__all__ = ["run_scenario", "row", "record", "record_case", "RECORDS"]

# Machine-readable benchmark records accumulated across a run; written out by
# `python -m benchmarks.run --json PATH` so perf trajectories can be tracked
# across PRs (and replayed by `--compare`).
RECORDS: List[Dict] = []


def record(
    bench: str, case: str, us_per_event: float, derived: str = "", **extra
) -> Dict:
    """One benchmark row.  Every record carries an ``xfer_s`` column —
    the host<->device transfer wall, split out of ``run_s`` so device
    engines report compute and data movement separately.  Families that
    do no device transfer record ``None`` (JSON ``null``), and old
    baselines recorded before the column existed are backfilled with
    ``None`` by the ``--compare`` loader.

    The same backfill contract covers the observability columns:
    ``jit_compiles`` (device-dispatch compile count consumed during the
    case, from the kernel-plane profile) and ``metrics_overhead_s`` (extra
    wall spent collecting + exporting obs-plane metrics; ``None`` for
    families that don't measure it)."""
    rec = {
        "bench": bench,
        "case": case,
        "us_per_event": round(float(us_per_event), 2),
        "derived": derived,
        "xfer_s": None,
        "jit_compiles": None,
        "metrics_overhead_s": None,
    }
    rec.update(extra)
    if rec["xfer_s"] is not None:
        rec["xfer_s"] = round(float(rec["xfer_s"]), 4)
    if rec["metrics_overhead_s"] is not None:
        rec["metrics_overhead_s"] = round(float(rec["metrics_overhead_s"]), 4)
    RECORDS.append(rec)
    return rec


def run_scenario(**kw) -> ScenarioResult:
    """Single-config entry point (used by one-off benchmarks and docs)."""
    base = dict(num_cameras=1000, duration_s=600.0, seed=0)
    base.update(kw)
    return TrackingScenario(ScenarioConfig(**base)).run()


def _derived(summary: Dict, build_s: float) -> str:
    return (
        f"median_lat_s={summary['median_latency_s']};p99_s={summary['p99_latency_s']};"
        f"delayed={summary['delayed']};delayed_frac={summary['delayed_frac']};"
        f"dropped={summary['dropped']};dropped_frac={summary['dropped_frac']};"
        f"peak_active={summary['peak_active']};events={summary['source_events']};"
        f"build_s={build_s:.3f}"
    )


def row(
    name: str,
    res: ScenarioResult,
    run_s: float,
    bench: str = "",
    build_s: float = 0.0,
    mode: str = "full",
) -> str:
    """Record + CSV row for one scenario result.  ``run_s`` must be the
    ``run()`` wall-time only — construction is recorded separately via
    ``build_s`` so one-off world builds don't pollute the per-event rate."""
    s = res.summary()
    us_per_event = run_s * 1e6 / max(s["source_events"], 1)
    derived = _derived(s, build_s)
    record(
        bench or "scenario", name, us_per_event, derived,
        run_s=round(run_s, 4), build_s=round(build_s, 4), mode=mode,
    )
    return f"{name},{us_per_event:.1f},{derived}"


def record_case(bench: str, rec: CaseRecord, mode: str = "full") -> str:
    """Record + CSV row for one sweep :class:`CaseRecord`."""
    derived = _derived(rec.summary, rec.build_s)
    record(
        bench, rec.name, rec.us_per_event, derived,
        run_s=round(rec.run_s, 4), build_s=round(rec.build_s, 4), mode=mode,
    )
    return f"{rec.name},{rec.us_per_event:.1f},{derived}"
