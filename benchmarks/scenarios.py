"""Shared scenario runners for the paper-figure benchmarks."""

from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.sim import ScenarioConfig, ScenarioResult, TrackingScenario

__all__ = ["run_scenario", "row", "record", "RECORDS"]

# Machine-readable benchmark records accumulated across a run; written out by
# `python -m benchmarks.run --json PATH` so perf trajectories can be tracked
# across PRs.
RECORDS: List[Dict] = []


def record(bench: str, case: str, us_per_event: float, derived: str = "") -> Dict:
    rec = {
        "bench": bench,
        "case": case,
        "us_per_event": round(float(us_per_event), 2),
        "derived": derived,
    }
    RECORDS.append(rec)
    return rec


def run_scenario(**kw) -> ScenarioResult:
    base = dict(num_cameras=1000, duration_s=600.0, seed=0)
    base.update(kw)
    return TrackingScenario(ScenarioConfig(**base)).run()


def row(name: str, res: ScenarioResult, wall_s: float, bench: str = "") -> str:
    s = res.summary()
    us_per_event = wall_s * 1e6 / max(s["source_events"], 1)
    derived = (
        f"median_lat_s={s['median_latency_s']};p99_s={s['p99_latency_s']};"
        f"delayed={s['delayed']};delayed_frac={s['delayed_frac']};"
        f"dropped={s['dropped']};dropped_frac={s['dropped_frac']};"
        f"peak_active={s['peak_active']};events={s['source_events']}"
    )
    record(bench or "scenario", name, us_per_event, derived)
    return f"{name},{us_per_event:.1f},{derived}"
