"""Integration: the discrete-event tracking scenario reproduces the paper's
qualitative claims at reduced scale (full-scale runs live in benchmarks/)."""

import numpy as np
import pytest

from repro.sim import ScenarioConfig, TrackingScenario


def run(**kw):
    base = dict(num_cameras=300, duration_s=180.0, seed=0)
    base.update(kw)
    return TrackingScenario(ScenarioConfig(**base)).run()


@pytest.fixture(scope="module")
def db_run():
    return run(batching="dynamic", m_max=25)


def test_pipeline_processes_events(db_run):
    assert db_run.source_events > 50
    assert db_run.on_time > 0
    assert db_run.positives_completed > 0


def test_dynamic_batching_no_deadline_violations(db_run):
    """Paper §5.2.1 headline: Anveshak's batching has zero delayed events."""
    assert db_run.delayed == 0


def test_static_batching_delays_events():
    """Paper §5.2.1: a fixed batch waits unboundedly to fill -> delays."""
    res = run(batching="static", static_batch=20)
    assert res.delayed > 0
    assert res.median_latency > run(batching="static", static_batch=1).median_latency


def test_tl_feedback_loop_controls_active_set(db_run):
    counts = [c for _, c in db_run.active_timeline]
    assert max(counts) < 300, "spotlight must not keep all cameras active"
    assert max(counts) > min(counts), "spotlight expands and contracts"


def test_drops_keep_system_stable_under_overload():
    """Paper §5.2.3 (Fig. 11): without drops an overloaded system blows past
    gamma; with drops the surviving events stay within gamma."""
    overload = dict(tl_peak_speed=7.0, num_va=3, num_cr=3, num_cameras=600,
                    duration_s=240.0, batching="dynamic")
    nodrop = run(drops_enabled=False, **overload)
    drops = run(drops_enabled=True, avoid_drop_positives=True, **overload)
    assert drops.dropped > 0
    # With drops the delayed fraction collapses.
    assert drops.delayed_fraction <= nodrop.delayed_fraction
    assert drops.delayed_fraction < 0.05
    if nodrop.delayed_fraction > 0.2:  # genuinely overloaded baseline
        assert drops.median_latency < nodrop.median_latency


SKEWS = [17.0, -23.0, 5.5, -2.0, 100.0, -77.0, 0.5, 3.3, -9.9, 42.0]


def test_clock_skew_does_not_change_outcomes():
    """§4.6.2: per-node skews (source/sink at skew 0) leave every counter
    unchanged, because all batch/drop decisions cancel the skew.  Checked
    exactly with drops disabled (deterministic trajectory)."""
    a = run(batching="dynamic", drops_enabled=False)
    b = run(batching="dynamic", drops_enabled=False, node_clock_skews=SKEWS)
    assert a.source_events == b.source_events
    assert a.on_time == b.on_time
    assert a.delayed == b.delayed
    assert a.dropped == b.dropped


def test_clock_skew_statistically_invariant_with_drops():
    """With drops the closed loop is chaotic (one float-rounding difference
    reroutes an event and the trajectories diverge), so the skewed run is
    checked statistically: same stability regime, similar rates.  The exact
    rule-level invariance is proven in test_dropping/test_batching."""
    a = run(batching="dynamic", drops_enabled=True, avoid_drop_positives=True)
    b = run(batching="dynamic", drops_enabled=True, avoid_drop_positives=True,
            node_clock_skews=SKEWS)
    assert abs(a.source_events - b.source_events) <= 0.2 * max(a.source_events, 1)
    assert a.delayed_fraction < 0.05 and b.delayed_fraction < 0.05
    assert abs(a.dropped_fraction - b.dropped_fraction) < 0.15
