"""Integration: the discrete-event tracking scenario reproduces the paper's
qualitative claims at reduced scale (full-scale runs live in benchmarks/)."""

import numpy as np
import pytest

from repro.sim import ScenarioConfig, TrackingScenario


def run(**kw):
    base = dict(num_cameras=300, duration_s=180.0, seed=0)
    base.update(kw)
    return TrackingScenario(ScenarioConfig(**base)).run()


@pytest.fixture(scope="module")
def db_run():
    return run(batching="dynamic", m_max=25)


def test_pipeline_processes_events(db_run):
    assert db_run.source_events > 50
    assert db_run.on_time > 0
    assert db_run.positives_completed > 0


def test_dynamic_batching_no_deadline_violations(db_run):
    """Paper §5.2.1 headline: Anveshak's batching has zero delayed events."""
    assert db_run.delayed == 0


def test_static_batching_delays_events():
    """Paper §5.2.1: a fixed batch waits unboundedly to fill -> delays."""
    res = run(batching="static", static_batch=20)
    assert res.delayed > 0
    assert res.median_latency > run(batching="static", static_batch=1).median_latency


def test_tl_feedback_loop_controls_active_set(db_run):
    counts = [c for _, c in db_run.active_timeline]
    assert max(counts) < 300, "spotlight must not keep all cameras active"
    assert max(counts) > min(counts), "spotlight expands and contracts"


def test_drops_keep_system_stable_under_overload():
    """Paper §5.2.3 (Fig. 11): without drops an overloaded system blows past
    gamma; with drops the surviving events stay within gamma."""
    overload = dict(tl_peak_speed=7.0, num_va=3, num_cr=3, num_cameras=600,
                    duration_s=240.0, batching="dynamic")
    nodrop = run(drops_enabled=False, **overload)
    drops = run(drops_enabled=True, avoid_drop_positives=True, **overload)
    assert drops.dropped > 0
    # With drops the delayed fraction collapses.
    assert drops.delayed_fraction <= nodrop.delayed_fraction
    assert drops.delayed_fraction < 0.05
    if nodrop.delayed_fraction > 0.2:  # genuinely overloaded baseline
        assert drops.median_latency < nodrop.median_latency


SKEWS = [17.0, -23.0, 5.5, -2.0, 100.0, -77.0, 0.5, 3.3, -9.9, 42.0]


def test_clock_skew_does_not_change_outcomes():
    """§4.6.2: per-node skews (source/sink at skew 0) leave every counter
    unchanged, because all batch/drop decisions cancel the skew.  Checked
    exactly with drops disabled (deterministic trajectory)."""
    a = run(batching="dynamic", drops_enabled=False)
    b = run(batching="dynamic", drops_enabled=False, node_clock_skews=SKEWS)
    assert a.source_events == b.source_events
    assert a.on_time == b.on_time
    assert a.delayed == b.delayed
    assert a.dropped == b.dropped


def test_clock_skew_statistically_invariant_with_drops():
    """With drops the closed loop is chaotic (one float-rounding difference
    reroutes an event and the trajectories diverge), so the skewed run is
    checked statistically: same stability regime, similar rates.  The exact
    rule-level invariance is proven in test_dropping/test_batching."""
    a = run(batching="dynamic", drops_enabled=True, avoid_drop_positives=True)
    b = run(batching="dynamic", drops_enabled=True, avoid_drop_positives=True,
            node_clock_skews=SKEWS)
    assert abs(a.source_events - b.source_events) <= 0.2 * max(a.source_events, 1)
    assert a.delayed_fraction < 0.05 and b.delayed_fraction < 0.05
    assert abs(a.dropped_fraction - b.dropped_fraction) < 0.15


# --------------------------------------------------------------------- #
# Network-model host classification (paper §5.1 topology)                 #
# --------------------------------------------------------------------- #
def test_transit_delay_host_classification():
    """IPC / LAN / MAN hop classification: same host is IPC; distinct
    cluster hosts (node*/head) share the LAN; any hop touching an edge host
    crosses the MAN — *including two distinct edge sites* (edge3 -> edge7),
    which used to be misclassified as LAN because both names start with
    "edge"."""
    from repro.sim.simulator import DiscreteEventSimulator, NetworkModel

    net = NetworkModel()
    cases = [
        # (src, dst, expected latency)
        ("edge3", "edge3", net.ipc_latency_s),   # IPC: same host
        ("node2", "node2", net.ipc_latency_s),
        ("node0", "node7", net.lan_latency_s),   # LAN: distinct cluster hosts
        ("node4", "head", net.lan_latency_s),
        ("head", "node4", net.lan_latency_s),
        ("edge3", "node1", net.man_latency_s),   # MAN: edge <-> cluster
        ("node1", "edge3", net.man_latency_s),
        ("edge3", "edge7", net.man_latency_s),   # MAN: distinct edge sites
        ("edge7", "edge3", net.man_latency_s),
        ("edge3", "head", net.man_latency_s),
    ]
    for src, dst, latency in cases:
        expected = latency if src == dst else latency + 2900 * 8.0 / net.lan_bandwidth_bps
        assert net.transit_delay(src, dst, 2900, 0.0) == pytest.approx(expected), (src, dst)

    # The simulator's cached classification agrees with the network model.
    sim = DiscreteEventSimulator(net)
    for src, dst, _ in cases:
        assert sim.transit_delay(src, dst, 2900) == pytest.approx(
            net.transit_delay(src, dst, 2900, 0.0)
        ), (src, dst)
    # And the cache serves the same answer twice.
    assert sim.transit_delay("edge3", "edge7", 2900) == pytest.approx(
        net.man_latency_s + 2900 * 8.0 / net.lan_bandwidth_bps
    )
