"""Skew-invariance and stability properties for drops/bounds (§4.6).

Requires the optional ``hypothesis`` test dependency (declared in
pyproject.toml under ``[project.optional-dependencies] test``); the module
is skipped cleanly when it is not installed.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.bounds import stable_batch_size
from repro.core.dropping import drop_before_queuing


def xi(b):
    return 0.05 + 0.01 * b


@settings(max_examples=200, deadline=None)
@given(
    sigma=st.floats(-100, 100, allow_nan=False),
    a1=st.floats(0, 10),
    delay=st.floats(0, 10),
    beta=st.floats(0.01, 5),
)
def test_dp1_skew_invariance(sigma, a1, delay, beta):
    """A device skew shifts both the arrival timestamp and the (locally
    learned) budget's frame; decisions are invariant (§4.6.2)."""
    base = drop_before_queuing(a1, a1 + delay, xi(1), beta)
    # skewed clock: arrival measured as +sigma; the budget beta is learned
    # from departures measured on the same skewed clock, so beta_tilde =
    # beta + sigma relative to the source timestamp... the comparison uses
    # u~ = (a + sigma) - a1 and beta~ = beta + sigma: identical decision.
    skewed = drop_before_queuing(a1, a1 + delay + sigma, xi(1), beta + sigma)
    assert base == skewed


@settings(max_examples=100, deadline=None)
@given(
    omega=st.floats(1.0, 200.0),
    headroom=st.floats(0.2, 5.0),
)
def test_stable_batch_satisfies_constraints(omega, headroom):
    m = stable_batch_size(xi, omega=omega, budget_headroom=headroom)
    if m is not None:
        assert (m - 1) / omega + xi(m) <= headroom + 1e-9
        assert xi(m) <= headroom / 2 + 1e-9