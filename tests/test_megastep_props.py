"""Property + compile-count tests for the fused mega-step engine.

The fuzz half (requires the optional ``hypothesis`` dependency, skipped
cleanly when missing) hammers the bit-exactness gate over random small
configs: whatever TL mix / warm start / duration hypothesis draws, the
fused run must equal the interpreted pipeline *exactly* — not "close", not
per-summary, but deep-equal on every observable book.

The compile-count half pins the dispatch contract: one world geometry run
repeatedly (and chunked over multiple K-tick dispatches) compiles the scan
at most once per bucket shape, the shape is accounted in
``dispatch.jit_cache_sizes()``, and the Pallas lane-chain kernel
(interpret mode off-TPU) is bit-equal to the jnp inner scan it replaces.
"""

import copy

import numpy as np
import pytest

from repro.kernels import dispatch
from repro.query import MultiQueryScenario, QuerySpec
from repro.sim import ScenarioConfig


def _fixed_cfg(**kw):
    base = dict(num_cameras=60, duration_s=60.0, seed=0, tl="bfs",
                batching="dynamic", m_max=25)
    base.update(kw)
    return ScenarioConfig(**base)


def _pair(cfg, specs):
    a = MultiQueryScenario(copy.deepcopy(cfg), copy.deepcopy(specs)).run()
    c = copy.deepcopy(cfg)
    c.engine = "megastep"
    scn = MultiQueryScenario(c, copy.deepcopy(specs))
    b = scn.run()
    return a, b, scn


def _books(res):
    out = {
        "global": res.result.summary(),
        "lat": res.result.latencies,
        "active": res.result.active_timeline,
        "per": {qid: res.per_query_summary(qid) for qid in res.per_query},
    }
    for qid in res.per_query:
        st = res.registry.get(qid)
        out[("ctrl", qid)] = (sorted(st.requested), sorted(st.applied))
    return out


# --------------------------------------------------------------------- #
# Compile-count: at most one compile per (bucket, K) shape               #
# --------------------------------------------------------------------- #
def test_scan_compiles_once_per_bucket_shape():
    """Two different seeds/TL mixes on the same world geometry hit the same
    bucket shape: the second run must not add a compilation, and the shape
    must show up in the shared jit-cache accounting."""
    specs_a = [QuerySpec(tl="wbfs"), QuerySpec(tl="bfs")]
    specs_b = [QuerySpec(tl="bfs", tl_peak_speed=6.0), QuerySpec(tl="base"),
               QuerySpec(tl="wbfs", last_seen_camera=11)]

    _, _, scn = _pair(_fixed_cfg(), specs_a)
    if scn.engine_used != "megastep-device":  # pragma: no cover - no jax
        pytest.skip(f"device backend unavailable: {scn.engine_used}")
    sizes0 = dispatch.jit_cache_sizes()["megastep"]
    assert sizes0 >= 1

    # duration 60 -> T=61 ticks -> two K=64 dispatches would need T>64;
    # same geometry, different query mix and seed: same bucket shape.
    _, _, scn = _pair(_fixed_cfg(seed=3), specs_b)
    assert scn.engine_used == "megastep-device"
    assert dispatch.jit_cache_sizes()["megastep"] == sizes0

    # A longer run spans multiple K-tick chunks of the SAME shape (k0 is a
    # traced scalar): still no new compilation beyond its own (T-bucket)
    # shape, and repeating it adds nothing.
    _, _, scn = _pair(_fixed_cfg(duration_s=150.0), specs_a)
    assert scn.engine_used == "megastep-device"
    grown = dispatch.jit_cache_sizes()["megastep"]
    _, _, scn = _pair(_fixed_cfg(duration_s=150.0, seed=4), specs_b)
    assert scn.engine_used == "megastep-device"
    assert dispatch.jit_cache_sizes()["megastep"] == grown


def test_megastep_cache_is_bounded():
    """The scan shares the bounded-jit-cache contract with every other
    padded kernel: its LRU is registered under the "megastep" key."""
    specs = [QuerySpec(tl="wbfs")]
    _, _, scn = _pair(_fixed_cfg(), specs)
    if scn.engine_used != "megastep-device":  # pragma: no cover - no jax
        pytest.skip(f"device backend unavailable: {scn.engine_used}")
    assert "megastep" in dispatch._JIT_LRU
    assert len(dispatch._JIT_LRU["megastep"]) <= dispatch.MAX_JIT_SHAPES


# --------------------------------------------------------------------- #
# Pallas lane-chain kernel == jnp inner scan (interpret mode off-TPU)     #
# --------------------------------------------------------------------- #
def test_pallas_lane_chain_matches_jnp_scan():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.kernels.megastep.kernel import lane_chain_tick_pallas

    rng = np.random.default_rng(7)
    L, S, U = 4, 8, 32
    with enable_x64():
        real = rng.random((L, S)) < 0.6
        has = rng.random((L, S)) < 0.5
        va_b = rng.uniform(0.0, 3.0, L)
        va_armed = rng.random(L) < 0.5
        cr_b = rng.uniform(0.0, 3.0, L)
        cr_armed = rng.random(L) < 0.5
        draws = rng.integers(0, U // 2, L)
        uniforms = rng.uniform(size=U)
        t_arr, xi_va, xi_cr = 1.25, 0.03125, 0.0625
        d_vc, d_cu, p_tp = 0.001953125, 0.015625, 0.9
        params = jnp.asarray([t_arr, xi_va, xi_cr, d_vc, d_cu, p_tp])

        got = lane_chain_tick_pallas(
            jnp.asarray(real), jnp.asarray(has), jnp.asarray(va_b),
            jnp.asarray(va_armed), jnp.asarray(cr_b), jnp.asarray(cr_armed),
            jnp.asarray(draws), jnp.asarray(uniforms), params,
            interpret=jax.default_backend() != "tpu",
        )

        # The jnp reference: the exact slot_step scan from ops._build_chunk_fn.
        def slot_step(cc, s):
            b_v, a_v, b_c, a_c, dr = cc
            r = jnp.asarray(real)[:, s]
            h = jnp.asarray(has)[:, s]
            fu_v = t_arr >= b_v
            st_v = jnp.where(a_v, b_v, t_arr + (b_v - t_arr))
            end_v = jnp.where(fu_v, t_arr + xi_va, st_v + xi_va)
            q_v = jnp.where(fu_v, 0.0, st_v - t_arr)
            b_v = jnp.where(r, end_v, b_v)
            a_v = jnp.where(r, ~fu_v, a_v)
            arr_c = end_v + d_vc
            fu_c = arr_c >= b_c
            st_c = jnp.where(a_c, b_c, arr_c + (b_c - arr_c))
            end_c = jnp.where(fu_c, arr_c + xi_cr, st_c + xi_cr)
            q_c = jnp.where(fu_c, 0.0, st_c - arr_c)
            b_c = jnp.where(r, end_c, b_c)
            a_c = jnp.where(r, ~fu_c, a_c)
            u = jnp.asarray(uniforms)[jnp.minimum(dr, U - 1)]
            drawn = r & h
            p = drawn & (u <= p_tp)
            dr = dr + drawn
            return (b_v, a_v, b_c, a_c, dr), (
                end_v, q_v, fu_v, end_c, q_c, fu_c, end_c + d_cu, p
            )

        carry0 = (jnp.asarray(va_b), jnp.asarray(va_armed),
                  jnp.asarray(cr_b), jnp.asarray(cr_armed),
                  jnp.asarray(draws))
        want_carry, so = jax.lax.scan(
            slot_step, carry0, jnp.arange(S, dtype=jnp.int64)
        )
        want = want_carry + tuple(x.T for x in so)

        assert len(got) == len(want)
        for g, w in zip(got, want):
            gh, wh = np.asarray(g), np.asarray(w)
            assert gh.dtype == wh.dtype or gh.dtype == np.bool_
            np.testing.assert_array_equal(gh, wh)


# --------------------------------------------------------------------- #
# Hypothesis fuzz: fused == interpreted on random small configs           #
# --------------------------------------------------------------------- #
# The compile-count / Pallas tests above must run even without the
# optional dependency, so only the fuzz half skips.
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @st.composite
    def small_runs(draw):
        cams = draw(st.sampled_from([40, 60]))
        cfg = dict(
            num_cameras=cams,
            duration_s=draw(st.sampled_from([30.0, 45.0, 60.0])),
            seed=draw(st.integers(0, 3)),
            tl="bfs",
            batching=draw(st.sampled_from(["dynamic", "static"])),
            m_max=25,
        )
        if cfg["batching"] == "static":
            cfg["static_batch"] = 1
        n = draw(st.integers(1, 3))
        specs = []
        for _ in range(n):
            specs.append(QuerySpec(
                tl=draw(st.sampled_from(["base", "bfs", "wbfs"])),
                tl_peak_speed=draw(st.one_of(st.none(),
                                             st.sampled_from([3.0, 6.0]))),
                last_seen_camera=draw(st.one_of(st.none(),
                                                st.integers(0, cams - 1))),
            ))
        return cfg, specs

    @settings(max_examples=8, deadline=None, derandomize=True)
    @given(run=small_runs())
    def test_fused_is_bit_equal_to_interpreted(run):
        cfg_kw, specs = run
        cfg = ScenarioConfig(**cfg_kw)
        a, b, scn = _pair(cfg, specs)
        # Whatever backend the draw lands on (device, or host past a
        # capacity divergence), the books must be bit-identical.
        assert scn.engine_used.startswith("megastep-"), (
            scn.engine_fallback_reason
        )
        assert _books(a) == _books(b)
