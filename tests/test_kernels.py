"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_chunked_ref, attention_ref
from repro.kernels.reid_match.kernel import reid_match_pallas
from repro.kernels.reid_match.ref import reid_match_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_decode_step_ref, ssd_ref

KEY = jax.random.PRNGKey(0)


def tol_for(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


# --------------------------------------------------------------------- #
# flash attention                                                        #
# --------------------------------------------------------------------- #
FLASH_CASES = [
    # (B, S, T, Hq, Hkv, D, causal, window, q_offset, dtype)
    (2, 128, 128, 4, 2, 64, True, 0, 0, jnp.float32),
    (1, 200, 200, 5, 5, 64, True, 0, 0, jnp.float32),     # odd heads/len
    (2, 256, 256, 4, 1, 128, True, 64, 0, jnp.bfloat16),  # MQA + window
    (1, 64, 192, 2, 2, 32, True, 0, 128, jnp.float32),    # continuation
    (1, 128, 128, 2, 2, 64, False, 0, 0, jnp.float32),    # bidirectional
    (1, 96, 96, 4, 2, 48, True, 0, 0, jnp.bfloat16),      # Dv == D != mult of 128
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(i) for i in range(len(FLASH_CASES))])
def test_flash_pallas_matches_ref(case):
    B, S, T, Hq, Hkv, D, causal, window, qo, dt = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dt)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dt)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dt)
    ref = attention_ref(q, k, v, causal=causal, window=window, q_offset=qo)
    got = flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=qo,
        block_q=64, block_k=64, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol_for(dt)
    )


def test_flash_pallas_mla_value_dim():
    """MLA: qk head dim 192, value head dim 128."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 96))
    k = jax.random.normal(ks[1], (1, 128, 4, 96))
    v = jax.random.normal(ks[2], (1, 128, 4, 64))
    ref = attention_ref(q, k, v, causal=True)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-4)


def test_chunked_ref_matches_dense_ref():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 130, 4, 48))
    k = jax.random.normal(ks[1], (2, 130, 2, 48))
    v = jax.random.normal(ks[2], (2, 130, 2, 32))
    a = attention_ref(q, k, v, causal=True, window=40)
    b = attention_chunked_ref(q, k, v, causal=True, window=40, q_block=32, kv_block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# --------------------------------------------------------------------- #
# decode attention                                                       #
# --------------------------------------------------------------------- #
DECODE_CASES = [
    (2, 256, 4, 2, 64, 0, jnp.float32),
    (3, 300, 8, 8, 64, 0, jnp.float32),
    (2, 512, 4, 1, 128, 128, jnp.bfloat16),
    (1, 128, 2, 2, 32, 0, jnp.float32),
]


@pytest.mark.parametrize("case", DECODE_CASES, ids=[str(i) for i in range(len(DECODE_CASES))])
def test_decode_pallas_matches_ref(case):
    B, T, Hq, Hkv, D, window, dt = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, D), dt)
    # head-major cache layout (B, Hkv, T, D) — §Perf H3
    k = jax.random.normal(ks[1], (B, Hkv, T, D), dt)
    v = jax.random.normal(ks[2], (B, Hkv, T, D), dt)
    length = jax.random.randint(ks[3], (B,), 1, T + 1)
    ref = decode_attention_ref(q, k, v, length, window=window)
    got = decode_attention_pallas(q, k, v, length, window=window, block_k=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol_for(dt)
    )


# --------------------------------------------------------------------- #
# SSD scan                                                               #
# --------------------------------------------------------------------- #
SSD_CASES = [
    (2, 128, 4, 32, 1, 16, 32, False),
    (1, 96, 8, 16, 2, 32, 32, True),
    (1, 100, 4, 16, 1, 16, 32, False),  # ragged length
    (2, 64, 2, 64, 1, 64, 64, True),
]


@pytest.mark.parametrize("case", SSD_CASES, ids=[str(i) for i in range(len(SSD_CASES))])
def test_ssd_pallas_matches_ref(case):
    B, L, H, P, G, N, chunk, init = case
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, G, N)) * 0.5
    s0 = jax.random.normal(ks[5], (B, H, P, N)) * 0.3 if init else None
    y_ref, fs_ref = ssd_ref(x, dt, A, Bm, Cm, chunk=chunk, initial_state=s0)
    y_got, fs_got = ssd_scan_pallas(
        x, dt, A, Bm, Cm, chunk=chunk, initial_state=s0, interpret=True
    )
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(fs_got), np.asarray(fs_ref), atol=2e-4)


def test_ssd_decode_step_matches_scan():
    """One recurrent step == scan over a length-1 sequence."""
    B, H, P, G, N = 2, 4, 16, 1, 16
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, 1, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, 1, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, 1, G, N))
    Cm = jax.random.normal(ks[4], (B, 1, G, N))
    s0 = jax.random.normal(ks[5], (B, H, P, N)) * 0.3
    y_scan, fs_scan = ssd_ref(x, dt, A, Bm, Cm, chunk=1, initial_state=s0)
    y_step, fs_step = ssd_decode_step_ref(s0, x[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0])
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_scan[:, 0]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(fs_step), np.asarray(fs_scan), atol=1e-5)


# --------------------------------------------------------------------- #
# reid match                                                             #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("N,Q,D,thr", [(100, 3, 64, 0.5), (257, 1, 128, 0.3), (64, 8, 32, 0.9)])
def test_reid_pallas_matches_ref(N, Q, D, thr):
    g = jax.random.normal(jax.random.fold_in(KEY, 1), (N, D))
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (Q, D))
    s1, b1, m1 = reid_match_ref(g, q, threshold=thr)
    s2, b2, m2 = reid_match_pallas(g, q, threshold=thr, block_n=64, interpret=True)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)
    assert np.array_equal(np.asarray(b1), np.asarray(b2))
    assert np.array_equal(np.asarray(m1), np.asarray(m2))


def test_reid_match_finds_planted_target():
    """A gallery row equal to the query must match with score ~1."""
    g = jax.random.normal(KEY, (50, 64))
    q = g[17:18] * 2.0  # same direction
    s, b, m = reid_match_ref(g, q, threshold=0.99)
    assert bool(m[17])
    assert int(jnp.argmax(s)) == 17
